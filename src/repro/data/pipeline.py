"""Deterministic, resumable data pipeline.

Synthetic LM stream: every batch is a pure function of (seed, step), so a
restart from checkpoint step k replays bit-identical batches with no data
state to persist — the fault-tolerance contract at 1000-node scale (DESIGN.md
§7).  A memory-mapped token-file source is provided for real corpora; it
keeps the same (seed, step) -> batch determinism by hashing step into file
offsets.

Batches are structured Markov streams (not uniform noise) so the training
loss has signal to descend — the end-to-end example asserts that descent.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    token_file: Optional[str] = None  # raw uint16/uint32 tokens, memory-mapped


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """Order-2 structured stream: tokens[t+1] = f(tokens[t]) + noise."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    k1, k2, k3 = jax.random.split(key, 3)
    start = jax.random.randint(k1, (B, 1), 0, V)
    mult = 31 % V or 1
    offs = jnp.arange(S + 1)[None, :]
    seq = (start + offs * mult) % V  # deterministic progression
    noise_mask = jax.random.bernoulli(k2, 0.1, (B, S + 1))
    noise = jax.random.randint(k3, (B, S + 1), 0, V)
    seq = jnp.where(noise_mask, noise, seq).astype(jnp.int32)
    return {"tokens": seq[:, :-1], "targets": seq[:, 1:]}


class TokenFileSource:
    """Memory-mapped corpus of raw token ids (little-endian uint32)."""

    def __init__(self, path: str, dtype=np.uint32):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")

    def batch(self, cfg: DataConfig, step: int) -> dict:
        n = len(self.tokens)
        B, S = cfg.global_batch, cfg.seq_len
        rng = np.random.default_rng((cfg.seed, step))
        offs = rng.integers(0, max(n - S - 1, 1), size=(B,))
        rows = np.stack([self.tokens[o : o + S + 1].astype(np.int64) for o in offs])
        rows = np.asarray(rows % cfg.vocab_size, np.int32)
        return {
            "tokens": jnp.asarray(rows[:, :-1]),
            "targets": jnp.asarray(rows[:, 1:]),
        }


class DataIterator:
    """step-indexed iterator; ``seek(step)`` makes resume trivial."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self.source = TokenFileSource(cfg.token_file) if cfg.token_file else None

    def seek(self, step: int) -> None:
        self.step = step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = (
            self.source.batch(self.cfg, self.step)
            if self.source is not None
            else synthetic_batch(self.cfg, self.step)
        )
        self.step += 1
        return b
