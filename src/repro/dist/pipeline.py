"""Pipeline parallelism over the ``pod`` axis (paper mode (2), multi-EDPU).

The paper's TEMPORAL mode runs PRGs serially, each using all compute
resources; across pods the analogous schedule is a microbatch pipeline:
stage s (one pod) runs layer-group s, handing activations to stage s+1 via
``collective-permute`` each tick.  ``bubble_fraction`` is the classic GPipe
idle fraction that the planner trades off against microbatch memory.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def bubble_fraction(n_micro: int, n_stage: int) -> float:
    """GPipe bubble: (S-1)/(M+S-1) of step time is idle ramp-up/down."""
    if n_stage <= 1:
        return 0.0
    if n_micro < 1:
        return 1.0
    return (n_stage - 1) / (n_micro + n_stage - 1)


def pipeline_forward(stage_fn, mesh, axis: str = "pod"):
    """Build a pipelined forward over ``axis``.

    ``stage_fn(w_stage, x) -> x`` is one stage's compute.  The returned
    callable takes ``w`` (n_stage, ...) — one leading-dim slice per stage —
    and ``micro`` (n_micro, mb, ...) microbatches, and returns the
    microbatches after all stages, bit-identical to running the stages
    sequentially.  Schedule: n_micro + n_stage - 1 ticks; each tick every
    device runs its stage on the activation it holds, then the activation
    ring-advances one stage via collective-permute.
    """
    n_stage = dict(mesh.shape)[axis]

    def pipelined(w, micro):
        def body(wi, mb):
            stage = lax.axis_index(axis)
            wi = jnp.squeeze(wi, axis=0)  # (1, ...) local slice -> (...)
            n_micro = mb.shape[0]
            ticks = n_micro + n_stage - 1
            perm = [(j, j + 1) for j in range(n_stage - 1)]
            out = jnp.zeros_like(mb)

            def tick(t, carry):
                out, recv = carry
                # Stage 0 injects microbatch t (clipped: ramp-down ticks feed
                # it stale data whose results are never written); later stages
                # consume what the previous stage permuted to them.
                x_in = jnp.where(stage == 0, mb[jnp.clip(t, 0, n_micro - 1)], recv)
                y = stage_fn(wi, x_in)
                # Only the last stage writes: microbatch t - (n_stage-1).
                out_idx = t - (n_stage - 1)
                wr = jnp.clip(out_idx, 0, n_micro - 1)
                keep = (stage == n_stage - 1) & (out_idx >= 0)
                out = out.at[wr].set(jnp.where(keep, y, out[wr]))
                recv = y if n_stage == 1 else lax.ppermute(y, axis, perm)
                return out, recv

            out, _ = lax.fori_loop(0, ticks, tick, (out, jnp.zeros_like(mb[0])))
            # Results live on the last stage only; the psum (zeros elsewhere)
            # both completes the sum and replicates for out_specs=P().
            return lax.psum(out, axis)

        micro_spec = P(*([None] * micro.ndim))
        w_spec = P(axis, *([None] * (w.ndim - 1)))
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(w_spec, micro_spec),
            out_specs=micro_spec,
            check_rep=False,
        )(w, micro)

    return pipelined
