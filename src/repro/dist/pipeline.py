"""Pipeline parallelism over the ``pod`` axis (paper mode (2), multi-EDPU).

Paper-to-code map: docs/ARCHITECTURE.md §"Pod axis".  The paper's TEMPORAL
mode runs PRGs serially, each using all compute resources; across pods the
analogous schedule is a microbatch pipeline: stage s (one pod) runs
layer-group s, handing activations to stage s+1 via ``collective-permute``
each tick.

Microbatch schedule (GPipe, all-forward):

    tick t = 0 .. M + S - 2       (M microbatches, S stages)
      stage 0    consumes microbatch ``min(t, M-1)`` (ramp-down ticks feed
                 it stale data whose results are never written),
      stage s>0  consumes whatever stage s-1 permuted to it on tick t-1,
      stage S-1  writes microbatch ``t - (S-1)`` once ``t >= S-1``.

    Every device is busy every tick, so the only idle time is the ramp:
    ``bubble_fraction(M, S) = (S-1)/(M+S-1)`` of step time — the planner
    (core/plan.py) trades this against per-microbatch activation memory by
    raising M when ``pod_role == "pipeline"``.

Wire format of the handoff: one activation tensor (mb, ...) per tick per
stage boundary, moved by ``collective-permute`` (point-to-point, no
all-to-all, no host round-trip).  The final ``psum`` over the pod axis is
zero-cost information-wise (all stages but the last hold zeros) and
replicates the result for ``out_specs``.

The tick loop is a ``lax.scan`` (not ``fori_loop``) so the whole schedule
is reverse-mode differentiable: ``launch/train.py`` routes
``pod_role == "pipeline"`` plans straight through ``jax.value_and_grad``
of a loss built on :func:`pipeline_forward`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def bubble_fraction(n_micro: int, n_stage: int) -> float:
    """GPipe bubble: (S-1)/(M+S-1) of step time is idle ramp-up/down."""
    if n_stage <= 1:
        return 0.0
    if n_micro < 1:
        return 1.0
    return (n_stage - 1) / (n_micro + n_stage - 1)


def pipeline_forward(stage_fn, mesh, axis: str = "pod", batch_axes: tuple = ()):
    """Build a pipelined forward over ``axis``.

    ``stage_fn(w_stage, x) -> x`` is one stage's compute.  ``w_stage`` is
    the stage's *local* slice of the weights: a pytree whose leaves keep
    their leading dim — ``n_groups / n_stage`` layer-groups per stage (the
    per-stage param slicing that ``Shardings.param_spec`` mirrors by
    putting ``pod`` on the stacked leading dim).  The returned callable
    takes ``w`` (leaves ``(n_groups, ...)``, ``n_groups % n_stage == 0``)
    and ``micro`` ``(n_micro, mb, ...)`` microbatches, and returns the
    microbatches after all stages, bit-identical to running the stages
    sequentially.  ``batch_axes`` names mesh axes carrying data
    parallelism on the microbatch dim (dim 1), so pipeline and DP compose
    in one shard_map.
    """
    n_stage = dict(mesh.shape)[axis]

    def pipelined(w, micro):
        def body(wi, mb):
            stage = lax.axis_index(axis)
            n_micro = mb.shape[0]
            ticks = n_micro + n_stage - 1
            perm = [(j, j + 1) for j in range(n_stage - 1)]

            def tick(carry, t):
                out, recv = carry
                # Stage 0 injects microbatch t (clipped: ramp-down ticks feed
                # it stale data whose results are never written); later stages
                # consume what the previous stage permuted to them.
                x_in = jnp.where(stage == 0, mb[jnp.clip(t, 0, n_micro - 1)], recv)
                y = stage_fn(wi, x_in)
                # Only the last stage writes: microbatch t - (n_stage-1).
                out_idx = t - (n_stage - 1)
                wr = jnp.clip(out_idx, 0, n_micro - 1)
                keep = (stage == n_stage - 1) & (out_idx >= 0)
                out = out.at[wr].set(jnp.where(keep, y, out[wr]))
                recv = y if n_stage == 1 else lax.ppermute(y, axis, perm)
                return (out, recv), None

            (out, _), _ = lax.scan(
                tick, (jnp.zeros_like(mb), jnp.zeros_like(mb[0])), jnp.arange(ticks)
            )
            # Results live on the last stage only; the psum (zeros elsewhere)
            # both completes the sum and replicates for the out_specs.
            return lax.psum(out, axis)

        batch_entry = (
            batch_axes
            if len(batch_axes) > 1
            else (batch_axes[0] if batch_axes else None)
        )
        micro_spec = P(None, batch_entry, *([None] * (micro.ndim - 2)))
        w_specs = jax.tree.map(
            lambda leaf: P(axis, *([None] * (leaf.ndim - 1))), w
        )
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(w_specs, micro_spec),
            out_specs=micro_spec,
            check_rep=False,
        )(w, micro)

    return pipelined
