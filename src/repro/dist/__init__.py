"""repro.dist — the execution layer that maps an ExecutionPlan onto devices.

core/plan.py *derives* the accelerator instance (SPATIAL vs TEMPORAL stage
modes, P_ATB head sharding, remat/microbatching); this package *executes* it:

  sharding.py    PartitionSpecs per parameter/cache/activation path
                 (Megatron orientation + divisibility safety net)
  collectives.py manual shard_map collectives (ring overlap matmul,
                 compressed gradient psum)
  pipeline.py    TEMPORAL serial-PRG microbatch pipelining over the pod axis
"""
from repro.dist.collectives import compressed_psum, overlap_all_gather_matmul
from repro.dist.pipeline import bubble_fraction, pipeline_forward
from repro.dist.sharding import Shardings

__all__ = [
    "Shardings",
    "overlap_all_gather_matmul",
    "compressed_psum",
    "bubble_fraction",
    "pipeline_forward",
]
