"""repro.dist — the execution layer that maps an ExecutionPlan onto devices.

core/plan.py *derives* the accelerator instance (SPATIAL vs TEMPORAL stage
modes, P_ATB head sharding, remat/microbatching); this package *executes* it:

  sharding.py    PartitionSpecs per parameter/cache/activation path
                 (Megatron orientation + divisibility safety net)
  collectives.py manual shard_map collectives (ring overlap matmul,
                 Megatron-SP reduce-scatter, compressed gradient psum)
  pipeline.py    TEMPORAL serial-PRG microbatch pipelining over the pod axis

Since PR 2 all three are live in launch/train.py: pipeline via
plan.pod_role, compressed_psum via plan.grad_compression, and the SP
collectives via plan.seq_parallel_acts (docs/ARCHITECTURE.md).
"""
from repro.dist.collectives import (
    compressed_psum,
    overlap_all_gather_matmul,
    ring_gather_matmul,
    seq_scatter,
    wire_bytes,
)
from repro.dist.pipeline import bubble_fraction, pipeline_forward
from repro.dist.sharding import Shardings

__all__ = [
    "Shardings",
    "overlap_all_gather_matmul",
    "ring_gather_matmul",
    "seq_scatter",
    "compressed_psum",
    "wire_bytes",
    "bubble_fraction",
    "pipeline_forward",
]
