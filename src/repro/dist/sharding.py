"""Plan -> PartitionSpec mapping (the paper's mode decision, executed).

``Shardings`` is the single object the launchers hand to jit: it turns the
ExecutionPlan's per-stage SPATIAL/TEMPORAL decision into Megatron-oriented
parameter specs (column-parallel QKV/up projections, row-parallel output/down
projections), decode-cache specs (KV heads over ``model`` when divisible,
else the sequence dim), batch specs (TEMPORAL folds the model axis into data
parallelism), and named activation constraints for the forward pass.

Every spec passes through the ``_fit`` divisibility safety net: an axis whose
extent does not divide the dim is dropped to ``None`` rather than letting
GSPMD pad or error — the reduced smoke configs exercise exactly this path.
"""
from __future__ import annotations

import logging
import math
from typing import Any, Optional, Sequence

import jax
import jax.tree_util as jtu
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.plan import SPATIAL, ExecutionPlan

PyTree = Any

logger = logging.getLogger(__name__)

# Megatron orientation by leaf name.  Column-parallel weights shard their
# output (last) dim; row-parallel weights shard their input (second-to-last)
# dim so the pair needs one collective per stage, not two.
COLUMN_PARALLEL = frozenset(
    {"wqkv", "wq", "wk", "wv", "w1", "w3", "w_x", "w_g", "w_r", "w_k", "w_v"}
)
ROW_PARALLEL = frozenset({"wo", "w2", "w_out", "w_o"})

ACT_NAMES = ("act_hidden", "act_heads", "act_kv", "act_heads_flat")


def _key_names(path: Sequence) -> list[str]:
    """Stringified key path (DictKey / SequenceKey / GetAttrKey / raw str)."""
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return names


class Shardings:
    """Sharding rules for one (mesh x plan x arch) accelerator instance.

    Spec-level methods (``param_spec``, ``cache_spec``, ``_fit``,
    ``batch_axes_for``) only read ``mesh.shape`` so they work on shape-only
    mesh stand-ins; ``*_shardings``/``constrain`` need a real mesh.
    """

    def __init__(self, mesh, plan: ExecutionPlan, cfg):
        self.mesh = mesh
        self.plan = plan
        self.cfg = cfg
        self.axis_sizes = dict(mesh.shape)
        self._fit_warned: set = set()  # (dim, axes) pairs already reported

    # ------------------------------------------------------------- helpers
    def _axis(self, name: str) -> int:
        return self.axis_sizes.get(name, 1)

    def _ns(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def _fit(self, spec: P, shape: Sequence[int]) -> P:
        """Divisibility safety net: drop mesh axes a dim cannot host.

        Each drop is logged once per (dim extent, axes) pair so a
        misconfigured mesh (e.g. 9 heads on a 16-wide model axis) is
        debuggable instead of silently running replicated.
        """
        out = []
        for i, entry in enumerate(spec):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            if any(a not in self.axis_sizes for a in axes):
                out.append(None)
                continue
            size = math.prod(self._axis(a) for a in axes)
            ok = i < len(shape) and size > 0 and shape[i] % size == 0
            if not ok:
                dim = shape[i] if i < len(shape) else None
                key = (dim, axes)
                if key not in self._fit_warned:
                    self._fit_warned.add(key)
                    logger.warning(
                        "Shardings safety net: dim %s (index %d of shape %s) "
                        "does not divide over mesh axes %s (extent %d); "
                        "dropping to replicated for arch=%s",
                        dim, i, tuple(shape), axes, size, self.plan.arch,
                    )
            out.append(entry if ok else None)
        return P(*out)

    def _dp_axes(self) -> tuple[str, ...]:
        """Mesh axes that carry data parallelism, outermost first."""
        axes = []
        if self._axis("pod") > 1 and self.plan.pod_role == "data":
            axes.append("pod")
        axes.append("data")
        if self.plan.dp_over_model:
            axes.append("model")  # TEMPORAL: serial PRGs use ALL chips (FSDP)
        return tuple(axes)

    def batch_axes_for(self, batch: int) -> Optional[tuple[str, ...]]:
        """Largest dp-axis prefix the global batch divides, or None."""
        axes = list(self._dp_axes())
        while axes:
            size = math.prod(self._axis(a) for a in axes)
            if batch > 0 and batch % size == 0:
                return tuple(axes)
            axes.pop()
        return None

    @staticmethod
    def _entry(axes: Optional[tuple[str, ...]]):
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    # ------------------------------------------------------------ parameters
    def param_spec(self, path: Sequence, leaf) -> P:
        """PartitionSpec for one parameter leaf, identified by its tree path.

        Leading stack dims (scanned pattern-groups) are absorbed by indexing
        dims from the end of the shape.
        """
        names = _key_names(path)
        name = names[-1] if names else ""
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd <= 1:
            return P(*([None] * nd))
        spec: list = [None] * nd

        stage = "mha" if ("attn" in names or "cross" in names) else "ffn"
        mode = self.plan.mode_for(stage)
        is_moe_w = self.cfg.is_moe and "ffn" in names and name in ("w1", "w2", "w3", "router")

        if name == "embed":
            if self.plan.embed_shard == "vocab":
                spec[-2] = "model"
            elif self.plan.embed_shard == "embed":
                spec[-1] = "model"
        elif name in ("lm_head", "cls_head"):
            if name == "lm_head" and self.plan.embed_shard == "vocab":
                spec[-1] = "model"
        elif is_moe_w:
            if name != "router":  # router (d, E) is tiny: keep replicated
                if self.plan.moe_mode == "ep" and nd >= 3:
                    spec[-3] = "model"  # experts on the stacked leading dim
                elif self.plan.moe_mode == "tp":
                    spec[-2 if name == "w2" else -1] = "model"
        elif mode == SPATIAL:
            if name in COLUMN_PARALLEL:
                spec[-1] = "model"
                if self.plan.zero_weights:
                    spec[-2] = "data"
            elif name in ROW_PARALLEL:
                spec[-2] = "model"
                if self.plan.zero_weights:
                    spec[-1] = "data"
        else:  # TEMPORAL: no tensor parallelism; ZeRO-shard weights over DP
            if (self.plan.dp_over_model or self.plan.zero_weights) and name in (
                COLUMN_PARALLEL | ROW_PARALLEL
            ):
                axes = self._dp_axes() if self.plan.dp_over_model else ("data",)
                spec[-1] = self._entry(axes)
        # Pipeline pods: the stacked layer-group dim is the stage dim — each
        # pod holds n_groups/n_stage groups, exactly the per-stage slice
        # dist.pipeline.pipeline_forward consumes (in_specs P("pod", ...)).
        if (
            self.plan.pod_role == "pipeline"
            and self._axis("pod") > 1
            and len(names) >= 2
            and names[0] == "blocks"
            and names[1] == "stack"
        ):
            spec[0] = "pod"
        return self._fit(P(*spec), shape)

    def param_shardings(self, params: PyTree) -> PyTree:
        return jtu.tree_map_with_path(lambda p, leaf: self._ns(self.param_spec(p, leaf)), params)

    def stack_specs(self, stack: PyTree) -> PyTree:
        """Raw PartitionSpecs for the ``blocks.stack`` subtree.

        shard_map ``in_specs`` for the manual-collective layer paths (the
        Megatron-SP stack and the pipeline scheduler) — the same rules as
        ``param_spec`` but without wrapping in NamedSharding, and with the
        path re-rooted at ``blocks.stack`` so leaf names resolve.
        """
        prefix = (jtu.DictKey("blocks"), jtu.DictKey("stack"))
        return jtu.tree_map_with_path(
            lambda p, leaf: self.param_spec(prefix + tuple(p), leaf), stack
        )

    # ------------------------------------------------------------ decode cache
    def cache_spec(self, path: Sequence, leaf) -> P:
        """Decode-cache leaf spec: batch over data; KV heads over ``model``
        when divisible, else the sequence dim (long-context serving).

        Paged pool leaves (continuous batching) have no batch dim — blocks
        are shared by every request — so only the KV-head dim shards (the
        GSPMD-constrained serve path of the ROADMAP's SP decode item); the
        block dim stays replicated because block ids are global."""
        names = _key_names(path)
        name = names[-1] if names else ""
        shape = tuple(leaf.shape)
        nd = len(shape)
        spec: list = [None] * nd
        if "paged" in names:
            if name in ("k", "v", "k_scale", "v_scale") and nd >= 4:
                spec[-2] = "model"  # (..., n_blocks, block, KV, Dh/1)
            return self._fit(P(*spec), shape)
        if "cross_kv" in names and nd >= 4:
            spec[-4] = "data"  # encoder memory kv: batch only
        elif name in ("k", "v") and nd >= 4:
            spec[-4] = "data"
            if self.cfg.n_kv_heads % max(self._axis("model"), 1) == 0:
                spec[-2] = "model"
            else:
                spec[-3] = "model"  # shard the sequence dim instead
        elif name == "S" and nd >= 4:
            spec[-4] = "data"  # rwkv state (B, H, Dh, Dh)
        elif name in ("h", "shift", "cmix") and nd >= 2:
            spec[-2] = "data"
        elif name == "conv" and nd >= 3:
            spec[-3] = "data"
        elif name == "memory" and nd >= 3:
            spec[-3] = "data"
        return self._fit(P(*spec), shape)

    def cache_shardings(self, cache: PyTree) -> PyTree:
        return jtu.tree_map_with_path(lambda p, leaf: self._ns(self.cache_spec(p, leaf)), cache)

    # ------------------------------------------------------------ batch inputs
    def batch_spec(self, leaf) -> P:
        shape = tuple(leaf.shape)
        spec: list = [None] * len(shape)
        if not shape:
            return P()
        spec[0] = self._entry(self.batch_axes_for(shape[0]))
        if self.plan.seq_shard and len(shape) >= 2:
            spec[1] = "data"  # long-context: batch < data axis, split the seq
        return self._fit(P(*spec), shape)

    def batch_shardings(self, batch: PyTree) -> PyTree:
        return jax.tree.map(lambda leaf: self._ns(self.batch_spec(leaf)), batch)

    # ------------------------------------------------------------ activations
    def act_spec(self, name: str, shape: Sequence[int]) -> P:
        spec: list = [None] * len(shape)
        if not shape:
            return P()
        spec[0] = self._entry(self.batch_axes_for(shape[0]))
        spatial_mha = self.plan.mode_for("mha") == SPATIAL
        if name == "act_hidden":
            if self.plan.seq_shard and len(shape) >= 2:
                spec[1] = "data"
            elif self.plan.seq_parallel_acts and len(shape) >= 2:
                spec[1] = "model"
        elif name == "act_heads" and len(shape) >= 3:
            if spatial_mha and self.plan.head_shards > 1:
                spec[-2] = "model"
        elif name == "act_kv" and len(shape) >= 3:
            if spatial_mha:
                spec[-2] = "model"
        elif name == "act_heads_flat":
            if spatial_mha and self.plan.head_shards > 1:
                spec[-1] = "model"
        return self._fit(P(*spec), shape)

    def constrain(self, x, name: Optional[str] = None):
        """The ``shard`` callable threaded through forward/train/serve."""
        if name not in ACT_NAMES or not hasattr(x, "shape"):
            return x
        spec = self.act_spec(name, x.shape)
        return jax.lax.with_sharding_constraint(x, self._ns(spec))
