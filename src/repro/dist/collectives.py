"""Manual shard_map collectives (EA4RCA-style communication avoiding).

GSPMD's automatic collectives are the baseline; these primitives are the
hand-scheduled alternatives for the two hot exchanges:

``overlap_all_gather_matmul``
    The Megatron all-gather-then-matmul replaced by a ring schedule: each
    device matmuls the row chunk it currently holds while passing it to its
    neighbour via ``collective-permute``, so communication hides behind
    compute and no ``all-gather`` op appears in the HLO.

``compressed_psum``
    Gradient cross-replica sum in a quantized domain, reusing
    ``train/compression.py``'s grid.  bf16 halves the wire bytes; int8
    reduces the exchanged mantissa to 8 bits on a shared scale (the psum
    itself still moves int32 words on this backend — a true narrow-wire
    exchange is future work, see ROADMAP).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.train.compression import quantize


def overlap_all_gather_matmul(mesh, x, w, axis: str = "model"):
    """Compute ``x @ w`` with x row-sharded over ``axis``, w replicated.

    Ring schedule: at step i each device multiplies the chunk that originated
    ``i`` hops behind it and forwards it around the ring, accumulating the
    full (M, N) product locally; after ``n`` steps every device holds the
    replicated result without ever materializing an all-gather of x.
    """
    n = dict(mesh.shape)[axis]

    def ring(xi, wi):
        idx = lax.axis_index(axis)
        m_local = xi.shape[0]
        out = jnp.zeros((m_local * n, wi.shape[1]), xi.dtype)
        perm = [(j, (j + 1) % n) for j in range(n)]

        def body(i, carry):
            out, chunk = carry
            src = (idx - i) % n  # origin of the chunk currently held
            out = lax.dynamic_update_slice(out, chunk @ wi, (src * m_local, 0))
            chunk = lax.ppermute(chunk, axis, perm)
            return out, chunk

        out, _ = lax.fori_loop(0, n, body, (out, xi))
        return out

    return shard_map(
        ring,
        mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None, None),
        check_rep=False,
    )(x, w)


def compressed_psum(g, axis: str, mode: str = "int8"):
    """Cross-replica gradient sum with a compressed wire format.

    Call inside shard_map.  int8: a shared scale (one scalar pmax) puts every
    replica's payload in the int8 grid, the exchange sums small integers, and
    one multiply reconstructs fp32 — the mantissa crossing the wire is 8-bit.
    bf16: the exchange itself runs in bf16.  Both reductions are plain psums
    so shard_map's replication checker accepts ``out_specs=P()``.
    """
    if mode == "bf16":
        q, _ = quantize(g, mode)
        return lax.psum(q, axis).astype(jnp.float32)
    if mode == "int8":
        g32 = g.astype(jnp.float32)
        amax = lax.pmax(jnp.max(jnp.abs(g32)), axis)  # shared grid scale
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int32)
        return lax.psum(q, axis).astype(jnp.float32) * scale
    return lax.psum(g, axis)
