"""Manual shard_map collectives (EA4RCA-style communication avoiding).

GSPMD's automatic collectives are the baseline; these primitives are the
hand-scheduled alternatives for the two hot exchanges (paper-to-code map:
docs/ARCHITECTURE.md §"Communication schedule").

``ring_gather_matmul`` / ``overlap_all_gather_matmul``
    The Megatron all-gather-then-matmul replaced by a ring schedule: each
    device matmuls the chunk it currently holds while passing it to its
    neighbour via ``collective-permute``, so communication hides behind
    compute and no ``all-gather`` op appears in the HLO.
    ``ring_gather_matmul`` is the manual-mode core (call it *inside* an
    enclosing ``shard_map`` — the Megatron-SP layer stack does exactly
    that); ``overlap_all_gather_matmul`` wraps it in its own ``shard_map``
    for standalone use.  Both are written with ``lax.scan`` (not
    ``fori_loop``) so the schedule is reverse-mode differentiable and the
    SP layer stack can train through it.

``seq_scatter``
    The inverse half of the Megatron-SP pair: a row-parallel partial
    product is summed *and* re-sharded onto the sequence dim in one
    ``reduce-scatter`` — the residual stream never materializes replicated.

``compressed_psum``
    Gradient cross-replica sum in a quantized domain, reusing
    ``train/compression.py``'s grid (``quantize`` with a shared pmax
    scale).  Wire formats:

    * ``bf16`` — payload crosses the wire as bf16 (16-bit mantissa+exp),
      summed directly; one cast back to fp32 on arrival.
    * ``int8`` — one scalar ``pmax`` establishes a shared grid, each
      replica's payload is an int8 lattice point on that grid, the
      exchange sums small integers (carried as int32 words on this
      backend — a true narrow-wire transport is future work, see
      ROADMAP), and a single multiply reconstructs fp32.
    * anything else — plain fp32 ``psum`` (the uncompressed baseline).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.train.compression import quantize


def ring_gather_matmul(xi, wi, axis: str, n: int, gather_dim: int = 0):
    """Manual-mode ring matmul: gather ``xi`` over ``axis`` while multiplying.

    Call *inside* shard_map.  ``xi`` is this device's chunk, sharded over
    ``axis`` on ``gather_dim`` (0: (m, K) rows; 1: (B, s, K) sequence);
    ``wi`` is this device's (K, N) weight shard (replicated or
    column-parallel — the ring does not care).  At step i each device
    multiplies the chunk that originated ``i`` hops behind it and forwards
    it around the ring; after ``n`` steps every device holds the full
    ``x @ wi`` with no all-gather of ``x`` ever materialized.
    """
    idx = lax.axis_index(axis)
    chunk_len = xi.shape[gather_dim]
    out_shape = list(xi.shape)
    out_shape[gather_dim] = chunk_len * n
    out_shape[-1] = wi.shape[1]
    out0 = jnp.zeros(tuple(out_shape), xi.dtype)
    perm = [(j, (j + 1) % n) for j in range(n)]

    def step(carry, i):
        out, chunk = carry
        src = (idx - i) % n  # origin of the chunk currently held
        start = [0] * len(out_shape)
        start[gather_dim] = src * chunk_len
        out = lax.dynamic_update_slice(out, chunk @ wi, tuple(start))
        chunk = lax.ppermute(chunk, axis, perm)
        return (out, chunk), None

    (out, _), _ = lax.scan(step, (out0, xi), jnp.arange(n))
    return out


def overlap_all_gather_matmul(mesh, x, w, axis: str = "model"):
    """Compute ``x @ w`` with x row-sharded over ``axis``, w replicated.

    Standalone shard_map wrapper around :func:`ring_gather_matmul`: after
    ``n`` ring steps every device holds the replicated (M, N) product
    without ever materializing an all-gather of x.
    """
    n = dict(mesh.shape)[axis]

    def ring(xi, wi):
        return ring_gather_matmul(xi, wi, axis, n, gather_dim=0)

    return shard_map(
        ring,
        mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None, None),
        check_rep=False,
    )(x, w)


def seq_scatter(partial, axis: str, scatter_dim: int = 1):
    """Manual-mode reduce-scatter: sum row-parallel partials over ``axis``
    and hand each device its ``scatter_dim`` chunk (the Megatron-SP
    "g-bar" collective that returns the residual to sequence sharding)."""
    return lax.psum_scatter(partial, axis, scatter_dimension=scatter_dim, tiled=True)


def compressed_psum(g, axis, mode: str = "int8"):
    """Cross-replica gradient sum with a compressed wire format.

    Call inside shard_map; ``axis`` may be one name or a tuple.  int8: a
    shared scale (one scalar pmax) puts every replica's payload on the same
    int8 grid (``train/compression.quantize`` with an explicit scale), the
    exchange sums small integers, and one multiply reconstructs fp32 — the
    mantissa crossing the wire is 8-bit.  bf16: the exchange itself runs in
    bf16.  Both reductions are plain psums so shard_map's replication
    checker accepts ``out_specs=P()``.
    """
    if mode == "bf16":
        q, _ = quantize(g, mode)
        return lax.psum(q, axis).astype(jnp.float32)
    if mode == "int8":
        g32 = g.astype(jnp.float32)
        amax = lax.pmax(jnp.max(jnp.abs(g32)), axis)  # shared grid scale
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q, _ = quantize(g32, mode, scale=scale)
        return lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32) * scale
    return lax.psum(g, axis)


def wire_bytes(n_elements: int, mode: str) -> int:
    """Bytes one replica puts on the wire per exchange for ``n_elements``
    gradient values (the quantity BENCH_dist.json tracks).  int8 counts the
    ideal narrow-wire payload (1 byte + amortized scale), the format the
    schedule is designed for, not the int32 words the current backend moves.
    """
    per = {"bf16": 2, "int8": 1}.get(mode, 4)
    return n_elements * per + (4 if mode == "int8" else 0)
