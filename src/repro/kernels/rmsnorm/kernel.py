"""Fused RMSNorm(+residual) Pallas kernel.

The paper's C6 ("memory-bound nonlinear operators ride the MM dataflow")
applied to the norm that brackets every EDPU stage: one HBM round-trip
instead of three (residual add, mean-square reduce, scale) — on TPU the row
block stays in VMEM across all three.

Grid (rows / block_rows,); each step normalizes a (block_rows, d) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, *rest, eps: float, has_residual: bool):
    if has_residual:
        r_ref, o_ref = rest
    else:
        (o_ref,) = rest
    x = x_ref[...].astype(jnp.float32)
    if has_residual:
        x = x + r_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    y = y * (1.0 + s_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_call(
    x: jax.Array,
    scale: jax.Array,
    residual=None,
    *,
    block_rows: int = 256,
    eps: float = 1e-6,
    interpret: bool = True,
):
    """x: (N, d); scale: (d,); residual: (N, d) or None -> (N, d)."""
    N, d = x.shape
    br = min(block_rows, N)
    while N % br:
        br //= 2
    in_specs = [
        pl.BlockSpec((br, d), lambda i: (i, 0)),
        pl.BlockSpec((d,), lambda i: (0,)),
    ]
    args = [x, scale]
    if residual is not None:
        in_specs.append(pl.BlockSpec((br, d), lambda i: (i, 0)))
        args.append(residual)
    kernel = functools.partial(
        _rmsnorm_kernel, eps=eps, has_residual=residual is not None
    )
    return pl.pallas_call(
        kernel,
        grid=(N // br,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(*args)
