"""Jitted wrapper: (..., d) model layout -> kernel rows."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_call


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, residual=None, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = True):
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    rf = residual.reshape(-1, shape[-1]) if residual is not None else None
    out = rmsnorm_call(
        xf, scale, rf, block_rows=block_rows, eps=eps, interpret=interpret
    )
    return out.reshape(shape)
