"""custom_vjp for the flash-attention op: FlashAttention-style backward with
score recomputation (nothing quadratic is saved between fwd and bwd).

Forward saves only (o, lse) per row; backward recomputes the (bq x bk) score
blocks in VMEM and accumulates dq/dk/dv — the training-path counterpart of
the paper's "softmax rides the MM dataflow" (C6).  The block-level math here
is the jnp reference of a dedicated bwd Pallas kernel; the fwd Pallas kernel
(kernel.py) plugs into ``flash_attention_vjp`` unchanged on real TPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _fwd_with_lse(q, k, v, *, n_q_per_kv, causal, window, prefix, scale):
    """Oracle forward that also returns the logsumexp rows (BH, Sq)."""
    BH, Sq, D = q.shape
    kk = jnp.repeat(k, n_q_per_kv, axis=0)
    vv = jnp.repeat(v, n_q_per_kv, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    mask = _mask(Sq, k.shape[1], causal, window, prefix)
    s = jnp.where(mask[None], s, NEG_INF)
    m = s.max(-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(s - m[..., None]), -1))
    p = jnp.exp(s - lse[..., None])
    o = jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32))
    return o.astype(q.dtype), lse


def _mask(Sq, Sk, causal, window, prefix):
    iq = jnp.arange(Sq)[:, None]
    ik = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        c = iq >= ik
        if prefix > 0:
            c |= ik < prefix
        m &= c
    if window > 0:
        m &= (iq - ik) < window
    return m


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def flash_attention_vjp(q, k, v, n_q_per_kv, causal, window, prefix, scale):
    o, _ = _fwd_with_lse(
        q, k, v, n_q_per_kv=n_q_per_kv, causal=causal, window=window,
        prefix=prefix, scale=scale,
    )
    return o


def _vjp_fwd(q, k, v, n_q_per_kv, causal, window, prefix, scale):
    o, lse = _fwd_with_lse(
        q, k, v, n_q_per_kv=n_q_per_kv, causal=causal, window=window,
        prefix=prefix, scale=scale,
    )
    return o, (q, k, v, o, lse)


def _vjp_bwd(n_q_per_kv, causal, window, prefix, scale, res, do):
    q, k, v, o, lse = res
    BH, Sq, D = q.shape
    G = n_q_per_kv
    kk = jnp.repeat(k, G, axis=0)
    vv = jnp.repeat(v, G, axis=0)
    q32, do32, o32 = (t.astype(jnp.float32) for t in (q, do, o))
    # recompute p from (q, k, lse): the flash backward identity
    s = jnp.einsum("bqd,bkd->bqk", q32, kk.astype(jnp.float32)) * scale
    mask = _mask(Sq, k.shape[1], causal, window, prefix)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - lse[..., None])
    # dv = p^T do ; dp = do v^T ; ds = p * (dp - rowsum(do * o))
    dv_full = jnp.einsum("bqk,bqd->bkd", p, do32)
    dp = jnp.einsum("bqd,bkd->bqk", do32, vv.astype(jnp.float32))
    delta = jnp.sum(do32 * o32, axis=-1, keepdims=True)
    ds = p * (dp - delta) * scale
    dq = jnp.einsum("bqk,bkd->bqd", ds, kk.astype(jnp.float32))
    dk_full = jnp.einsum("bqk,bqd->bkd", ds, q32)
    # fold GQA groups back onto shared kv heads
    BKH = k.shape[0]
    dk = dk_full.reshape(BKH, G, *dk_full.shape[1:]).sum(1)
    dv = dv_full.reshape(BKH, G, *dv_full.shape[1:]).sum(1)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_vjp.defvjp(_vjp_fwd, _vjp_bwd)


def flash_attention_grad(q, k, v, *, causal=True, window=0, prefix=0):
    """(B, S, H, D) layout wrapper with the custom backward."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KH, -1, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KH, -1, D)
    out = flash_attention_vjp(qr, kr, vr, G, causal, window, prefix, scale)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
