"""Jitted public wrapper: (B, S, H, D) model layout -> kernel layout.

Block sizes default to the CAT plan's MHA-stage PU tile (clamped to the
sequence), mirroring how the paper assigns ATB work to PU specifications.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_call


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "prefix", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    prefix: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D). Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    bk = min(block_k, Sk)
    while Sk % bk:
        bk //= 2
    # (B, S, H, D) -> (B*H, S, D) with q head h consuming kv head h // G
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KH, Sk, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KH, Sk, D)
    out = flash_attention_call(
        qr, kr, vr,
        n_q_per_kv=G, block_q=bq, block_k=bk,
        causal=causal, window=window, prefix=prefix, interpret=interpret,
    )
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
