"""Pure-jnp oracle: exact masked softmax attention (materializes scores)."""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q, k, v, *, n_q_per_kv: int, causal: bool, window: int = 0, prefix: int = 0,
    softmax_scale=None,
):
    """Same layout contract as the kernel: q (BH,Sq,D), kv (BKH,Sk,D)."""
    BH, Sq, D = q.shape
    BKH, Sk, _ = k.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    kk = jnp.repeat(k, n_q_per_kv, axis=0)
    vv = jnp.repeat(v, n_q_per_kv, axis=0)
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    iq = jnp.arange(Sq)[:, None]
    ik = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        c = iq >= ik
        if prefix > 0:
            c |= ik < prefix
        mask &= c
    if window > 0:
        mask &= (iq - ik) < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32)).astype(q.dtype)
