"""Flash-attention Pallas kernel — the ATB (paper Fig. 3) on TPU.
(Eq. 7/8 head-parallelism map: docs/ARCHITECTURE.md §"Eq. 7/8".)

The paper inserts softmax into the MM dataflow between the two attention
matmuls as a PL pipeline branch (C6); on TPU that is exactly the online-
softmax block schedule: scores never leave VMEM, the (m, l, acc) carry rides
across kv blocks.  Supports causal, sliding-window and prefix-LM masking and
GQA (kv head = q head // group).

Layouts: q (B*H, Sq, D); k/v (B*KH, Sk, D).  Grid (B*H, Sq/bq, Sk/bk),
kv innermost; scratch m/l/acc persists across the kv sweep.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, bq: int, bk: int, nk: int, causal: bool, window: int, prefix: int,
    scale: float,
):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0].astype(jnp.float32)  # (bk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    iq = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    ik = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        c = iq >= ik
        if prefix > 0:
            c |= ik < prefix
        mask &= c
    if window > 0:
        mask &= (iq - ik) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _done():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_call(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    n_q_per_kv: int,
    block_q: int,
    block_k: int,
    causal: bool,
    window: int = 0,
    prefix: int = 0,
    softmax_scale=None,
    interpret: bool = True,
):
    """q: (BH, Sq, D); k/v: (BKH, Sk, D), BH = BKH * n_q_per_kv (per batch).

    NOTE caller lays heads out so q row h maps to kv row h // n_q_per_kv.
    """
    BH, Sq, D = q.shape
    BKH, Sk, _ = k.shape
    assert BH == BKH * n_q_per_kv
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    G = n_q_per_kv

    kernel = functools.partial(
        _flash_kernel,
        bq=block_q, bk=block_k, nk=nk,
        causal=causal, window=window, prefix=prefix, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            _VMEM((block_q,), jnp.float32),
            _VMEM((block_q,), jnp.float32),
            _VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
