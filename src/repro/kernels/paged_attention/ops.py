"""Jitted public wrapper: model layout -> kernel layout for paged attention.

``pages_per_tile`` is the plan knob (``ServePlan.pages_per_tile``, derived
from the hardware model's VMEM budget in ``core/plan.derive_serve_plan``);
it is clamped here to a divisor of the table width so the tile sweep covers
the row exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.plan import largest_divisor_of
from repro.kernels.paged_attention.kernel import paged_attention_call


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "window", "pages_per_tile", "interpret"),
)
def paged_attention(
    q: jax.Array,
    entry: dict,
    table: jax.Array,
    lens: jax.Array,
    q_lens: jax.Array,
    *,
    block_size: int,
    window: int = 0,
    pages_per_tile: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, W, H, D) model layout; entry: paged pool entry
    ({"k","v"[,"k_scale","v_scale"]}, models/cache layout); table (B, MB);
    lens/q_lens (B,).  Returns (B, W, H, D); rows >= q_lens[b] are zeros.
    """
    B, W, H, D = q.shape
    KH = entry["k"].shape[2]
    G = H // KH
    MB = table.shape[1]
    ppt = largest_divisor_of(MB, pages_per_tile or MB)
    # (B, W, H, D) -> (B, KH, G*W, D): q head h = kh*G + g consumes kv head
    # kh (same GQA map as models/layers + the flash kernel); row r = g*W + i.
    qr = (
        q.reshape(B, W, KH, G, D).transpose(0, 2, 3, 1, 4).reshape(B, KH, G * W, D)
    )
    out = paged_attention_call(
        qr,
        entry["k"],
        entry["v"],
        entry.get("k_scale"),
        entry.get("v_scale"),
        table.astype(jnp.int32),
        lens.astype(jnp.int32),
        q_lens.astype(jnp.int32),
        slab=W,
        block_size=block_size,
        pages_per_tile=ppt,
        window=window,
        interpret=interpret,
    )
    return out.reshape(B, KH, G, W, D).transpose(0, 3, 1, 2, 4).reshape(B, W, H, D)
