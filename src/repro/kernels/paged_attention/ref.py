"""Pure-jnp oracle for the fused paged-attention kernel.

Same call contract as the kernel (pool entry + block table + per-slot
lens/q_lens), but it is allowed to do the thing the kernel exists to avoid:
materialize the dense gather in HBM and run an exact masked softmax over it.
The kernel's parity sweep (tests/test_kernels_paged_attention.py) pins the
fused path to this oracle across bf16/int8 pages, SWA, ragged lengths and
empty slots.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(
    q: jax.Array,
    entry: dict,
    table: jax.Array,
    lens: jax.Array,
    q_lens: jax.Array,
    *,
    block_size: int,
    window: int = 0,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """q: (B, W, H, D); entry: paged pool entry (models/cache layout);
    table: (B, MB) int32; lens: (B,) positions already cached per slot;
    q_lens: (B,) live query rows per slot (0 idle / 1 decode / <=W prefill).

    Query row i of slot b sits at absolute position ``lens[b] + i`` and is
    live iff ``i < q_lens[b]``; dead rows return zeros.  Assumes this step's
    KV was already written into the pool (``models/cache.paged_update``).
    """
    from repro.models.cache import paged_gather

    B, W, H, D = q.shape
    k, v = paged_gather(entry, table, block_size)  # (B, Skv, KH, D), the
    KH = k.shape[2]  # dense materialization the kernel never does
    G = H // KH
    Skv = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)

    qr = q.reshape(B, W, KH, G, D)
    s = (
        jnp.einsum("bqhgd,bkhd->bhgqk", qr.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )  # (B, KH, G, W, Skv)
    pos = lens[:, None] + jnp.arange(W)[None, :]  # (B, W)
    j = jnp.arange(Skv)
    valid = j[None, None, :] <= pos[:, :, None]  # (B, W, Skv)
    valid &= (jnp.arange(W)[None, :] < q_lens[:, None])[..., None]
    if window > 0:
        valid &= (pos[:, :, None] - j[None, None, :]) < window
    vm = valid[:, None, None]  # (B, 1, 1, W, Skv)
    s = jnp.where(vm, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.where(vm, jnp.exp(s - m), 0.0)  # dead rows stay exactly zero
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p / l, v.astype(jnp.float32))
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, W, H, D).astype(q.dtype)
