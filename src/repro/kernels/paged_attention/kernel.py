"""Fused paged-attention Pallas kernel: decode/mixed-slab attention straight
off the block table.

The serving engine's old path gathered every slot's KV pages into a dense
``(B, cache_len, KH, D)`` HBM buffer before attending — one full write + read
of the whole cache per layer per step.  This kernel consumes the block-table
row directly: for each slot it walks the table in tiles of
``pages_per_tile`` pages, streams whole int8/bf16 pages (all KV heads at
once — one contiguous DMA per pool per page) into a VMEM tile, dequantizes
int8 pages in-kernel on ``train/compression.quantize``'s per-(token,
kv-head) grid, and runs the online-softmax flash loop with per-slot length
masking and sliding-window wraparound.  No dense gathered cache ever exists
in HBM.

Layouts (ops.py does the model-layout shuffle):
  q       (B, KH, G*W, D)   row r of slot b = query i = r % W of group
                            g = r // W, at absolute position lens[b] + i
  pools   (N, bs, KH, D)    k/v pages (+ (N, bs, KH, 1) fp32 scales for
                            int8); 16-bit float pools arrive bitcast to
                            int16 (bits are bits for a DMA, and the
                            interpreter's bf16 copy path is pathological)
  table   (B, MB) int32     scalar-prefetched; block 0 is the trash block
  lens    (B)    int32      positions already cached per slot
  q_lens  (B)    int32      live query rows (0 idle / 1 decode / <=W prefill)

Grid (B, NT), table tiles innermost; the (m, l, acc) scratch carries the
online softmax across the tile sweep, all KV heads batched in one program.
Tiles entirely past a slot's high-water mark (or entirely below its
attention window) skip both the DMA and the compute — per-slot work is
proportional to the slot's live context, not to ``cache_len``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _paged_kernel(
    # scalar prefetch
    tbl_ref, lens_ref, qlens_ref,
    # inputs: q block in VMEM, pools pinned in HBM/ANY
    q_ref, k_ref, v_ref, ks_ref, vs_ref,
    # output
    o_ref,
    # scratch
    kt, vt, kst, vst, m_ref, l_ref, acc_ref, sems,
    *, W: int, bs: int, ppt: int, nt: int, window: int, scale: float,
    quantized: bool,
):
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base = lens_ref[b]  # first live query position of this slot
    q_len = qlens_ref[b]
    tile_lo = t * (ppt * bs)  # absolute position of the tile's first key
    tile_hi = tile_lo + ppt * bs - 1
    # Tile liveness: anything to attend here?  Keys above the slot's last
    # query position are future/trash; with a sliding window, keys below
    # base - (window - 1) are out of every row's window (SWA "wraparound":
    # contexts longer than the window skip their own oldest tiles).
    live = (q_len > 0) & (tile_lo <= base + q_len - 1)
    if window > 0:
        live &= tile_hi >= base - (window - 1)

    @pl.when(live)
    def _tile():
        def copies(p):
            blk = tbl_ref[b, t * ppt + p]
            ops = [
                pltpu.make_async_copy(k_ref.at[blk], kt.at[p], sems.at[0]),
                pltpu.make_async_copy(v_ref.at[blk], vt.at[p], sems.at[1]),
            ]
            if quantized:
                ops += [
                    pltpu.make_async_copy(ks_ref.at[blk], kst.at[p], sems.at[2]),
                    pltpu.make_async_copy(vs_ref.at[blk], vst.at[p], sems.at[3]),
                ]
            return ops

        # Stream the tile's pages into VMEM, one page-fetch ahead of the
        # wait (double-buffered pipeline; a fori_loop so the trace stays
        # O(1) in pages_per_tile instead of unrolling every DMA).
        for cp in copies(0):
            cp.start()

        def fetch(p, _):
            @pl.when(p + 1 < ppt)
            def _next():
                for cp in copies(p + 1):
                    cp.start()

            for cp in copies(p):
                cp.wait()
            return 0

        jax.lax.fori_loop(0, ppt, fetch, 0)

        KH, GW, D = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
        T = ppt * bs

        def pages(tile):  # (ppt, bs, KH, x) -> (T, KH, x), layout untouched
            tile = tile.reshape(T, KH, tile.shape[-1])
            if tile.dtype == jnp.int16:
                # bf16 bits in an int16 carrier — re-tag and keep the MXU
                # operand in bf16 (f32 accumulate): no widening pass over
                # the tile, the matmul upconverts in-register.
                return jax.lax.bitcast_convert_type(tile, jnp.bfloat16)
            return tile.astype(jnp.float32)

        k = pages(kt[...])
        v = pages(vt[...])
        if quantized:  # in-kernel dequant on the per-(token, head) grid
            k = k * kst[...].reshape(T, KH, 1)
            v = v * vst[...].reshape(T, KH, 1)

        # Pages stay in their DMA'd (token, head, d) layout; the head dim
        # rides as a dot_general batch dim so no in-VMEM transpose is paid.
        q = q_ref[0].astype(k.dtype)  # (KH, GW, D)
        s = (
            jax.lax.dot_general(
                q, k, (((2,), (2,)), ((0,), (1,))),
                preferred_element_type=jnp.float32,
            )
            * scale
        )  # (KH, GW, T)

        qi = jax.lax.broadcasted_iota(jnp.int32, (GW, T), 0) % W
        pos = base + qi  # per-row absolute position
        j = tile_lo + jax.lax.broadcasted_iota(jnp.int32, (GW, T), 1)
        valid = (j <= pos) & (qi < q_len)
        if window > 0:
            valid &= (pos - j) < window
        valid = valid[None]  # broadcast over KH
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        # `where` (not bare exp) so fully-masked rows contribute exactly 0
        # while m is still NEG_INF — exp(NEG_INF - NEG_INF) would be 1.
        p_ = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p_.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p_.astype(v.dtype), v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _done():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        ).astype(o_ref.dtype)


def paged_attention_call(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_scale,
    v_scale,
    table: jax.Array,
    lens: jax.Array,
    q_lens: jax.Array,
    *,
    slab: int,
    block_size: int,
    pages_per_tile: int,
    window: int = 0,
    softmax_scale=None,
    interpret: bool = True,
):
    """q: (B, KH, G*W, D) kernel layout; pools (N, bs, KH, D); returns the
    same (B, KH, G*W, D).  ``pages_per_tile`` must divide the table width."""
    B, KH, GW, D = q.shape
    MB = table.shape[1]
    bs = block_size
    ppt = pages_per_tile
    assert MB % ppt == 0, (MB, ppt)
    nt = MB // ppt
    quantized = k_scale is not None
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    if k_pages.dtype == jnp.bfloat16:
        # DMA bits, not floats: the interpreter copies bf16 element-wise
        # (~70x slower than int16); on hardware the bitcast is a no-op and
        # the kernel re-widens with a 16-bit shift.
        k_pages = jax.lax.bitcast_convert_type(k_pages, jnp.int16)
        v_pages = jax.lax.bitcast_convert_type(v_pages, jnp.int16)

    kernel = functools.partial(
        _paged_kernel,
        W=slab, bs=bs, ppt=ppt, nt=nt, window=window, scale=scale,
        quantized=quantized,
    )
    pool_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    scratch = [
        _VMEM((ppt, bs, KH, D), k_pages.dtype),  # k tile
        _VMEM((ppt, bs, KH, D), k_pages.dtype),  # v tile
        _VMEM((ppt, bs, KH, 1), jnp.float32),  # k scales (int8 only)
        _VMEM((ppt, bs, KH, 1), jnp.float32),  # v scales
        _VMEM((KH, GW), jnp.float32),  # m
        _VMEM((KH, GW), jnp.float32),  # l
        _VMEM((KH, GW, D), jnp.float32),  # acc
        pltpu.SemaphoreType.DMA((4,)),
    ]
    if not quantized:  # keep operand count static: pass the pools twice
        k_scale, v_scale = k_pages, v_pages
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, nt),
            in_specs=[
                pl.BlockSpec((1, KH, GW, D), lambda b, t, *_: (b, 0, 0, 0)),
                pool_spec, pool_spec, pool_spec, pool_spec,
            ],
            out_specs=pl.BlockSpec((1, KH, GW, D), lambda b, t, *_: (b, 0, 0, 0)),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((B, KH, GW, D), q.dtype),
        interpret=interpret,
    )(table, lens, q_lens, q, k_pages, v_pages, k_scale, v_scale)
