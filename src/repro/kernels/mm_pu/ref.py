"""Pure-jnp oracle for the MM PU kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(x, activation: str):
    if activation == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if activation == "silu":
        return jax.nn.silu(x)
    if activation == "relu":
        return jax.nn.relu(x)
    if activation == "relu2":
        return jnp.square(jax.nn.relu(x))
    return x


def mm_pu_ref(
    x, w, *, bias=None, residual=None, w_scale=None, activation="none",
    out_dtype=None
):
    out_dtype = out_dtype or x.dtype
    wf = w.astype(jnp.float32)
    if w_scale is not None:
        wf = wf * w_scale.astype(jnp.float32)
    r = jnp.dot(x.astype(jnp.float32), wf, preferred_element_type=jnp.float32)
    if bias is not None:
        r = r + bias.astype(jnp.float32)
    r = _act(r, activation)
    if residual is not None:
        r = r + residual.astype(jnp.float32)
    return r.astype(out_dtype)


def quantize_weights_int8(w):
    """Per-output-channel symmetric int8 (the paper's Int8 deployment mode)."""
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)
