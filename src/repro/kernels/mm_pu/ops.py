"""Jitted public wrapper for the MM PU kernel.

Picks the tile spec via the CAT solver (paper: "select the appropriate AIE MM
PU specification according to the Transformer model specification"), pads to
tile multiples (the ViT L=197 padding effect, reported via ``pad_overhead``),
and dispatches to the Pallas kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hardware import DEFAULT_HARDWARE
from repro.core.pu import MMTileSpec, pick_pu
from repro.kernels.mm_pu.kernel import mm_pu_call


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def pad_overhead(m: int, n: int, k: int, spec: MMTileSpec) -> float:
    """Fraction of MXU work spent on padding for this (mm, spec) pairing."""
    pm = -(-m // spec.block_m) * spec.block_m
    pn = -(-n // spec.block_n) * spec.block_n
    pk = -(-k // spec.block_k) * spec.block_k
    return pm * pn * pk / (m * n * k) - 1.0


@functools.partial(
    jax.jit,
    static_argnames=(
        "spec", "activation", "out_dtype", "interpret",
    ),
)
def mm_pu(
    x: jax.Array,
    w: jax.Array,
    *,
    spec: Optional[MMTileSpec] = None,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    w_scale: Optional[jax.Array] = None,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
):
    """x: (M, K) @ w: (K, N) with fused epilogue. Returns (M, N)."""
    M, K = x.shape
    N = w.shape[1]
    if spec is None:
        spec = pick_pu(M, N, K, DEFAULT_HARDWARE, x.dtype.itemsize)
    bm = min(spec.block_m, max(128, 1 << (M - 1).bit_length()))
    bn = min(spec.block_n, max(128, 1 << (N - 1).bit_length()))
    bk = min(spec.block_k, max(128, 1 << (K - 1).bit_length()))
    xp = _pad_to(x, bm, bk)
    wp = _pad_to(w, bk, bn)
    biasp = _pad_to(bias, 1, bn) if bias is not None else None
    resp = _pad_to(residual, bm, bn) if residual is not None else None
    scalep = _pad_to(w_scale, 1, bn) if w_scale is not None else None
    out = mm_pu_call(
        xp, wp,
        block_m=bm, block_n=bn, block_k=bk,
        bias=biasp, residual=resp, w_scale=scalep,
        activation=activation, out_dtype=out_dtype, interpret=interpret,
    )
    return out[:M, :N]
