"""MM PU Pallas kernel — the AIE MM PU (paper §IV.B) as a VMEM-tiled matmul.

Block shapes come from the CAT tile solver (core/pu.py, Eq. 3'/4'; equation
cross-reference: docs/ARCHITECTURE.md): the tile
family LARGE/STANDARD/SMALL is the paper's Fig. 4 on TPU.  The epilogue
(bias / activation / residual / int8 dequant) is the paper's C6: memory-bound
nonlinear ops ride the MM dataflow instead of round-tripping HBM.

Grid (M/bm, N/bn, K/bk), k innermost; fp32 accumulation in VMEM scratch;
double buffering of the HBM->VMEM streams is Pallas' pipeline (the AIE
DMA/Window analog, C7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces; interpret mode accepts them too
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _apply_activation(x, activation: str):
    if activation == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if activation == "silu":
        return jax.nn.silu(x)
    if activation == "relu":
        return jax.nn.relu(x)
    if activation == "relu2":
        return jnp.square(jax.nn.relu(x))
    return x


def _mm_kernel(
    x_ref, w_ref, *rest, nk: int, activation: str, has_bias: bool,
    has_residual: bool, int8_w: bool
):
    idx = 0
    scale_ref = rest[idx] if int8_w else None
    idx += int(int8_w)
    bias_ref = rest[idx] if has_bias else None
    idx += int(has_bias)
    res_ref = rest[idx] if has_residual else None
    idx += int(has_residual)
    o_ref = rest[idx]
    acc_ref = rest[idx + 1]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    if int8_w:
        w = w.astype(jnp.float32)
    acc_ref[...] += jnp.dot(
        x.astype(jnp.float32) if int8_w else x,
        w,
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        r = acc_ref[...]
        if int8_w:
            r = r * scale_ref[...].astype(jnp.float32)  # per-column dequant
        if has_bias:
            r = r + bias_ref[...].astype(jnp.float32)
        r = _apply_activation(r, activation)
        if has_residual:
            r = r + res_ref[...].astype(jnp.float32)
        o_ref[...] = r.astype(o_ref.dtype)


def mm_pu_call(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    bias=None,
    residual=None,
    w_scale=None,
    activation: str = "none",
    out_dtype=None,
    interpret: bool = True,
):
    """x: (M, K); w: (K, N) [int8 if w_scale given]; bias: (1, N);
    residual: (M, N); w_scale: (1, N). Dims must be multiples of the blocks
    (ops.py pads — the paper's ViT L=197 padding observation)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    nk = K // block_k
    int8_w = w_scale is not None
    out_dtype = out_dtype or x.dtype

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if int8_w:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)))
        args.append(w_scale)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)))
        args.append(bias)
    if residual is not None:
        in_specs.append(pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)))
        args.append(residual)

    kernel = functools.partial(
        _mm_kernel,
        nk=nk,
        activation=activation,
        has_bias=bias is not None,
        has_residual=residual is not None,
        int8_w=int8_w,
    )
    scratch = (
        [_VMEM((block_m, block_n), jnp.float32)]
        if _VMEM is not None
        else [pl.BlockSpec.memory_space]  # pragma: no cover
    )
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)
