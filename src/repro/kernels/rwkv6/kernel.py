"""Chunked RWKV-6 WKV Pallas kernel.

TPU adaptation of the Finch recurrence (DESIGN.md: the ATB of the attention-
free arch).  A GPU implementation leans on warp-level scans; on TPU we use
the chunked linear-attention form so the MXU does the work: per chunk, a
(c x c) decay-weighted intra-chunk matmul plus a (c x D) state contraction,
with the (D_k x D_v) state carried in VMEM scratch across the sequential
chunk grid.

Grid (B*H, S/c), chunk dim innermost.  Decay ratios are computed as
exp(L_{t-1} - L_j) with the exponent masked <= 0 (never overflows; the
factored exp(L)*exp(-L) form would).

Layouts: r/k/v/logw (B*H, S, D); u (H, D); out (B*H, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *, c: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)  # (c, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)  # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)  # (D,)

    L = jnp.cumsum(lw, axis=0)  # (c, D) inclusive
    Lq = L - lw  # L_{t-1}
    # intra-chunk: att[t,s] = sum_d r[t,d] k[s,d] exp(Lq[t,d] - L[s,d]), s < t
    delta = Lq[:, None, :] - L[None, :, :]  # (c, c, D)
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > jax.lax.broadcasted_iota(
        jnp.int32, (c, c), 1
    )
    delta = jnp.where(tri[..., None], delta, -jnp.inf)
    att = jnp.einsum("td,sd,tsd->ts", r, k, jnp.exp(delta))
    o = jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # bonus (current token): (sum_d r u k) * v_t
    o += jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    # cross-chunk state contribution
    rdec = r * jnp.exp(Lq)
    o += jax.lax.dot_general(
        rdec, s_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # state update: S = exp(Lc) * S + (k * exp(Lc - L))^T @ v
    Lc = L[-1]  # (D,)
    kfut = k * jnp.exp(Lc[None, :] - L)
    s_ref[...] = jnp.exp(Lc)[:, None] * s_ref[...] + jax.lax.dot_general(
        kfut, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0] = o.astype(o_ref.dtype)


def wkv_call(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,
    *,
    n_heads: int,
    chunk: int = 64,
    interpret: bool = True,
):
    """r/k/v/logw: (BH, S, D) with BH = B * n_heads; u: (H, D)."""
    BH, S, D = r.shape
    assert S % chunk == 0, (S, chunk)
    H = n_heads
    kernel = functools.partial(_wkv_kernel, c=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, D), lambda b, j: (b % H, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, D), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), r.dtype),
        scratch_shapes=[_VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
