"""Jitted wrapper: model layout (B, S, H, D) -> kernel layout (B*H, S, D)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv_call


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    """r/k/v/w: (B, S, H, D) with w the decay in (0,1); u: (H, D)."""
    B, S, H, D = r.shape
    c = min(chunk, S)
    while S % c:
        c //= 2
    to_k = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-12))
    out = wkv_call(
        to_k(r), to_k(k), to_k(v), to_k(logw), u,
        n_heads=H, chunk=c, interpret=interpret,
    )
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
