"""Sequential-scan oracle for the WKV kernel (kernel layout)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def wkv_ref(r, k, v, logw, u, *, n_heads: int):
    """r/k/v/logw: (BH, S, D); u: (H, D). Returns (BH, S, D)."""
    BH, S, D = r.shape
    H = n_heads
    B = BH // H
    w = jnp.exp(logw.astype(jnp.float32))
    uu = jnp.tile(u.astype(jnp.float32), (B, 1))  # (BH, D)

    def step(S_state, xs):
        rt, kt, vt, wt = xs  # (BH, D)
        kv = kt[:, :, None] * vt[:, None, :]
        out = jnp.einsum("bk,bkd->bd", rt, S_state + uu[:, :, None] * kv)
        S_new = wt[:, :, None] * S_state + kv
        return S_new, out

    xs = tuple(
        t.swapaxes(0, 1).astype(jnp.float32) for t in (r, k, v, w)
    )
    S0 = jnp.zeros((BH, D, D), jnp.float32)
    _, outs = lax.scan(step, S0, xs)
    return outs.swapaxes(0, 1).astype(r.dtype)
