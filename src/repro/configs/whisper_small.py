"""Whisper-small (enc-dec transformer backbone; conv frontend is a stub —
input_specs() provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,  # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=51865,
    enc_dec=True,
    n_enc_layers=12,
    enc_seq=1500,
    norm="layernorm",
    activation="gelu",
    pos_embedding="sinusoidal",
    frontend="audio",
    tie_embeddings=False,
    source="arXiv:2212.04356",
)
