"""BERT-Base — the paper's own primary benchmark model (§V, L=256, Int8).
Encoder-only bidirectional transformer."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="bert-base",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=30522,
    encoder_only=True,
    causal=False,
    norm="layernorm",
    activation="gelu",
    pos_embedding="learned",
    max_seq_len=512,
    source="paper Table IV (BERT-Base, L=256)",
)
