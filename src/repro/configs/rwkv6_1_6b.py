"""RWKV-6 "Finch" 1.6B (attention-free, data-dependent decay).
[arXiv:2404.05892; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,  # wkv heads (head_size 64)
    n_kv_heads=32,
    d_head=64,
    d_ff=7168,
    vocab_size=65536,
    layer_pattern=("rwkv6",),
    rnn_heads=32,
    norm="layernorm",
    activation="rwkv",
    pos_embedding="none",
    tie_embeddings=False,
    source="arXiv:2404.05892",
)
