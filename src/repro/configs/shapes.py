"""The assigned input-shape set for the LM-family archs (task spec).

train_4k / prefill_32k lower ``train_step`` / ``prefill_step``;
decode_32k / long_500k lower ``serve_step`` (one new token against a
seq_len-deep cache).  long_500k requires a sub-quadratic path and is skipped
for pure full-attention archs (noted in DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for one (arch, shape) cell."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape is LONG_500K and not cfg.supports_long_context():
        return False, "pure full-attention arch; 500k decode needs sub-quadratic attention"
    return True, ""


def cells(cfg: ArchConfig):
    for shape in ALL_SHAPES:
        ok, reason = applicable(cfg, shape)
        yield shape, ok, reason
