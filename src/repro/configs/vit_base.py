"""ViT-Base — the paper's second benchmark model (§V, L=197, Int8).
Encoder-only; patch embedding provided precomputed (frontend stub)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="vit-base",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=1,  # no token embedding; patches come precomputed
    encoder_only=True,
    causal=False,
    norm="layernorm",
    activation="gelu",
    pos_embedding="learned",
    frontend="vision",
    n_prefix_embeds=197,  # 14x14 patches + cls
    n_classes=1000,
    max_seq_len=256,
    source="paper Table IV (ViT-Base, L=197)",
)
