"""Architecture configuration — the "Transformer model configuration
information" of paper Table III (Head, Embed_dim, Dff, L) generalized to the
assigned architecture pool (dense / MoE / SSM / hybrid / VLM / audio)."""
from __future__ import annotations

import dataclasses
from typing import Tuple

LayerKind = str  # "attn" | "swa" | "local" | "rglru" | "rwkv6"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # --- attention details ---------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    pos_embedding: str = "rope"  # rope | sinusoidal | learned | none
    sliding_window: int = 0  # 0 = full attention (for "swa" layers)
    local_window: int = 0  # window of "local" attention layers (hybrid)
    layer_pattern: Tuple[LayerKind, ...] = ("attn",)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | geglu | gelu | rwkv
    # --- MoE -------------------------------------------------------------------
    n_experts: int = 1
    top_k: int = 1
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # --- recurrent blocks -------------------------------------------------------
    rnn_heads: int = 0  # RWKV6 wkv heads
    lru_width: int = 0  # RG-LRU recurrence width
    conv_width: int = 4  # temporal conv of the RG-LRU block
    # --- encoder / decoder ------------------------------------------------------
    enc_dec: bool = False
    encoder_only: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0  # fixed encoder length (whisper: 1500 frames)
    # --- modality frontend stub ("input_specs provides precomputed embeddings") -
    frontend: str = "none"  # none | vision | audio
    n_prefix_embeds: int = 0
    n_classes: int = 0  # encoder-only classifier head (ViT)
    tie_embeddings: bool = True
    causal: bool = True
    max_seq_len: int = 1 << 20
    source: str = ""

    # ------------------------------------------------------------------ helpers
    def layer_kind(self, i: int) -> LayerKind:
        return self.layer_pattern[i % len(self.layer_pattern)]

    @property
    def attention_free(self) -> bool:
        return all(k in ("rglru", "rwkv6") for k in self.layer_pattern)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 1

    def fused_qkv_ok(self) -> bool:
        """C5 Independent-Linear applies whenever the arch has attention."""
        return not self.attention_free

    def supports_long_context(self) -> bool:
        """Sub-quadratic path exists: SSM/linear-recurrence state or a bounded
        attention window. Pure full attention -> False (skip long_500k)."""
        kinds = set(self.layer_pattern)
        if kinds & {"rglru", "rwkv6"}:
            return True
        if "attn" in kinds and self.sliding_window == 0:
            return False
        return all(
            (k == "swa" and self.sliding_window > 0)
            or (k == "local" and self.local_window > 0)
            or k in ("rglru", "rwkv6")
            for k in kinds
        )

    def effective_ff_width(self) -> int:
        """Hidden width that activations actually traverse per token."""
        if self.is_moe:
            return self.moe_d_ff * self.top_k
        return self.d_ff

    # ------------------------------------------------------------- param counts
    def _ffn_params(self) -> int:
        if self.is_moe:
            per = self.d_model * self.moe_d_ff
            mult = 3 if self.activation in ("swiglu", "geglu") else 2
            return self.n_experts * mult * per + self.d_model * self.n_experts
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        if self.activation == "rwkv":
            mult = 2  # channel-mix: Wk (d->dff), Wv (dff->d); Wr folded below
        return mult * self.d_model * self.d_ff

    def _attn_params(self) -> int:
        qkv = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        out = self.n_heads * self.d_head * self.d_model
        return qkv + out

    def _layer_params(self, kind: LayerKind) -> int:
        if kind in ("attn", "swa", "local"):
            core = self._attn_params()
        elif kind == "rglru":
            w = self.lru_width or self.d_model
            # in/out proj (x2 branches), temporal conv, recurrence + input gates
            core = (
                2 * self.d_model * w
                + w * self.d_model
                + self.conv_width * w
                + 2 * w * w // max(self.rnn_heads, 1)
                + 2 * w
            )
        elif kind == "rwkv6":
            d = self.d_model
            # r,k,v,o + gates (lora decays ~small)
            core = 4 * d * d + d * self.rnn_heads * self.d_head
        else:
            raise ValueError(kind)
        return core + self._ffn_params() + 2 * self.d_model  # norms

    def param_count(self, active_only: bool = False) -> int:
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            p = self._layer_params(kind)
            if active_only and self.is_moe:
                per = self.d_model * self.moe_d_ff
                mult = 3 if self.activation in ("swiglu", "geglu") else 2
                p = p - self.n_experts * mult * per + self.top_k * mult * per
            total += p
        if self.enc_dec:
            for _ in range(self.n_enc_layers):
                total += self._layer_params("attn")  # encoder self-attn layers
                total += self._attn_params()  # decoder cross-attn (paired)
        if self.n_classes:
            total += self.d_model * self.n_classes
        return int(total)

    # ----------------------------------------------------------------- reduced
    def reduced(self) -> "ArchConfig":
        """Smoke-test-sized member of the same family (task spec f)."""
        n_layers = max(2, len(self.layer_pattern))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=64,
            n_heads=2,
            n_kv_heads=1 if self.n_kv_heads < self.n_heads else 2,
            d_head=32,
            d_ff=128,
            vocab_size=512,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            local_window=min(self.local_window, 16) if self.local_window else 0,
            n_experts=4 if self.is_moe else 1,
            top_k=min(self.top_k, 2) if self.is_moe else 1,
            moe_d_ff=64 if self.is_moe else 0,
            # drop-free at smoke scale so prefill/decode match the full pass
            moe_capacity_factor=float(self.n_experts) if self.is_moe else 1.25,
            rnn_heads=2 if self.rnn_heads else 0,
            lru_width=64 if self.lru_width else 0,
            n_enc_layers=2 if self.enc_dec else 0,
            enc_seq=8 if self.enc_dec else 0,
            n_prefix_embeds=4 if self.n_prefix_embeds else 0,
            n_classes=16 if self.n_classes else 0,
            max_seq_len=4096,
        )
