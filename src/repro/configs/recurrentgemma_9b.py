"""RecurrentGemma-9B (Griffin hybrid: RG-LRU + local attn, 1:2).
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab_size=256000,
    local_window=2048,
    layer_pattern=("rglru", "rglru", "local"),
    rnn_heads=16,  # RG-LRU block-diagonal recurrence gate heads
    lru_width=4096,
    conv_width=4,
    norm="rmsnorm",
    activation="geglu",
    source="arXiv:2402.19427",
)
