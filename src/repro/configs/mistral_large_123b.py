"""Mistral-Large-Instruct-2407 (123B dense).
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    activation="swiglu",
    tie_embeddings=False,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)
