"""PaliGemma-3B (SigLIP frontend stub + gemma-2B decoder backbone).
[arXiv:2407.07726; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab_size=257216,
    norm="rmsnorm",
    activation="geglu",
    frontend="vision",
    n_prefix_embeds=256,  # 224/14 = 16x16 SigLIP patches, precomputed (stub)
    source="arXiv:2407.07726",
)
