"""Config registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig
from repro.configs.shapes import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ShapeSpec,
    applicable,
    cells,
)

_MODULES = {
    "mistral-large-123b": "mistral_large_123b",
    "qwen3-1.7b": "qwen3_1_7b",
    "smollm-135m": "smollm_135m",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "paligemma-3b": "paligemma_3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-small": "whisper_small",
    # the paper's own evaluation models
    "bert-base": "bert_base",
    "vit-base": "vit_base",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k not in ("bert-base", "vit-base"))
ALL_ARCHS = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    key = name.replace("_", "-").lower()
    if key.endswith("-reduced"):
        return get_config(key[: -len("-reduced")]).reduced()
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[key]}")
    return mod.CONFIG


__all__ = [
    "ArchConfig",
    "ShapeSpec",
    "ALL_SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "applicable",
    "cells",
    "get_config",
    "ASSIGNED_ARCHS",
    "ALL_ARCHS",
]
