from repro.serve.engine import (
    ServingEngine,
    greedy_generate,
    make_decode_step,
    make_mixed_step,
    make_prefill_step,
)
from repro.serve.scheduler import BlockAllocator, Request, Scheduler, random_stream
from repro.serve.speculative import (
    ModelDraft,
    NGramDraft,
    make_draft_source,
    prompt_lookup,
)

__all__ = [
    "ServingEngine",
    "greedy_generate",
    "make_decode_step",
    "make_mixed_step",
    "make_prefill_step",
    "BlockAllocator",
    "Request",
    "Scheduler",
    "random_stream",
    "ModelDraft",
    "NGramDraft",
    "make_draft_source",
    "prompt_lookup",
]
