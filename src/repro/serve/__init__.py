from repro.serve.engine import (
    ServingEngine,
    greedy_generate,
    make_decode_step,
    make_prefill_step,
)
from repro.serve.scheduler import BlockAllocator, Request, Scheduler, random_stream

__all__ = [
    "ServingEngine",
    "greedy_generate",
    "make_decode_step",
    "make_prefill_step",
    "BlockAllocator",
    "Request",
    "Scheduler",
    "random_stream",
]
