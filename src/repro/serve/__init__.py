"""``repro.serve`` — the public serving API.

The documented surface is deliberately small:

* :class:`Request` — one request: ``Request(prompt, max_new_tokens,
  tenant=, priority=, slo_ttft_ms=, tag=)`` (multi-tenant descriptors are
  keyword-only; everything after them in the dataclass is scheduler-owned
  runtime state).
* :class:`ServingEngine` — the continuous-batching engine; its contract is
  ``submit()`` / ``run()`` / ``summary()``.
* :func:`make_draft_source` — speculative-decoding draft factory
  (:class:`NGramDraft` / :class:`ModelDraft` are its products; construct
  through the factory unless a test needs one directly).
* ``random_stream`` / ``make_trace`` / ``parse_mix`` / ``per_class_report``
  / ``WORKLOADS`` — synthetic streams and multi-tenant trace workloads.
* ``greedy_generate`` and the eager ``make_prefill_step`` /
  ``make_decode_step`` — the whole-batch fallback path (also the parity
  oracle).
* :class:`FaultInjector` + the fault-tolerance error types
  (:class:`TransientDeviceError`, :class:`StallError`,
  :class:`LadderExhausted`) — the chaos harness and the exceptions the
  hardened engine raises (see ``docs/ROBUSTNESS.md``).
* :class:`Observability` (re-exported from :mod:`repro.obs`) — the
  metrics + tracing + drift bundle the engine accepts via
  ``ServingEngine(..., obs=)`` (see ``docs/OBSERVABILITY.md``).

Everything else (``Scheduler``, ``BlockAllocator``, ``PrefixIndex``,
``make_mixed_step``, the slab-packing helpers) is engine internals:
importable from their modules for tests and extensions, but not part of the
stable seam — PR 7+ should build on the names in ``__all__``.
"""

from repro.obs import Observability
from repro.serve.engine import (
    ServingEngine,
    greedy_generate,
    make_decode_step,
    make_prefill_step,
)
from repro.serve.faults import (
    FaultInjector,
    LadderExhausted,
    StallError,
    TransientDeviceError,
)
from repro.serve.scheduler import Request, random_stream
from repro.serve.speculative import make_draft_source
from repro.serve.workload import (
    WORKLOADS,
    WorkloadClass,
    make_trace,
    parse_mix,
    per_class_report,
)

__all__ = [
    # engine
    "ServingEngine",
    "Request",
    # fault tolerance / chaos harness
    "FaultInjector",
    "TransientDeviceError",
    "StallError",
    "LadderExhausted",
    # observability bundle (repro.obs)
    "Observability",
    # draft sources
    "make_draft_source",
    # streams / workloads
    "random_stream",
    "WORKLOADS",
    "WorkloadClass",
    "make_trace",
    "parse_mix",
    "per_class_report",
    # eager fallback + oracle
    "greedy_generate",
    "make_prefill_step",
    "make_decode_step",
]
