"""Radix index over token prefixes -> resident KV pool blocks.

Copy-on-write prefix sharing (docs/ARCHITECTURE.md §"Prefix sharing"):
production traffic is many users hitting a handful of shared system
prompts, so the scheduler keeps a trie whose edges are *block-sized token
runs* and whose nodes name the pool block holding that run's KV.  A new
request walks the trie with its prompt and leaves with the longest
resident prefix:

* **full blocks** — every ``block_size``-token edge that matches exactly is
  shared by reference: the scheduler bumps the block's refcount in
  :class:`~repro.serve.scheduler.BlockAllocator` and points the new
  request's block-table row at the same physical pages.  N users on one
  system prompt cost one set of pages and one prefill.
* **partial block** — when the prompt diverges *inside* a resident block
  (a non-block-aligned divergence point), the matched head of that block
  is still reusable KV; the scheduler forks it — copies the pages to a
  fresh block and prefills only the divergent tail.  This is the
  copy-on-write event: the resident block is never written by a sharer.

Matching is capped at ``len(prompt) - 1`` tokens: the unified step samples
a request's first output token from the logits of its final prompt row, so
even a fully-resident prompt must leave one row to prefill (vLLM's prefix
cache makes the same cut).

The index holds **no references of its own**: a node is valid exactly while
some live request holds its block (refcount > 0).  The scheduler calls
:meth:`forget` for every block the allocator actually releases, which drops
the node *and its subtree* — children encode longer prefixes that are
unreachable without the parent, so keeping them could at worst hide
shareable blocks, never corrupt a match.

KV pages are a pure function of the token prefix (causal attention,
deterministic forward), so token-content matching is exact: a block may
hold prompt tokens, generated tokens, or a mix — once full it never changes
(per-slot lengths are monotone; rejected speculative rows are rolled back
before registration) and any request whose prompt matches its content would
have written byte-identical pages, including the int8 quantization grid.
"""

from __future__ import annotations

from typing import Optional


class _Node:
    __slots__ = ("key", "block", "parent", "children")

    def __init__(self, key, block: int, parent: Optional["_Node"]):
        self.key = key  # tuple of block_size tokens (None at the root)
        self.block = block  # pool block id holding this run's KV
        self.parent = parent
        self.children: dict[tuple, _Node] = {}


class PrefixIndex:
    """Trie of block-sized token runs -> resident pool block ids."""

    def __init__(self, block_size: int):
        self.block_size = int(block_size)
        self._root = _Node(None, -1, None)
        self._by_block: dict[int, _Node] = {}

    def __len__(self) -> int:
        return len(self._by_block)

    # ------------------------------------------------------------- matching
    def match(self, tokens) -> tuple[list[int], Optional[tuple[int, int]], int]:
        """Longest resident prefix of ``tokens``.

        Returns ``(full, partial, n)``: ``full`` is the list of pool blocks
        whose entire ``block_size``-token run matches (share by refcount),
        ``partial`` is ``(block, k)`` when the next resident block matches
        only its first ``k < block_size`` tokens (fork-on-write candidate),
        and ``n = len(full) * block_size + k`` is the total matched token
        count, capped at ``len(tokens) - 1``.
        """
        bs = self.block_size
        toks = [int(t) for t in tokens]
        cap = len(toks) - 1
        if cap <= 0 or not self._root.children:
            return [], None, 0
        node, full = self._root, []
        p = 0
        while p + bs <= len(toks):
            child = node.children.get(tuple(toks[p : p + bs]))
            if child is None:
                break
            full.append(child.block)
            node, p = child, p + bs
        # best partial continuation among the children (divergence mid-block)
        best_block, best_k = -1, 0
        want = toks[p : p + bs]
        if want:
            for key, child in node.children.items():
                k = 0
                while k < len(want) and key[k] == want[k]:
                    k += 1
                if k > best_k:
                    best_block, best_k = child.block, k
        matched = min(p + best_k, cap)
        n_full, k = matched // bs, matched % bs
        if k == 0:
            return full[:n_full], None, matched
        # the partial block is either a trimmed full match or the best child
        blk = full[n_full] if n_full < len(full) else best_block
        return full[:n_full], (blk, k), matched

    # --------------------------------------------------------- registration
    def register(self, tokens, blocks: list[int]) -> int:
        """Walk/extend the path for ``tokens``, mapping the i-th full
        ``block_size``-token run to ``blocks[i]``.

        An existing node keeps its block (the first resident copy wins —
        identical content in two physical blocks is indexed once); the
        duplicate simply stays private to its owner.  Returns the number of
        newly indexed blocks."""
        bs = self.block_size
        toks = [int(t) for t in tokens]
        node, new = self._root, 0
        for i, b in enumerate(blocks):
            key = tuple(toks[i * bs : (i + 1) * bs])
            if len(key) < bs:
                break
            child = node.children.get(key)
            if child is None:
                child = _Node(key, int(b), node)
                node.children[key] = child
                self._by_block[int(b)] = child
                new += 1
            node = child
        return new

    # --------------------------------------------------------- invalidation
    def forget(self, block: int) -> None:
        """Drop the node for a released block (and its now-unreachable
        subtree).  Tolerates blocks that were never indexed or whose node
        was already dropped with an ancestor — the allocator frees in
        arbitrary order within one release."""
        node = self._by_block.pop(int(block), None)
        if node is None:
            return
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        stack = list(node.children.values())
        while stack:
            n = stack.pop()
            if self._by_block.get(n.block) is n:
                del self._by_block[n.block]
            stack.extend(n.children.values())
