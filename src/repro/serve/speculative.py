"""Speculative decoding: draft sources for the continuous-batching engine.

CAT's serving roofline proves decode is bandwidth-bound — one weight stream
per step with the MXU mostly idle.  Speculative decoding converts that idle
compute into tokens: a drafter proposes gamma cheap continuation tokens per
running slot, the engine packs them (plus the slot's real token) into the
existing unified (B, W) slab as a gamma+1-row verification chunk, and the
ONE jitted step scores every row at once.  The host keeps the longest draft
prefix matching the target's own greedy argmax, so the emitted tokens are
*exactly* what plain decode would have produced — any draft source only
changes speed, never tokens.  Rollback past rejected rows is the per-slot
length vector alone: the block table is untouched, the stale KV the dead
rows wrote is masked by the kernel and overwritten when the slot advances.

Two draft sources:

* :class:`NGramDraft` — prompt-lookup self-drafting: match the sequence's
  trailing n-gram against its own history and propose the tokens that
  followed last time.  No second model, no device work, cheap enough for
  the CPU-interpret CI matrix; shines on repetitive continuations.
* :class:`ModelDraft` — a small model (any ``configs/`` entry, e.g.
  smollm-135m drafting for qwen3-1.7b) with its *own* paged KV cache and
  its own single jitted mixed step (the same slab contract as the target
  engine, one trace total).  Slot state is keyed by request id and
  self-heals: each proposal round diffs the target's actual sequence
  against what the drafter has cached and rolls its length vector back to
  the common prefix, so target-side eviction, slot reuse, and rejected
  drafts need no explicit invalidation protocol.

The draft *depth* is a plan decision (``ServePlan.spec_len``, derived in
``core/plan.derive_serve_plan`` from the compute-vs-bandwidth slack), not a
drafter property — the same joint hardware/model contract that sizes the
decode batch sizes gamma.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HARDWARE, HardwareSpec
from repro.core.plan import ExecutionPlan, ServePlan, derive_plan, derive_serve_plan
from repro.models.cache import init_paged_cache
from repro.serve.engine import make_mixed_step
from repro.serve.scheduler import BlockAllocator

Ask = tuple  # (rid, full token sequence so far, max drafts wanted)


def prompt_lookup(
    seq: Sequence[int], n: int, max_ngram: int = 3, min_ngram: int = 1
) -> list[int]:
    """Propose up to ``n`` tokens by copying what followed an earlier
    occurrence of the sequence's trailing n-gram.

    Longest n-gram first; within one n-gram length the *most recent*
    occurrence whose continuation has all ``n`` tokens wins (a match at the
    sequence tail can only contribute a truncated draft — common on
    repeated-token runs — so it is kept only as the fallback when no
    occurrence anywhere has a full window).  Returns [] when no n-gram down
    to ``min_ngram`` recurs."""
    L = len(seq)
    fallback: list[int] = []
    for m in range(min(max_ngram, L - 1), min_ngram - 1, -1):
        pat = tuple(seq[L - m :])
        # scan right-to-left, excluding the suffix occurrence itself
        for i in range(L - m - 1, -1, -1):
            if tuple(seq[i : i + m]) == pat:
                cont = list(seq[i + m : i + m + n])
                if len(cont) == n:
                    return cont
                if not fallback:
                    fallback = cont
    return fallback


class NGramDraft:
    """Prompt-lookup self-drafting (no second model, host-side only)."""

    name = "ngram"

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.trace_counts: dict = {}  # no device program at all
        self.obs = None

    def bind_obs(self, obs) -> None:
        """Emit per-round draft counters into the engine's metrics registry
        (the engine binds its bundle at construction)."""
        self.obs = obs

    def propose(self, asks: list[Ask]) -> dict:
        out = {
            rid: prompt_lookup(seq, n, self.max_ngram, self.min_ngram)
            for rid, seq, n in asks
        }
        if self.obs is not None:
            self.obs.on_draft_round(
                self.name, len(asks), sum(len(d) for d in out.values())
            )
        return out


class ModelDraft:
    """Model drafting: a small config runs greedy continuation on its own
    paged cache through one jitted mixed step (the target engine's slab
    contract in miniature).

    Proposal rounds are fully batched: every asking slot contributes rows
    to one (B, Wd) draft slab per call — catch-up chunks (tokens the target
    emitted that the drafter has not cached yet) and autoregressive draft
    rows ride the same step, so a round costs
    ``ceil(max_catchup / Wd) + gamma - 1`` device calls regardless of how
    many slots speculate.
    """

    def __init__(
        self,
        params,
        cfg,
        plan: ExecutionPlan,
        serve: ServePlan,
        *,
        target_vocab: Optional[int] = None,
    ):
        self.cfg, self.plan, self.serve = cfg, plan, serve
        self.params = params
        self.name = cfg.name
        self.target_vocab = target_vocab
        self.pools = init_paged_cache(cfg, plan, serve)
        self.alloc = BlockAllocator(serve.n_blocks)
        B = serve.decode_batch
        self.table = np.zeros((B, serve.max_blocks_per_seq), np.int32)
        self.blocks: list[list[int]] = [[] for _ in range(B)]
        self.toks: list[list[int]] = [[] for _ in range(B)]  # cached tokens
        self.rids: list[Optional[str]] = [None] * B
        self.trace_counts = {"draft_step": 0}
        self.obs = None
        self._step = make_mixed_step(
            cfg, plan, serve, fused=serve.fused_attention,
            spec_width=1, trace=self.trace_counts, trace_key="draft_step",
        )
        # the drafter never injects chaos into its own step; drafts are
        # proposals, so a genuinely non-finite drafter just drafts garbage
        # the target's verification rejects
        self._no_poison = jnp.zeros((B,), jnp.float32)

    # ----------------------------------------------------------- slot state
    def _slot_for(self, rid: str, active: set) -> Optional[int]:
        """Slot of ``rid``, assigning (or stealing an inactive slot) on
        first sight.  At most ``decode_batch`` rids can ask per round (they
        occupy target slots), so a steal always finds a victim."""
        if rid in self.rids:
            return self.rids.index(rid)
        for b, r in enumerate(self.rids):
            if r is None:
                self.rids[b] = rid
                return b
        for b, r in enumerate(self.rids):
            if r not in active:
                self._release(b)
                self.rids[b] = rid
                return b
        return None

    def _release(self, b: int) -> None:
        if self.blocks[b]:
            self.alloc.free(self.blocks[b])
        self.blocks[b] = []
        self.table[b] = 0
        self.toks[b] = []
        self.rids[b] = None

    def _ensure_blocks(self, b: int, n_tokens: int) -> bool:
        bs = self.serve.block_size
        need = -(-n_tokens // bs) - len(self.blocks[b])
        if need <= 0:
            return True
        got = self.alloc.alloc(need)
        if got is None:
            return False  # pool dry: stop drafting this slot, never evict
        start = len(self.blocks[b])
        self.blocks[b].extend(got)
        self.table[b, start : len(self.blocks[b])] = got
        return True

    # -------------------------------------------------------------- drafting
    def propose(self, asks: list[Ask]) -> dict:
        """{rid: [<= n draft tokens]} for each (rid, seq, n) ask.

        Self-healing sync: the drafter's cache is valid only up to the
        longest common prefix of what it cached and the sequence the target
        actually kept — rejected drafts, evictions and slot churn all
        surface as a shorter prefix and cost nothing but re-feeding."""
        if not asks:
            return {}
        n_dispatches = 0
        active = {rid for rid, _, _ in asks}
        W = self.serve.mixed_slab_width
        B = self.serve.decode_batch
        state = {}  # slot -> [pending rows to feed, drafts, want]
        for rid, seq, n in asks:
            b = self._slot_for(rid, active)
            if b is None:
                continue
            cached, p = self.toks[b], 0
            while p < min(len(cached), len(seq)) and cached[p] == seq[p]:
                p += 1
            # keep >= 1 token pending: after an eviction-recompute the cache
            # can cover ALL of seq (greedy is deterministic), but drafting
            # needs the argmax after seq's last token, so re-feed it
            p = min(p, len(seq) - 1)
            self.toks[b] = cached[:p]  # rollback = length only, blocks stay
            state[b] = [list(seq[p:]), [], int(n)]
        while True:
            feeding = {}  # slot -> rows packed this call
            tokens = np.zeros((B, W), np.int32)
            tables = np.zeros_like(self.table)
            lens = np.zeros((B,), np.int32)
            kinds = np.zeros((B,), np.int32)
            for b, (pending, drafts, want) in state.items():
                if len(drafts) >= want:
                    continue
                rows = pending[:W] if pending else [drafts[-1]]
                if not self._ensure_blocks(b, len(self.toks[b]) + len(rows)):
                    state[b][2] = len(drafts)  # pool dry: freeze this slot
                    continue
                feeding[b] = rows
                tokens[b, : len(rows)] = rows
                tables[b] = self.table[b]
                lens[b] = len(self.toks[b])
                kinds[b] = len(rows)
            if not feeding:
                break
            tok, _, _, self.pools = self._step(
                self.params, self.pools, tokens, tables, lens, kinds,
                self._no_poison,
            )
            n_dispatches += 1
            tok = np.asarray(tok)
            for b, rows in feeding.items():
                pending, drafts, want = state[b]
                self.toks[b].extend(rows)
                if pending:
                    del pending[: len(rows)]
                    if pending:
                        continue  # mid-catch-up argmax: discard
                t = int(tok[b])
                if self.target_vocab is not None and t >= self.target_vocab:
                    state[b][2] = len(drafts)  # unverifiable id: stop early
                    continue
                drafts.append(t)
        out = {
            self.rids[b]: drafts
            for b, (_, drafts, _) in state.items()
            if self.rids[b] is not None
        }
        if self.obs is not None:
            self.obs.on_draft_round(
                self.name, len(asks),
                sum(len(d) for d in out.values()),
                device_steps=n_dispatches,
            )
        return out

    def bind_obs(self, obs) -> None:
        """Emit per-round draft counters (asks, drafted tokens, device
        dispatches) into the engine's metrics registry."""
        self.obs = obs

    def summary(self) -> dict:
        return {
            "draft_model": self.name,
            "traces": dict(self.trace_counts),
            "serve_plan": self.serve.to_record(),
        }


def make_draft_source(
    name: Optional[str],
    target_cfg,
    target_serve: ServePlan,
    *,
    hw: HardwareSpec = DEFAULT_HARDWARE,
    params=None,
    seed: int = 0,
    reduced: bool = False,
):
    """Build the DraftSource named by a plan/CLI ``draft`` string.

    ``"none"``/None -> None, ``"ngram"`` -> :class:`NGramDraft`, anything
    else is a config name -> :class:`ModelDraft` with freshly initialized
    params (or ``params`` when the caller already has trained weights —
    passing the *target's* params turns it into a self-drafting oracle,
    useful as the acceptance upper bound in benchmarks)."""
    if name in (None, "", "none"):
        return None
    if name == "ngram":
        return NGramDraft()
    from repro.configs import get_config
    from repro.models.params import init_params

    cfg = get_config(name)
    if reduced:
        cfg = cfg.reduced()
    mesh = {"data": 1, "model": 1}
    plan = derive_plan(
        cfg, mesh, hw,
        batch=target_serve.decode_batch,
        seq_len=target_serve.prefill_chunk,
        training=False,
    )
    serve_d = derive_serve_plan(
        cfg, mesh, hw,
        max_seq_len=target_serve.max_seq_len,
        decode_batch=target_serve.decode_batch,
        block_size=target_serve.block_size,
        prefill_chunk=target_serve.prefill_chunk,
        mixed_slab_width=target_serve.mixed_slab_width,
        # same page precision as the target: a self-drafting oracle must
        # score the prefix through the same cache numerics or a near-tie
        # argmax can flip and quietly break the acceptance-1.0 bound
        kv_dtype=target_serve.kv_dtype,
    )
    if params is None:
        params = init_params(jax.random.PRNGKey(seed), cfg, plan, dtype=jnp.float32)
    return ModelDraft(
        params, cfg, plan, serve_d, target_vocab=target_cfg.vocab_size
    )
