"""Serving: the continuous-batching engine + the eager prefill/decode steps.

Two layers:

* ``make_prefill_step`` / ``make_decode_step`` / ``greedy_generate`` — the
  eager whole-batch path (dense cache, every request in lockstep).  The
  dry-run lowers these for the decode_32k / long_500k / prefill_32k cells
  and non-attention archs (RWKV/RG-LRU/enc-dec) serve through it.
* ``ServingEngine`` — continuous batching over the paged KV cache
  (``models/cache.init_paged_cache``) with at most TWO static-shape jitted
  device programs: the unified mixed prefill/decode step, plus (when the
  plan's ``rolled_steps`` > 1) the rolled decode loop that runs K decode
  iterations per dispatch.  Every slot owns
  ``mixed_slab_width`` query rows of a shared (B, W) token slab — a decode
  slot uses 1, a prefill slot up to W (its next prompt chunk), idle rows
  are dead and write to the trash block — so prefilling new requests rides
  in whatever rows the decode batch isn't using instead of stalling it for
  an iteration.  Attention runs through the fused Pallas paged-attention
  kernel (``kernels/paged_attention``), which consumes the block table
  directly; the dense gather fallback only remains for model-sharded
  meshes (GSPMD cannot partition the kernel yet) and as the oracle.
  Scheduling policy lives host-side in ``serve/scheduler.py``; the knobs
  (decode batch, block size, KV dtype, slab width, pages per VMEM tile)
  come from ``core/plan.derive_serve_plan``.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan, ServePlan, serve_feasible
from repro.models.cache import (
    cache_from_prefill,
    init_paged_cache,
    paged_copy_block,
)
from repro.models.transformer import forward, logits_fn
from repro.serve.scheduler import Request, Scheduler

PyTree = Any
Identity = lambda x, name=None: x


def make_prefill_step(cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity):
    def prefill_step(params, batch):
        x, pc, _ = forward(
            params, batch, cfg=cfg, plan=plan, collect_cache=True, shard=shard
        )
        logits = logits_fn(params, x[:, -1:], cfg)
        return logits, pc

    return prefill_step


def make_decode_step(cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity):
    def decode_step(params, token, cache):
        x, new_cache, _ = forward(
            params, {"tokens": token}, cfg=cfg, plan=plan, cache=cache, shard=shard
        )
        logits = logits_fn(params, x, cfg)
        return logits, new_cache

    return decode_step


def greedy_generate(
    params: PyTree,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    batch: dict,
    n_steps: int,
    cache_len: int,
    shard: Callable = Identity,
    cache_dtype=jnp.bfloat16,
):
    """Eager helper for the examples/tests (prefill then greedy decode).

    ``shard`` is a ``Shardings.constrain``-style callable; the default keeps
    single-device behaviour unchanged."""
    prefill = make_prefill_step(cfg, plan, shard=shard)
    decode = jax.jit(make_decode_step(cfg, plan, shard=shard))
    logits, pc = prefill(params, batch)
    cache = cache_from_prefill(cfg, plan, pc, cache_len, dtype=cache_dtype)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    for _ in range(n_steps - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------
def make_mixed_step(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    serve: ServePlan,
    *,
    fused: bool,
    shard: Callable = Identity,
    spec_width: int = 1,
    trace: Optional[dict] = None,
    trace_key: str = "step",
):
    """Build the ONE jitted unified mixed prefill/decode step.

    ``step(params, pools, tokens (B, W), tables (B, MB), lens (B,),
    kinds (B,))`` returns ``(tok, vtok, pools)``: ``tok[b]`` is the greedy
    token at slot b's last live row; ``vtok`` (B, spec_width) is the greedy
    argmax at each of the slot's leading rows — the verification targets of
    speculative decoding (row i scores the token that should follow the
    slot's i-th slab token).  With ``spec_width == 1`` no extra logits are
    computed and ``vtok`` is just ``tok[:, None]``.

    Shared by :class:`ServingEngine` and the model drafter
    (``serve/speculative.ModelDraft``) — the drafter is mechanically a
    second, smaller serving engine riding the same slab contract."""
    page_state = {
        "block_size": serve.block_size,
        "fused": bool(fused),
        "pages_per_tile": serve.pages_per_tile,
    }

    def step_fn(params, pools, tokens, tables, lens, kinds):
        if trace is not None:
            trace[trace_key] += 1
        cache = {"layers": pools["layers"], "t": lens}
        x, nc, _ = forward(
            params, {"tokens": tokens}, cfg=cfg, plan=plan, cache=cache,
            shard=shard,
            page_state={**page_state, "table": tables, "q_lens": kinds},
        )
        # per-slot greedy token at the last live row (kinds-1; row 0 for
        # decode slots, the final prompt token on a last prefill chunk)
        idx = jnp.maximum(kinds - 1, 0)
        xl = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        tok = jnp.argmax(logits_fn(params, xl, cfg)[:, -1], axis=-1)
        if spec_width > 1:
            # verification targets: the target model's own greedy choice
            # after every leading row (drafted rows ride rows 1..gamma)
            vtok = jnp.argmax(logits_fn(params, x[:, :spec_width], cfg), axis=-1)
        else:
            vtok = tok[:, None]
        return tok, vtok, {"layers": nc["layers"]}

    return jax.jit(step_fn, donate_argnums=(1,))


def make_rolled_step(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    serve: ServePlan,
    *,
    fused: bool,
    shard: Callable = Identity,
    trace: Optional[dict] = None,
    trace_key: str = "rolled_step",
):
    """Build the rolled on-device decode loop: K decode iterations, ONE
    dispatch (the rolled-compilation idiom — ``lax.while_loop`` keeps the
    loop body compiled once, not unrolled).

    ``rolled(params, pools, tok (B,), tables (B, MB), lens (B,),
    steps_left (B,), k_steps ())`` runs up to ``k_steps`` decode iterations
    entirely on device: each iteration forwards every slot's current token
    as a width-1 slab, samples the greedy next token, repacks it as the
    next iteration's input, writes its KV at the slot's position and
    advances the per-slot length.  The host only sees the finished span.

    ``steps_left[b]`` is slot b's own iteration budget (0 = idle slot):
    a slot whose budget runs out mid-span goes *dead* — its row writes to
    the trash block, its sampled token freezes — while the others keep
    decoding, and the loop's ``cond`` exits early once every slot is done
    (the on-device analogue of per-slot EOS/max-len exit; the scheduler's
    event horizon guarantees nothing *else* needs the host mid-span).

    Returns ``(out (B, K), lens (B,), pools)``; ``out[b, :steps_left[b]]``
    are slot b's tokens in order (later columns hold -1).  ``k_steps`` and
    ``steps_left`` are data, not shapes — one compile serves every horizon
    the scheduler picks, so ``trace_counts["rolled_step"]`` stays at 1.
    The static ``K = serve.rolled_steps`` only sizes the output buffer.
    """
    page_state = {
        "block_size": serve.block_size,
        "fused": bool(fused),
        "pages_per_tile": serve.pages_per_tile,
    }
    K = int(serve.rolled_steps)

    def rolled_fn(params, pools, tok, tables, lens, steps_left, k_steps):
        if trace is not None:
            trace[trace_key] += 1
        B = tok.shape[0]

        def cond(state):
            i = state[0]
            return jnp.logical_and(i < k_steps, jnp.any(steps_left > i))

        def body(state):
            i, tok, lens, layers, out = state
            live = steps_left > i
            kinds = live.astype(jnp.int32)
            x, nc, _ = forward(
                params, {"tokens": tok[:, None]}, cfg=cfg, plan=plan,
                cache={"layers": layers, "t": lens}, shard=shard,
                page_state={**page_state, "table": tables, "q_lens": kinds},
            )
            nxt = jnp.argmax(logits_fn(params, x, cfg)[:, -1], axis=-1)
            nxt = nxt.astype(jnp.int32)
            return (
                i + 1,
                jnp.where(live, nxt, tok),
                lens + kinds,
                nc["layers"],
                out.at[:, i].set(jnp.where(live, nxt, -1)),
            )

        _, _, lens, layers, out = jax.lax.while_loop(
            cond,
            body,
            (
                jnp.int32(0), tok, lens, pools["layers"],
                jnp.full((B, K), -1, jnp.int32),
            ),
        )
        return out, lens, {"layers": layers}

    return jax.jit(rolled_fn, donate_argnums=(1,))


def _by_tenant(finished: list) -> dict:
    groups: dict = {}
    for r in finished:
        groups.setdefault(r.tenant, []).append(r)
    return groups


def _percentiles(xs: list) -> Optional[dict]:
    """Latency summary of a sample list; None when there are no samples.

    Always carries ``n``: with one sample every percentile is that sample
    (numpy's interpolation degenerates), which is statistically meaningless
    without the count — callers (and humans reading BENCH json) need it to
    judge whether p99 is a tail or an artifact."""
    if not xs:
        return None
    arr = np.asarray(xs, np.float64)
    return {
        "n": int(arr.size),
        "mean": float(arr.mean()),
        "p50": float(np.percentile(arr, 50)),
        "p90": float(np.percentile(arr, 90)),
        "p99": float(np.percentile(arr, 99)),
    }


class ServingEngine:
    """Continuous-batching serving over the paged KV cache.

    Exactly ONE jitted device program with static shapes:

    * ``step(params, pools, token_slab (B, W), tables (B, MB), lens (B,),
      kinds (B,))`` — ``kinds[b]`` is slot b's live query-row count (0 idle,
      1 decode, n <= W prefill chunk); ``lens[b]`` the absolute position of
      its first row.  Each slot's KV rides its own block-table row, the
      fused paged-attention kernel masks per slot, and the returned greedy
      token is taken at the slot's last live row — a runner's next token,
      or the first output of a request whose final prompt chunk this was.

    The scheduler packs the slab per iteration: admit, grow, one mixed
    step.  ``trace_counts`` proves there is no per-request retracing — it
    stays bounded by {"step": 1, "rolled_step": 1} however the stream
    churns (the second program is the rolled decode loop, compiled at most
    once; absent when rolling is off), including with speculative decoding
    on (draft depth varies per slot per iteration, but only the *values*
    of ``kinds`` change, never a shape).

    When ``serve.rolled_steps > 1`` (and speculation is off) the engine
    also builds the rolled on-device decode loop: whenever the scheduler's
    event horizon says no host event falls due for K >= 2 iterations, one
    ``step()`` call dispatches K decode iterations as one device program
    (``make_rolled_step``) and advances the iteration clock by the span.
    Greedy outputs are byte-identical to the K=1 path by construction —
    the loop body is the same forward/argmax on the same paged state.

    ``draft`` (a ``serve/speculative`` DraftSource) + ``serve.spec_len`` > 0
    turn decode slots speculative: each running slot's drafted continuation
    rides its slab row as gamma+1 rows (mechanically a prefill chunk), the
    step scores every row, and the host keeps the longest draft prefix that
    matches the target's own greedy argmax — output tokens are identical to
    the non-speculative engine by construction, rollback is just the
    per-slot length vector.
    """

    def __init__(
        self,
        params: PyTree,
        cfg: ArchConfig,
        plan: ExecutionPlan,
        serve: ServePlan,
        *,
        shardings=None,
        fused: Optional[bool] = None,
        draft=None,
    ):
        ok, reason = serve_feasible(cfg)
        if not ok:
            raise ValueError(f"{cfg.name} cannot serve continuously: {reason}")
        self.cfg, self.plan, self.serve = cfg, plan, serve
        self.sched = Scheduler(serve)
        self.params = params
        self.pools = init_paged_cache(cfg, plan, serve)
        if shardings is not None:
            self.pools = jax.device_put(
                self.pools, shardings.cache_shardings(self.pools)
            )
        shard = shardings.constrain if shardings is not None else Identity
        if fused is None:
            # GSPMD cannot partition the Pallas call over a multi-device
            # mesh yet (ROADMAP: shard_map decode); those engines fall
            # back to the gather path, everything else runs the kernel
            # (a single-device Shardings is just an identity placement).
            fused = serve.fused_attention and (
                shardings is None or shardings.mesh.size == 1
            )
        self.fused = bool(fused)
        self.draft = draft
        self.spec_len = serve.spec_len if draft is not None else 0
        if self.spec_len >= serve.mixed_slab_width and serve.mixed_slab_width > 0:
            # plan clamps this already; belt-and-braces for hand-built plans
            self.spec_len = serve.mixed_slab_width - 1
        self.trace_counts = {"step": 0}
        self.iteration = 0
        self.stats = {
            "steps": 0, "prefill_tokens": 0, "generated_tokens": 0,
            "draft_rows": 0, "accepted_drafts": 0, "spec_slots": 0,
            "spec_generated": 0, "fork_copies": 0, "occupancy_sum": 0.0,
            "rolled_dispatches": 0, "rolled_steps": 0, "device_s": 0.0,
        }
        # copy-on-write fork: one jitted block copy, reused for every fork
        # (block ids are data, not shapes — compiles once, retraces never;
        # deliberately NOT counted in ``trace_counts``, which is the mixed
        # step's no-retrace invariant)
        self._copy = jax.jit(paged_copy_block, donate_argnums=(0,))
        # verify-row width follows the *engine's* draft-gated depth, not the
        # plan's: a speculative plan served without a draft source must not
        # pay spec_len+1 rows of discarded vocab logits every step
        self._step = make_mixed_step(
            cfg, plan, serve, fused=self.fused, shard=shard,
            spec_width=self.spec_len + 1 if self.spec_len > 0 else 1,
            trace=self.trace_counts,
        )
        # rolled on-device decode loop: K iterations per dispatch, used
        # whenever the scheduler's event horizon allows K >= 2.  Gated off
        # under speculation — draft accept/rollback is a host event every
        # iteration, so the horizon would always be 1 anyway.
        self.rolled_cap = int(serve.rolled_steps) if self.spec_len == 0 else 1
        if self.rolled_cap > 1:
            self.trace_counts["rolled_step"] = 0
            self._rolled = make_rolled_step(
                cfg, plan, serve, fused=self.fused, shard=shard,
                trace=self.trace_counts,
            )
        else:
            self._rolled = None

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    def reset_stats(self) -> None:
        """Zero the throughput counters, finished-request latency samples and
        the iteration clock (e.g. after a jit-warmup stream) — request
        arrivals are absolute iterations, so the clock must restart or a
        post-warmup 'staggered' stream arrives as a burst.  Compiled step
        caches and pool contents are left alone."""
        for k in self.stats:
            self.stats[k] = 0.0 if isinstance(self.stats[k], float) else 0
        self.stats.pop("wall_s", None)
        self.sched.finished = []
        self.iteration = 0

    def _propose_drafts(self) -> dict:
        """Ask the draft source for each running slot's continuation.

        Depth per slot degrades gracefully: never more than the plan's
        gamma, never past the slab width (gamma+1 rows must fit next to the
        slot's real token), and never drafting tokens the request has no
        budget left to emit — a slot with no headroom simply decodes
        plainly.  Returns {rid: [draft tokens]}."""
        cap = min(self.spec_len, self.serve.mixed_slab_width - 1)
        if self.draft is None or cap <= 0:
            return {}
        if self.sched._slo_pressure():
            # draft rows widen every runner's slab share; while an SLO'd
            # prefill is at risk that width belongs to prompt chunks
            return {}
        asks = []
        for req in self.sched.running():
            n = min(cap, req.max_new_tokens - len(req.out) - 1)
            if n > 0:
                asks.append((req.rid, req.prompt + req.out, n))
        if not asks:
            return {}
        props = self.draft.propose(asks)
        return {rid: list(d) for rid, d in props.items() if d}

    def step(self) -> None:
        """One engine iteration: admit -> fork copies -> draft -> grow ->
        one unified mixed step -> accept/rollback.

        When the rolled loop is enabled and the scheduler's event horizon
        allows K >= 2 decode iterations before the next host-required
        event, one call dispatches the rolled step instead — K iterations,
        one device program — and the iteration clock advances by the span.
        Fallback to the ordinary K=1 slab is transparent (same tokens, the
        differential harness asserts byte identity).

        Fork copies are applied immediately after admission, before anything
        can release blocks (growth/eviction run later in the iteration), so
        a copy's source block is still resident when the device reads it."""
        s = self.sched
        s.admit(self.iteration)
        for src, dst in s.drain_copies():
            self.pools = self._copy(
                self.pools, jnp.int32(src), jnp.int32(dst)
            )
            self.stats["fork_copies"] += 1
        if self._rolled is not None:
            k, steps = s.plan_rolled(self.iteration, self.rolled_cap)
            if k > 1:
                self._rolled_dispatch(k, steps)
                return
        drafts = self._propose_drafts()
        s._grow_for_decode({rid: len(d) for rid, d in drafts.items()})
        if s.busy():
            tokens, tables, lens, kinds = s._slab_view(
                self.serve.mixed_slab_width, drafts
            )
            traces_before = self.trace_counts["step"]
            t0 = time.perf_counter()
            sampled, vtok, self.pools = self._step(
                self.params, self.pools, tokens, tables, lens, kinds
            )
            sampled = np.asarray(sampled)  # block for an honest step time
            vtok = np.asarray(vtok)
            dt_ms = (time.perf_counter() - t0) * 1e3
            self.stats["device_s"] += dt_ms / 1e3
            if self.trace_counts["step"] == traces_before:
                # feed SLO chunk sizing a compile-free step-time estimate
                s.step_ms = (
                    dt_ms if s.step_ms is None else 0.8 * s.step_ms + 0.2 * dt_ms
                )
            c = s._slab_done(sampled, kinds, vtok, drafts)
            self.stats["steps"] += 1
            self.stats["prefill_tokens"] += c["prefill"]
            self.stats["generated_tokens"] += c["generated"]
            self.stats["draft_rows"] += c["draft_rows"]
            self.stats["accepted_drafts"] += c["accepted_drafts"]
            self.stats["spec_slots"] += c["spec_slots"]
            self.stats["spec_generated"] += c["spec_generated"]
            self.stats["occupancy_sum"] += (
                int((kinds > 0).sum()) / self.serve.decode_batch
            )
        self.iteration += 1

    def _rolled_dispatch(self, k: int, steps: np.ndarray) -> None:
        """Run one rolled span: up to ``k`` decode iterations in ONE device
        dispatch (per-slot budgets ``steps``, blocks already pre-reserved by
        ``plan_rolled``).  Host bookkeeping happens once for the whole span;
        the iteration clock and the per-step stats advance by the span
        length so rolled and K=1 runs stay comparable."""
        s = self.sched
        tok0 = np.zeros((self.serve.decode_batch,), np.int32)
        for b, req in enumerate(s.slots):
            if req is not None and steps[b] > 0:
                tok0[b] = req.out[-1]
        traces_before = self.trace_counts["rolled_step"]
        t0 = time.perf_counter()
        out, _, self.pools = self._rolled(
            self.params, self.pools, jnp.asarray(tok0),
            jnp.asarray(s.table), jnp.asarray(s.lens),
            jnp.asarray(steps, np.int32), jnp.int32(k),
        )
        out = np.asarray(out)  # block for an honest span time
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.stats["device_s"] += dt_ms / 1e3
        adv = int(steps.max())  # device iterations actually executed
        if self.trace_counts["rolled_step"] == traces_before and adv > 0:
            # per-iteration estimate feeds the same SLO chunk-sizing EMA
            per = dt_ms / adv
            s.step_ms = per if s.step_ms is None else 0.8 * s.step_ms + 0.2 * per
        c = s._rolled_done(out, steps)
        self.stats["steps"] += adv
        self.stats["rolled_dispatches"] += 1
        self.stats["rolled_steps"] += adv
        self.stats["generated_tokens"] += c["generated"]
        # same unit as the K=1 path: live-slot fraction summed per device
        # iteration (slot b is live for its first steps[b] iterations)
        self.stats["occupancy_sum"] += float(steps.sum()) / self.serve.decode_batch
        self.iteration += adv

    def run(self, requests=(), max_iterations: int = 100_000) -> dict:
        """Drive the stream to completion; returns {rid: generated tokens}."""
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while not self.sched.idle and self.iteration < max_iterations:
            self.step()
        self.stats["wall_s"] = time.perf_counter() - t0
        if not self.sched.idle:
            raise RuntimeError(f"stream not drained after {max_iterations} iters")
        return {r.rid: list(r.out) for r in self.sched.finished}

    def summary(self) -> dict:
        """Engine accounting.  ``tok_per_s`` counts *emitted output tokens*
        only — not slab rows: prompt rows are reported separately as
        ``prefill_tokens`` and rejected draft rows are never counted, so
        throughput cannot be inflated by prefill traffic or by speculation
        that verifies nothing.

        Safe at any sample count: a cold engine (0 steps, 0 finished)
        reports None for every rate/percentile instead of dividing by zero,
        a step-driven engine (no ``run()``, so no ``wall_s``) falls back to
        accumulated device time for ``tok_per_s``, and percentile dicts
        carry ``n`` so a 1-sample p99 is recognizable as such."""
        d = max(self.stats["steps"], 1)
        fin = self.sched.finished
        spec_on = self.draft is not None and self.spec_len > 0
        wall = self.stats.get("wall_s") or self.stats["device_s"] or None
        return {
            "iterations": self.iteration,
            "steps": self.stats["steps"],
            "prefill_tokens": self.stats["prefill_tokens"],
            "generated_tokens": self.stats["generated_tokens"],
            "mean_occupancy": self.stats["occupancy_sum"] / d,
            "evictions": self.sched.n_evictions,
            "traces": dict(self.trace_counts),
            "fused_attention": self.fused,
            "wall_s": self.stats.get("wall_s"),
            "device_s": self.stats["device_s"],
            "step_ms": self.sched.step_ms,
            "tok_per_s": (
                self.stats["generated_tokens"] / wall if wall else None
            ),
            "rolled": {
                "enabled": self._rolled is not None,
                "cap": self.rolled_cap,
                "dispatches": self.stats["rolled_dispatches"],
                "rolled_steps": self.stats["rolled_steps"],
                "mean_span": (
                    self.stats["rolled_steps"] / self.stats["rolled_dispatches"]
                    if self.stats["rolled_dispatches"]
                    else None
                ),
            },
            "latency_s": _percentiles(
                [r.t_done - r.t_admit for r in fin if r.t_done and r.t_admit]
            ),
            "ttft_s": _percentiles(
                [r.t_first - r.t_admit for r in fin if r.t_first and r.t_admit]
            ),
            "tenants": {
                t: {
                    "finished": len(rs),
                    "latency_s": _percentiles(
                        [r.t_done - r.t_admit for r in rs if r.t_done and r.t_admit]
                    ),
                    "ttft_s": _percentiles(
                        [r.t_first - r.t_admit for r in rs if r.t_first and r.t_admit]
                    ),
                }
                for t, rs in sorted(_by_tenant(fin).items())
            },
            "prefix": {
                "enabled": self.sched.index is not None,
                "admissions": self.sched.n_admissions,
                "hits": self.sched.n_prefix_hits,
                "hit_rate": (
                    self.sched.n_prefix_hits / self.sched.n_admissions
                    if self.sched.n_admissions
                    else None
                ),
                "tokens_saved": self.sched.prefix_tokens_saved,
                "forks": self.sched.n_forks,
                "fork_copies": self.stats["fork_copies"],
                "peak_blocks": self.sched.alloc.peak_in_use,
                "double_frees": self.sched.alloc.double_frees,
            },
            "spec": {
                "enabled": spec_on,
                "spec_len": self.spec_len,
                "draft": self.serve.draft,
                "draft_rows": self.stats["draft_rows"],
                "accepted_drafts": self.stats["accepted_drafts"],
                "acceptance_rate": (
                    self.stats["accepted_drafts"] / self.stats["draft_rows"]
                    if self.stats["draft_rows"]
                    else None
                ),
                # mean output tokens per speculating slot-step (> 1 means
                # speculation is beating plain decode on those steps)
                "tokens_per_spec_step": (
                    self.stats["spec_generated"] / self.stats["spec_slots"]
                    if self.stats["spec_slots"]
                    else None
                ),
                "draft_traces": (
                    dict(self.draft.trace_counts)
                    if spec_on and hasattr(self.draft, "trace_counts")
                    else None
                ),
            },
            "serve_plan": self.serve.to_record(),
        }
