"""Serving: prefill + batched decode.

``make_prefill_step`` runs the parallel forward with cache collection and
returns last-position logits (what a server samples from); ``make_decode_step``
advances one token for the whole batch against the cache.  The dry-run lowers
these for the decode_32k / long_500k / prefill_32k cells.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan
from repro.models.cache import cache_from_prefill
from repro.models.transformer import forward, logits_fn

PyTree = Any
Identity = lambda x, name=None: x


def make_prefill_step(cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity):
    def prefill_step(params, batch):
        x, pc, _ = forward(
            params, batch, cfg=cfg, plan=plan, collect_cache=True, shard=shard
        )
        logits = logits_fn(params, x[:, -1:], cfg)
        return logits, pc

    return prefill_step


def make_decode_step(cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity):
    def decode_step(params, token, cache):
        x, new_cache, _ = forward(
            params, {"tokens": token}, cfg=cfg, plan=plan, cache=cache, shard=shard
        )
        logits = logits_fn(params, x, cfg)
        return logits, new_cache

    return decode_step


def greedy_generate(
    params: PyTree,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    batch: dict,
    n_steps: int,
    cache_len: int,
    shard: Callable = Identity,
):
    """Eager helper for the examples/tests (prefill then greedy decode).

    ``shard`` is a ``Shardings.constrain``-style callable; the default keeps
    single-device behaviour unchanged."""
    prefill = make_prefill_step(cfg, plan, shard=shard)
    decode = jax.jit(make_decode_step(cfg, plan, shard=shard))
    logits, pc = prefill(params, batch)
    cache = cache_from_prefill(cfg, plan, pc, cache_len)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    for _ in range(n_steps - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
