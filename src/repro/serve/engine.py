"""Serving: the continuous-batching engine + the eager prefill/decode steps.

Two layers:

* ``make_prefill_step`` / ``make_decode_step`` / ``greedy_generate`` — the
  eager whole-batch path (dense cache, every request in lockstep).  The
  dry-run lowers these for the decode_32k / long_500k / prefill_32k cells
  and non-attention archs (RWKV/RG-LRU/enc-dec) serve through it.
* ``ServingEngine`` — continuous batching over the paged KV cache
  (``models/cache.init_paged_cache``) with at most TWO static-shape jitted
  device programs: the unified mixed prefill/decode step, plus (when the
  plan's ``rolled_steps`` > 1) the rolled decode loop that runs K decode
  iterations per dispatch.  Every slot owns
  ``mixed_slab_width`` query rows of a shared (B, W) token slab — a decode
  slot uses 1, a prefill slot up to W (its next prompt chunk), idle rows
  are dead and write to the trash block — so prefilling new requests rides
  in whatever rows the decode batch isn't using instead of stalling it for
  an iteration.  Attention runs through the fused Pallas paged-attention
  kernel (``kernels/paged_attention``), which consumes the block table
  directly; the dense gather fallback only remains for model-sharded
  meshes (GSPMD cannot partition the kernel yet) and as the oracle.
  Scheduling policy lives host-side in ``serve/scheduler.py``; the knobs
  (decode batch, block size, KV dtype, slab width, pages per VMEM tile)
  come from ``core/plan.derive_serve_plan``.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.hardware import DEFAULT_HARDWARE, HardwareSpec
from repro.core.plan import ExecutionPlan, ServePlan, serve_feasible
from repro.models.cache import (
    cache_from_prefill,
    init_paged_cache,
    paged_copy_block,
)
from repro.models.transformer import forward, logits_fn
from repro.obs import Observability
from repro.obs.calibrate import step_time_model
from repro.serve.faults import (
    LADDER,
    SALTS,
    FaultInjector,
    LadderExhausted,
    StallError,
    TransientDeviceError,
)
from repro.serve.scheduler import DONE, WAITING, Request, Scheduler

PyTree = Any
Identity = lambda x, name=None: x


def make_prefill_step(cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity):
    def prefill_step(params, batch):
        x, pc, _ = forward(
            params, batch, cfg=cfg, plan=plan, collect_cache=True, shard=shard
        )
        logits = logits_fn(params, x[:, -1:], cfg)
        return logits, pc

    return prefill_step


def make_decode_step(cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity):
    def decode_step(params, token, cache):
        x, new_cache, _ = forward(
            params, {"tokens": token}, cfg=cfg, plan=plan, cache=cache, shard=shard
        )
        logits = logits_fn(params, x, cfg)
        return logits, new_cache

    return decode_step


def greedy_generate(
    params: PyTree,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    batch: dict,
    n_steps: int,
    cache_len: int,
    shard: Callable = Identity,
    cache_dtype=jnp.bfloat16,
):
    """Eager helper for the examples/tests (prefill then greedy decode).

    ``shard`` is a ``Shardings.constrain``-style callable; the default keeps
    single-device behaviour unchanged."""
    prefill = make_prefill_step(cfg, plan, shard=shard)
    decode = jax.jit(make_decode_step(cfg, plan, shard=shard))
    logits, pc = prefill(params, batch)
    cache = cache_from_prefill(cfg, plan, pc, cache_len, dtype=cache_dtype)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    for _ in range(n_steps - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------
def make_mixed_step(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    serve: ServePlan,
    *,
    fused: bool,
    shard: Callable = Identity,
    spec_width: int = 1,
    trace: Optional[dict] = None,
    trace_key: str = "step",
):
    """Build the ONE jitted unified mixed prefill/decode step.

    ``step(params, pools, tokens (B, W), tables (B, MB), lens (B,),
    kinds (B,), poison (B,))`` returns ``(tok, vtok, finite, pools)``:
    ``tok[b]`` is the greedy token at slot b's last live row; ``vtok``
    (B, spec_width) is the greedy argmax at each of the slot's leading
    rows — the verification targets of speculative decoding (row i scores
    the token that should follow the slot's i-th slab token).  With
    ``spec_width == 1`` no extra logits are computed and ``vtok`` is just
    ``tok[:, None]``.

    ``finite[b]`` is the on-device health scalar — one bool per slot,
    false when any logit the slot sampled from is non-finite — and the
    host quarantines such slots instead of emitting garbage.  ``poison``
    is an additive per-slot logit offset the chaos harness uses to inject
    NaN (all-zero in production): it is *data*, not a shape, so the
    no-retrace contract is untouched.

    Shared by :class:`ServingEngine` and the model drafter
    (``serve/speculative.ModelDraft``) — the drafter is mechanically a
    second, smaller serving engine riding the same slab contract."""
    page_state = {
        "block_size": serve.block_size,
        "fused": bool(fused),
        "pages_per_tile": serve.pages_per_tile,
    }

    def step_fn(params, pools, tokens, tables, lens, kinds, poison):
        if trace is not None:
            trace[trace_key] += 1
        cache = {"layers": pools["layers"], "t": lens}
        x, nc, _ = forward(
            params, {"tokens": tokens}, cfg=cfg, plan=plan, cache=cache,
            shard=shard,
            page_state={**page_state, "table": tables, "q_lens": kinds},
        )
        # per-slot greedy token at the last live row (kinds-1; row 0 for
        # decode slots, the final prompt token on a last prefill chunk)
        idx = jnp.maximum(kinds - 1, 0)
        xl = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = logits_fn(params, xl, cfg)[:, -1] + poison[:, None]
        tok = jnp.argmax(logits, axis=-1)
        # one extra scalar per slot: a NaN/Inf anywhere in the sampled
        # logits poisons the sum, so isfinite(sum) is the whole check
        finite = jnp.isfinite(jnp.sum(logits, axis=-1))
        if spec_width > 1:
            # verification targets: the target model's own greedy choice
            # after every leading row (drafted rows ride rows 1..gamma)
            vlog = logits_fn(params, x[:, :spec_width], cfg)
            vlog = vlog + poison[:, None, None]
            vtok = jnp.argmax(vlog, axis=-1)
            finite = finite & jnp.isfinite(jnp.sum(vlog, axis=(-2, -1)))
        else:
            vtok = tok[:, None]
        return tok, vtok, finite, {"layers": nc["layers"]}

    return jax.jit(step_fn, donate_argnums=(1,))


def make_rolled_step(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    serve: ServePlan,
    *,
    fused: bool,
    shard: Callable = Identity,
    trace: Optional[dict] = None,
    trace_key: str = "rolled_step",
):
    """Build the rolled on-device decode loop: K decode iterations, ONE
    dispatch (the rolled-compilation idiom — ``lax.while_loop`` keeps the
    loop body compiled once, not unrolled).

    ``rolled(params, pools, tok (B,), tables (B, MB), lens (B,),
    steps_left (B,), k_steps ())`` runs up to ``k_steps`` decode iterations
    entirely on device: each iteration forwards every slot's current token
    as a width-1 slab, samples the greedy next token, repacks it as the
    next iteration's input, writes its KV at the slot's position and
    advances the per-slot length.  The host only sees the finished span.

    ``steps_left[b]`` is slot b's own iteration budget (0 = idle slot):
    a slot whose budget runs out mid-span goes *dead* — its row writes to
    the trash block, its sampled token freezes — while the others keep
    decoding, and the loop's ``cond`` exits early once every slot is done
    (the on-device analogue of per-slot EOS/max-len exit; the scheduler's
    event horizon guarantees nothing *else* needs the host mid-span).

    Returns ``(out (B, K), lens (B,), pools)``; ``out[b, :steps_left[b]]``
    are slot b's tokens in order (later columns hold -1).  ``k_steps`` and
    ``steps_left`` are data, not shapes — one compile serves every horizon
    the scheduler picks, so ``trace_counts["rolled_step"]`` stays at 1.
    The static ``K = serve.rolled_steps`` only sizes the output buffer.

    Fault tolerance inside the span: the loop carries a sticky per-slot
    *dead* flag set the first iteration the slot's logits go non-finite
    (``poison[b]`` lets the chaos harness force that at a chosen offset,
    -1 = never; it is data, not a shape).  A dead slot stops advancing —
    its length freezes at the last good position and its remaining output
    columns stay -1 — so the host sees exactly where to replay from while
    the healthy slots finish their spans.
    """
    page_state = {
        "block_size": serve.block_size,
        "fused": bool(fused),
        "pages_per_tile": serve.pages_per_tile,
    }
    K = int(serve.rolled_steps)

    def rolled_fn(params, pools, tok, tables, lens, steps_left, k_steps, poison):
        if trace is not None:
            trace[trace_key] += 1
        B = tok.shape[0]

        def cond(state):
            i, dead = state[0], state[5]
            return jnp.logical_and(i < k_steps, jnp.any((steps_left > i) & ~dead))

        def body(state):
            i, tok, lens, layers, out, dead = state
            live = (steps_left > i) & ~dead
            kinds = live.astype(jnp.int32)
            x, nc, _ = forward(
                params, {"tokens": tok[:, None]}, cfg=cfg, plan=plan,
                cache={"layers": layers, "t": lens}, shard=shard,
                page_state={**page_state, "table": tables, "q_lens": kinds},
            )
            logits = logits_fn(params, x, cfg)[:, -1]
            logits = logits + jnp.where(poison == i, jnp.float32(jnp.nan), 0.0)[
                :, None
            ]
            ok = jnp.isfinite(jnp.sum(logits, axis=-1))
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            good = live & ok
            return (
                i + 1,
                jnp.where(good, nxt, tok),
                lens + good.astype(jnp.int32),
                nc["layers"],
                out.at[:, i].set(jnp.where(good, nxt, -1)),
                dead | (live & ~ok),
            )

        _, _, lens, layers, out, _ = jax.lax.while_loop(
            cond,
            body,
            (
                jnp.int32(0), tok, lens, pools["layers"],
                jnp.full((B, K), -1, jnp.int32),
                jnp.zeros((B,), bool),
            ),
        )
        return out, lens, {"layers": layers}

    return jax.jit(rolled_fn, donate_argnums=(1,))


def _by_tenant(finished: list) -> dict:
    groups: dict = {}
    for r in finished:
        groups.setdefault(r.tenant, []).append(r)
    return groups


def _percentiles(xs: list) -> Optional[dict]:
    """Latency summary of a sample list; None when there are no samples.

    Always carries ``n``: with one sample every percentile is that sample
    (numpy's interpolation degenerates), which is statistically meaningless
    without the count — callers (and humans reading BENCH json) need it to
    judge whether p99 is a tail or an artifact."""
    if not xs:
        return None
    arr = np.asarray(xs, np.float64)
    return {
        "n": int(arr.size),
        "mean": float(arr.mean()),
        "p50": float(np.percentile(arr, 50)),
        "p90": float(np.percentile(arr, 90)),
        "p99": float(np.percentile(arr, 99)),
    }


class ServingEngine:
    """Continuous-batching serving over the paged KV cache.

    Exactly ONE jitted device program with static shapes:

    * ``step(params, pools, token_slab (B, W), tables (B, MB), lens (B,),
      kinds (B,))`` — ``kinds[b]`` is slot b's live query-row count (0 idle,
      1 decode, n <= W prefill chunk); ``lens[b]`` the absolute position of
      its first row.  Each slot's KV rides its own block-table row, the
      fused paged-attention kernel masks per slot, and the returned greedy
      token is taken at the slot's last live row — a runner's next token,
      or the first output of a request whose final prompt chunk this was.

    The scheduler packs the slab per iteration: admit, grow, one mixed
    step.  ``trace_counts`` proves there is no per-request retracing — it
    stays bounded by {"step": 1, "rolled_step": 1} however the stream
    churns (the second program is the rolled decode loop, compiled at most
    once; absent when rolling is off), including with speculative decoding
    on (draft depth varies per slot per iteration, but only the *values*
    of ``kinds`` change, never a shape).

    When ``serve.rolled_steps > 1`` (and speculation is off) the engine
    also builds the rolled on-device decode loop: whenever the scheduler's
    event horizon says no host event falls due for K >= 2 iterations, one
    ``step()`` call dispatches K decode iterations as one device program
    (``make_rolled_step``) and advances the iteration clock by the span.
    Greedy outputs are byte-identical to the K=1 path by construction —
    the loop body is the same forward/argmax on the same paged state.

    ``draft`` (a ``serve/speculative`` DraftSource) + ``serve.spec_len`` > 0
    turn decode slots speculative: each running slot's drafted continuation
    rides its slab row as gamma+1 rows (mechanically a prefill chunk), the
    step scores every row, and the host keeps the longest draft prefix that
    matches the target's own greedy argmax — output tokens are identical to
    the non-speculative engine by construction, rollback is just the
    per-slot length vector.
    """

    def __init__(
        self,
        params: PyTree,
        cfg: ArchConfig,
        plan: ExecutionPlan,
        serve: ServePlan,
        *,
        shardings=None,
        fused: Optional[bool] = None,
        draft=None,
        injector: Optional[FaultInjector] = None,
        obs: Optional[Observability] = None,
        hw: Optional[HardwareSpec] = None,
    ):
        ok, reason = serve_feasible(cfg)
        if not ok:
            raise ValueError(f"{cfg.name} cannot serve continuously: {reason}")
        self.cfg, self.plan, self.serve = cfg, plan, serve
        # observability bundle: metrics + drift meter always on (pure host
        # arithmetic), lifecycle tracing only when the caller enabled it on
        # the bundle — emission can never touch shapes or device work
        self.obs = obs if obs is not None else Observability()
        self.hw = hw if hw is not None else DEFAULT_HARDWARE
        self.sched = Scheduler(serve, obs=self.obs)
        self.params = params
        self.pools = init_paged_cache(cfg, plan, serve)
        if shardings is not None:
            self.pools = jax.device_put(
                self.pools, shardings.cache_shardings(self.pools)
            )
        shard = shardings.constrain if shardings is not None else Identity
        self._shard = shard
        self.injector = injector
        if injector is not None:
            injector.bind(self.obs)
        if fused is None:
            # GSPMD cannot partition the Pallas call over a multi-device
            # mesh yet (ROADMAP: shard_map decode); those engines fall
            # back to the gather path, everything else runs the kernel
            # (a single-device Shardings is just an identity placement).
            fused = serve.fused_attention and (
                shardings is None or shardings.mesh.size == 1
            )
        self.fused = bool(fused)
        # planner drift meter: freeze the predict_point roofline constants
        # for this (arch, plan, device, TP degree) so pricing a dispatch is
        # O(1); every calibrated dispatch records predicted vs measured
        # (summary()["calibration"], docs/OBSERVABILITY.md §Drift meter)
        mesh_model = (
            dict(shardings.mesh.shape).get("model", 1)
            if shardings is not None
            else 1
        )
        self._cost = step_time_model(
            cfg, serve, self.hw, mesh_model=mesh_model, fused=self.fused
        )
        self.draft = draft
        if draft is not None and hasattr(draft, "bind_obs"):
            draft.bind_obs(self.obs)
        self.spec_len = serve.spec_len if draft is not None else 0
        if self.spec_len >= serve.mixed_slab_width and serve.mixed_slab_width > 0:
            # plan clamps this already; belt-and-braces for hand-built plans
            self.spec_len = serve.mixed_slab_width - 1
        self.trace_counts = {"step": 0}
        self.iteration = 0
        self.stats = {
            "steps": 0, "prefill_tokens": 0, "generated_tokens": 0,
            "draft_rows": 0, "accepted_drafts": 0, "spec_slots": 0,
            "spec_generated": 0, "fork_copies": 0, "occupancy_sum": 0.0,
            "rolled_dispatches": 0, "rolled_steps": 0, "device_s": 0.0,
            "retries": 0, "transient_faults": 0, "rung_escalations": 0,
            "rung_recoveries": 0, "quarantines": 0, "poisoned": 0,
            "expired": 0, "shed": 0, "cancelled": 0, "injected_nans": 0,
        }
        # degradation ladder: 0 = rolled-K spans, 1 = K=1 mixed step,
        # 2 = eager gather fallback (built lazily).  Transient-fault
        # retries that exhaust retry_limit step DOWN; ladder_recovery
        # consecutive healthy dispatches step back UP.
        self.rung = 0
        self._healthy = 0
        self._gather = None
        self._last_fault: Optional[dict] = None
        self._no_poison = np.zeros((serve.decode_batch,), np.float32)
        # copy-on-write fork: one jitted block copy, reused for every fork
        # (block ids are data, not shapes — compiles once, retraces never;
        # deliberately NOT counted in ``trace_counts``, which is the mixed
        # step's no-retrace invariant)
        self._copy = jax.jit(paged_copy_block, donate_argnums=(0,))
        # verify-row width follows the *engine's* draft-gated depth, not the
        # plan's: a speculative plan served without a draft source must not
        # pay spec_len+1 rows of discarded vocab logits every step
        self._step = make_mixed_step(
            cfg, plan, serve, fused=self.fused, shard=shard,
            spec_width=self.spec_len + 1 if self.spec_len > 0 else 1,
            trace=self.trace_counts,
        )
        # rolled on-device decode loop: K iterations per dispatch, used
        # whenever the scheduler's event horizon allows K >= 2.  Gated off
        # under speculation — draft accept/rollback is a host event every
        # iteration, so the horizon would always be 1 anyway.
        self.rolled_cap = int(serve.rolled_steps) if self.spec_len == 0 else 1
        if self.rolled_cap > 1:
            self.trace_counts["rolled_step"] = 0
            self._rolled = make_rolled_step(
                cfg, plan, serve, fused=self.fused, shard=shard,
                trace=self.trace_counts,
            )
        else:
            self._rolled = None
        # engines without a rolled loop live on the "mixed" rung; recovery
        # never climbs above the floor
        self._rung_floor = 0 if self._rolled is not None else 1
        self.rung = self._rung_floor
        self.obs.m_rung.set(self.rung)

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        """Queue a request, validating the construction fields up front —
        a malformed request must fail here with the field named, not steps
        later inside the scheduler as an opaque shape error."""
        if not req.prompt:
            raise ValueError(f"request {req.rid}: prompt must not be empty")
        if req.max_new_tokens <= 0:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be positive,"
                f" got {req.max_new_tokens}"
            )
        if len(req.prompt) >= self.serve.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)}"
                f" >= max_seq_len {self.serve.max_seq_len}"
            )
        vocab = self.cfg.vocab_size
        for i, t in enumerate(req.prompt):
            if not 0 <= int(t) < vocab:
                raise ValueError(
                    f"request {req.rid}: prompt token id {int(t)} at"
                    f" position {i} outside vocab range [0, {vocab})"
                )
        if req.deadline_ms is None:
            req.deadline_ms = self.serve.deadline_ms
        self.sched.submit(req)

    def cancel(self, rid: str) -> bool:
        """Cancel a queued or in-flight request by id, releasing its
        blocks/radix refs; returns False when no live request matches."""
        for r in list(self.sched.waiting) + [
            s for s in self.sched.slots if s is not None
        ]:
            if r.rid == rid:
                self.sched.cancel(r, status="cancelled")
                self.stats["cancelled"] += 1
                return True
        return False

    def reset_stats(self) -> None:
        """Zero the throughput counters, finished-request latency samples and
        the iteration clock (e.g. after a jit-warmup stream) — request
        arrivals are absolute iterations, so the clock must restart or a
        post-warmup 'staggered' stream arrives as a burst.  Compiled step
        caches and pool contents are left alone."""
        for k in self.stats:
            self.stats[k] = 0.0 if isinstance(self.stats[k], float) else 0
        self.stats.pop("wall_s", None)
        self.sched.finished = []
        self.sched.shed = []
        self.iteration = 0

    def _propose_drafts(self) -> dict:
        """Ask the draft source for each running slot's continuation.

        Depth per slot degrades gracefully: never more than the plan's
        gamma, never past the slab width (gamma+1 rows must fit next to the
        slot's real token), and never drafting tokens the request has no
        budget left to emit — a slot with no headroom simply decodes
        plainly.  Returns {rid: [draft tokens]}."""
        cap = min(self.spec_len, self.serve.mixed_slab_width - 1)
        if self.draft is None or cap <= 0:
            return {}
        if self.sched._slo_pressure():
            # draft rows widen every runner's slab share; while an SLO'd
            # prefill is at risk that width belongs to prompt chunks
            return {}
        asks = []
        for req in self.sched.running():
            n = min(cap, req.max_new_tokens - len(req.out) - 1)
            if n > 0:
                asks.append((req.rid, req.prompt + req.out, n))
        if not asks:
            return {}
        props = self.draft.propose(asks)
        return {rid: list(d) for rid, d in props.items() if d}

    # ------------------------------------------------- degradation ladder
    def _gather_step(self):
        """Rung-2 fallback: the same mixed step compiled without the fused
        Pallas kernel (dense gather attention).  Built lazily — production
        never pays its compile unless the ladder actually reaches it — and
        traced under its own key so the no-retrace contract stays auditable
        (``fallback_step`` <= 1)."""
        if self._gather is None:
            self.trace_counts.setdefault("fallback_step", 0)
            self._gather = make_mixed_step(
                self.cfg, self.plan, self.serve, fused=False, shard=self._shard,
                spec_width=self.spec_len + 1 if self.spec_len > 0 else 1,
                trace=self.trace_counts, trace_key="fallback_step",
            )
        return self._gather

    def _escalate(self) -> bool:
        """Step one rung down the ladder; False when already at the bottom."""
        if self.rung >= len(LADDER) - 1:
            return False
        self.rung += 1
        self._healthy = 0
        self.stats["rung_escalations"] += 1
        self.obs.on_rung("down", self.rung, LADDER[self.rung])
        return True

    def _note_healthy(self) -> None:
        self._healthy += 1
        if self.rung > self._rung_floor and self._healthy >= self.serve.ladder_recovery:
            self.rung -= 1
            self._healthy = 0
            self.stats["rung_recoveries"] += 1
            self.obs.on_rung("up", self.rung, LADDER[self.rung])

    def _note_fault(self, kind: str, detail: str) -> None:
        self.stats["transient_faults"] += 1
        self._healthy = 0
        self._last_fault = {
            "kind": kind, "iteration": self.iteration, "detail": detail,
        }

    def _backoff(self, attempt: int) -> None:
        base = self.serve.retry_backoff_s
        if base > 0:
            time.sleep(min(base * 2 ** (attempt - 1), 0.25))

    def _retry_transients(self) -> bool:
        """Absorb transient dispatch faults for the upcoming device call
        with bounded, exponentially backed-off retries.  True = cleared to
        dispatch; False = this rung's retry budget is spent (the caller
        escalates).  The check runs *before* the jitted call, so a failed
        attempt never consumes the donated pool buffers.  (A production
        backend would map real device errors — e.g. XlaRuntimeError — to
        :class:`TransientDeviceError` at the same boundary.)"""
        attempts = 0
        while True:
            if self.injector is None:
                return True
            try:
                self.injector.check_dispatch(self.iteration)
                return True
            except TransientDeviceError as e:
                self._note_fault("transient", str(e))
                attempts += 1
                if attempts > self.serve.retry_limit:
                    return False
                self.stats["retries"] += 1
                self.obs.on_retry()
                self._backoff(attempts)

    def _poison_vec(self, kinds: np.ndarray) -> np.ndarray:
        """Per-slot additive logit poison for this iteration (chaos NaN
        injection), masked to occupied slots; all-zero without an injector."""
        if self.injector is None:
            return self._no_poison
        mask = self.injector.nan_mask(self.iteration, self.serve.decode_batch)
        mask = mask & (np.asarray(kinds) > 0)
        n = int(mask.sum())
        if n == 0:
            return self._no_poison
        self.injector.counts["nan"] += n
        self.stats["injected_nans"] += n
        # NaN injections are emitted here, not by the injector: only the
        # engine knows how many poisons actually landed on occupied slots
        self.obs.on_fault(
            "nan", seed=self.injector.seed, salt=SALTS["nan"],
            iteration=self.iteration, slots=n,
        )
        v = np.zeros((self.serve.decode_batch,), np.float32)
        v[mask] = np.nan
        return v

    def step(self) -> None:
        """One engine iteration: pressure/expiry -> admit -> shed -> fork
        copies -> draft -> grow -> one unified mixed step -> accept/rollback.

        When the rolled loop is enabled, the ladder sits at its top rung,
        and the scheduler's event horizon allows K >= 2 decode iterations
        before the next host-required event, one call dispatches the rolled
        step instead — K iterations, one device program — and the iteration
        clock advances by the span.  Fallback to the ordinary K=1 slab is
        transparent (same tokens, the differential harness asserts byte
        identity).

        Fork copies are applied immediately after admission, before anything
        can release blocks (growth/eviction run later in the iteration), so
        a copy's source block is still resident when the device reads it."""
        s = self.sched
        if self.injector is not None:
            self.injector.pressure(self.iteration, s.alloc)
        self.stats["expired"] += s.expire_deadlines(time.perf_counter())
        s.admit(self.iteration)
        self.stats["shed"] += s.shed_starved(self.iteration)
        for src, dst in s.drain_copies():
            self.pools = self._copy(
                self.pools, jnp.int32(src), jnp.int32(dst)
            )
            self.stats["fork_copies"] += 1
        if self._rolled is not None and self.rung == 0:
            k, steps = s.plan_rolled(self.iteration, self.rolled_cap)
            if k > 1 and self._rolled_dispatch(k, steps):
                return
            # retry exhaustion escalated mid-plan: fall through to the K=1
            # path this iteration (pre-reserved span blocks stay with their
            # slots; decode just proceeds one step at a time)
        drafts = self._propose_drafts()
        s._grow_for_decode({rid: len(d) for rid, d in drafts.items()})
        if s.busy():
            tokens, tables, lens, kinds = s._slab_view(
                self.serve.mixed_slab_width, drafts
            )
            # slab composition + roofline price, snapshotted pre-dispatch
            # (``_slab_done`` mutates slot states)
            ka = np.asarray(kinds)
            composition = {
                "idle": int((ka == 0).sum()),
                "decode": int((ka == 1).sum()),
                "prefill": len(s.prefilling()),
                "spec": sum(1 for r in s.running() if r.rid in drafts),
            }
            rows = int(ka.sum())
            phase = "prefill" if composition["prefill"] else "decode"
            predicted_s = self._cost.predict_s(
                rows, float(np.asarray(lens).sum()) + rows
            )
            while not self._retry_transients():
                if not self._escalate():
                    raise LadderExhausted(
                        "transient dispatch faults exhausted the retry ladder",
                        self.health(),
                    )
            step_fn = self._step if self.rung < 2 else self._gather_step()
            poison = self._poison_vec(kinds)
            trace_key = "step" if self.rung < 2 else "fallback_step"
            traces_before = self.trace_counts[trace_key]
            t0 = time.perf_counter()
            if self.injector is not None:
                sp = self.injector.spike_s(self.iteration)
                if sp:
                    time.sleep(sp)
            sampled, vtok, finite, self.pools = step_fn(
                self.params, self.pools, tokens, tables, lens, kinds,
                jnp.asarray(poison),
            )
            sampled = np.asarray(sampled)  # block for an honest step time
            vtok = np.asarray(vtok)
            finite = np.asarray(finite)
            t1 = time.perf_counter()
            dt_ms = (t1 - t0) * 1e3
            self.stats["device_s"] += dt_ms / 1e3
            self._note_healthy()
            calibrated = self.trace_counts[trace_key] == traces_before
            if calibrated:
                # feed SLO chunk sizing a compile-free step-time estimate
                s.step_ms = (
                    dt_ms if s.step_ms is None else 0.8 * s.step_ms + 0.2 * dt_ms
                )
            self.obs.on_dispatch(
                trace_key, phase, t0, t1, rows=rows,
                composition=composition, rung=LADDER[self.rung],
                predicted_s=predicted_s, calibrated=calibrated,
            )
            c = s._slab_done(
                sampled, kinds, vtok, drafts, finite=finite, span=(t0, t1)
            )
            self.obs.on_step_counts(c)
            self.obs.set_pool(
                available=s.alloc.available, in_use=s.alloc.in_use,
                active=len(s._active()), queued=len(s.waiting),
            )
            self.stats["steps"] += 1
            self.stats["prefill_tokens"] += c["prefill"]
            self.stats["generated_tokens"] += c["generated"]
            self.stats["draft_rows"] += c["draft_rows"]
            self.stats["accepted_drafts"] += c["accepted_drafts"]
            self.stats["spec_slots"] += c["spec_slots"]
            self.stats["spec_generated"] += c["spec_generated"]
            self.stats["quarantines"] += c["quarantined"]
            self.stats["poisoned"] += c["poisoned"]
            self.stats["occupancy_sum"] += (
                int((kinds > 0).sum()) / self.serve.decode_batch
            )
        self.iteration += 1

    def _rolled_dispatch(self, k: int, steps: np.ndarray) -> bool:
        """Run one rolled span: up to ``k`` decode iterations in ONE device
        dispatch (per-slot budgets ``steps``, blocks already pre-reserved by
        ``plan_rolled``).  Host bookkeeping happens once for the whole span;
        the iteration clock and the per-step stats advance by the span
        length so rolled and K=1 runs stay comparable.

        Returns False when transient faults spent this rung's retry budget
        — the ladder escalated to the K=1 mixed rung and the caller falls
        through to it for this iteration."""
        s = self.sched
        if not self._retry_transients():
            self._escalate()
            return False
        tok0 = np.zeros((self.serve.decode_batch,), np.int32)
        for b, req in enumerate(s.slots):
            if req is not None and steps[b] > 0:
                tok0[b] = req.out[-1]
        poison = np.full((self.serve.decode_batch,), -1, np.int32)
        if self.injector is not None:
            poison = self.injector.nan_in_span(
                self.iteration, k, self.serve.decode_batch
            )
            poison[np.asarray(steps) <= 0] = -1
            n = int((poison >= 0).sum())
            self.injector.counts["nan"] += n
            self.stats["injected_nans"] += n
            if n:
                self.obs.on_fault(
                    "nan", seed=self.injector.seed, salt=SALTS["nan"],
                    iteration=self.iteration, slots=n, span_k=int(k),
                )
        traces_before = self.trace_counts["rolled_step"]
        t0 = time.perf_counter()
        if self.injector is not None:
            sp = self.injector.spike_s(self.iteration)
            if sp:
                time.sleep(sp)
        out, _, self.pools = self._rolled(
            self.params, self.pools, jnp.asarray(tok0),
            jnp.asarray(s.table), jnp.asarray(s.lens),
            jnp.asarray(steps, np.int32), jnp.int32(k),
            jnp.asarray(poison),
        )
        out = np.asarray(out)  # block for an honest span time
        t1 = time.perf_counter()
        dt_ms = (t1 - t0) * 1e3
        self.stats["device_s"] += dt_ms / 1e3
        self._note_healthy()
        adv = int(steps.max())  # device iterations actually executed
        calibrated = self.trace_counts["rolled_step"] == traces_before and adv > 0
        if calibrated:
            # per-iteration estimate feeds the same SLO chunk-sizing EMA
            per = dt_ms / adv
            s.step_ms = per if s.step_ms is None else 0.8 * s.step_ms + 0.2 * per
        live = int((np.asarray(steps) > 0).sum())
        self.obs.on_dispatch(
            "rolled_step", "decode", t0, t1, rows=live,
            composition={
                "idle": self.serve.decode_batch - live, "decode": live,
            },
            rung=LADDER[self.rung], k=adv,
            predicted_s=self._cost.predict_s(
                live, float(np.asarray(s.lens).sum()), k=max(adv, 1)
            ),
            calibrated=calibrated,
        )
        c = s._rolled_done(out, steps, span=(t0, t1))
        self.obs.on_step_counts(c)
        self.obs.set_pool(
            available=s.alloc.available, in_use=s.alloc.in_use,
            active=len(s._active()), queued=len(s.waiting),
        )
        self.stats["steps"] += adv
        self.stats["rolled_dispatches"] += 1
        self.stats["rolled_steps"] += adv
        self.stats["generated_tokens"] += c["generated"]
        self.stats["quarantines"] += c["quarantined"]
        self.stats["poisoned"] += c["poisoned"]
        # same unit as the K=1 path: live-slot fraction summed per device
        # iteration (slot b is live for its first steps[b] iterations)
        self.stats["occupancy_sum"] += float(steps.sum()) / self.serve.decode_batch
        self.iteration += adv
        return True

    def run(self, requests=(), max_iterations: int = 100_000) -> dict:
        """Drive the stream to completion; returns {rid: generated tokens}
        for requests that *finished* (shed/expired/cancelled requests are
        reported through ``summary()``, not here).

        A stall detector watches for iterations that make no progress at
        all — no token emitted, no prompt row consumed, no admission, no
        completion or shedding — while work is actually pending (a future
        arrival idling the engine is not a stall).  ``stall_limit``
        consecutive dead iterations raise :class:`StallError` carrying
        ``health()`` instead of silently burning ``max_iterations``."""
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        sig = None
        stalled = 0
        while not self.sched.idle and self.iteration < max_iterations:
            self.step()
            s = self.sched
            cur = (
                self.stats["generated_tokens"],
                self.stats["prefill_tokens"],
                s.n_admissions,
                len(s.finished) + len(s.shed),
            )
            idle_by_design = all(x is None for x in s.slots) and all(
                r.arrival > self.iteration for r in s.waiting
            )
            if cur != sig or idle_by_design:
                sig, stalled = cur, 0
            else:
                stalled += 1
                if stalled >= self.serve.stall_limit:
                    raise StallError(
                        f"engine made no progress for {stalled} consecutive"
                        f" iterations (iteration {self.iteration})",
                        self.health(),
                    )
        self.stats["wall_s"] = time.perf_counter() - t0
        if not self.sched.idle:
            raise RuntimeError(f"stream not drained after {max_iterations} iters")
        return {r.rid: list(r.out) for r in self.sched.finished}

    # -------------------------------------------------- health + snapshot
    def health(self) -> dict:
        """Instantaneous engine health — cheap enough to poll every step,
        attached to StallError/LadderExhausted diagnostics."""
        s = self.sched
        arrived = sum(1 for r in s.waiting if r.arrival <= self.iteration)
        return {
            "iteration": self.iteration,
            "rung": self.rung,
            "rung_name": LADDER[self.rung],
            "healthy_streak": self._healthy,
            "retries": self.stats["retries"],
            "transient_faults": self.stats["transient_faults"],
            "quarantines": self.stats["quarantines"],
            "shed": self.stats["shed"],
            "expired": self.stats["expired"],
            "cancelled": self.stats["cancelled"],
            "poisoned": self.stats["poisoned"],
            "pool": {
                "n_blocks": self.serve.n_blocks,
                "available": s.alloc.available,
                "in_use": s.alloc.in_use,
            },
            "slots": {
                "running": len(s.running()),
                "prefilling": len(s.prefilling()),
                "free": s.slots.count(None),
            },
            "queue": {"arrived": arrived, "future": len(s.waiting) - arrived},
            "step_ms": s.step_ms,
            "last_fault": self._last_fault,
        }

    @staticmethod
    def _freeze(req: Request) -> dict:
        return {
            "rid": req.rid,
            "prompt": [int(t) for t in req.prompt],
            "max_new_tokens": int(req.max_new_tokens),
            "arrival": int(req.arrival),
            "tenant": req.tenant,
            "priority": int(req.priority),
            "slo_ttft_ms": req.slo_ttft_ms,
            "tag": req.tag,
            "deadline_ms": req.deadline_ms,
            "out": [int(t) for t in req.out],
            "status": req.status,
            "quarantines": int(req.quarantines),
        }

    @staticmethod
    def _thaw(rec: dict) -> Request:
        req = Request(
            rid=rec["rid"],
            prompt=list(rec["prompt"]),
            max_new_tokens=rec["max_new_tokens"],
            arrival=rec["arrival"],
            tenant=rec["tenant"],
            priority=rec["priority"],
            slo_ttft_ms=rec["slo_ttft_ms"],
            tag=rec["tag"],
            deadline_ms=rec["deadline_ms"],
        )
        req.out = list(rec["out"])
        req.status = rec["status"]
        req.quarantines = rec["quarantines"]
        return req

    def snapshot(self) -> dict:
        """JSON-serializable logical engine state: scheduler queues, request
        progress and the accounting counters — deliberately NO KV tensors
        and no allocator layout.  KV pages are a pure function of each
        request's token prefix (the PR 6 invariant), so ``restore`` on a
        fresh engine re-prefills every in-flight request's prompt + emitted
        tokens and the continuation is byte-identical; serialized state
        stays kilobytes however large the pools are.  Call between steps
        (the engine never yields mid-step)."""
        s = self.sched
        return {
            "version": 1,
            "arch": self.cfg.name,
            "iteration": self.iteration,
            "serve_plan": self.serve.to_record(),
            "active": [
                self._freeze(r) for r in s.slots if r is not None
            ],
            "waiting": [self._freeze(r) for r in s.waiting],
            "finished": [self._freeze(r) for r in s.finished],
            "shed": [self._freeze(r) for r in s.shed],
            "stats": {
                k: v for k, v in self.stats.items() if isinstance(v, (int, float))
            },
            "sched_counters": {
                "n_admissions": s.n_admissions,
                "n_evictions": s.n_evictions,
                "n_forks": s.n_forks,
                "n_prefix_hits": s.n_prefix_hits,
                "prefix_tokens_saved": s.prefix_tokens_saved,
            },
        }

    def restore(self, snap: dict) -> None:
        """Resume a snapshot on this (fresh, idle) engine.

        Finished/shed requests come back purely as records (accounting
        continuity); in-flight and queued requests re-enter the waiting
        queue with their emitted tokens preserved — admission prefills
        ``prompt + out[:-1]`` and the slot continues decoding from its
        last token, byte-identically (KV pages are a pure function of the
        prefix).  Deadline clocks restart at restore time: wall-clock
        timestamps from the crashed process are meaningless here."""
        if snap.get("arch") != self.cfg.name:
            raise ValueError(
                f"snapshot arch {snap.get('arch')!r} != engine {self.cfg.name!r}"
            )
        s = self.sched
        if not s.idle or s.finished or s.shed:
            raise RuntimeError("restore() needs a fresh idle engine")
        self.iteration = int(snap["iteration"])
        for rec in snap["finished"]:
            req = self._thaw(rec)
            req.state = DONE
            s.finished.append(req)
        for rec in snap["shed"]:
            req = self._thaw(rec)
            req.state = DONE
            s.shed.append(req)
        for rec in snap["active"] + snap["waiting"]:
            req = self._thaw(rec)
            req.state = WAITING
            self.submit(req)
        for k, v in snap.get("stats", {}).items():
            if k in self.stats:
                self.stats[k] = v
        sc = snap.get("sched_counters", {})
        s.n_admissions = sc.get("n_admissions", 0)
        s.n_evictions = sc.get("n_evictions", 0)
        s.n_forks = sc.get("n_forks", 0)
        s.n_prefix_hits = sc.get("n_prefix_hits", 0)
        s.prefix_tokens_saved = sc.get("prefix_tokens_saved", 0)

    def summary(self) -> dict:
        """Engine accounting.  ``tok_per_s`` counts *emitted output tokens*
        only — not slab rows: prompt rows are reported separately as
        ``prefill_tokens`` and rejected draft rows are never counted, so
        throughput cannot be inflated by prefill traffic or by speculation
        that verifies nothing.

        Safe at any sample count: a cold engine (0 steps, 0 finished)
        reports None for every rate/percentile instead of dividing by zero,
        a step-driven engine (no ``run()``, so no ``wall_s``) falls back to
        accumulated device time for ``tok_per_s``, and percentile dicts
        carry ``n`` so a 1-sample p99 is recognizable as such."""
        d = max(self.stats["steps"], 1)
        fin = self.sched.finished
        shed = self.sched.shed
        spec_on = self.draft is not None and self.spec_len > 0
        wall = self.stats.get("wall_s") or self.stats["device_s"] or None

        def _dispositions(rs: list) -> dict:
            out = {"shed": 0, "expired": 0, "cancelled": 0, "poisoned": 0}
            for r in rs:
                if r.status in out:
                    out[r.status] += 1
            return out

        tenants = {}
        for t, rs in sorted(_by_tenant(fin + shed).items()):
            t_fin = [r for r in rs if r.status == "ok"]
            tenants[t] = {
                "finished": len(t_fin),
                "latency_s": _percentiles(
                    [r.t_done - r.t_admit for r in t_fin if r.t_done and r.t_admit]
                ),
                "ttft_s": _percentiles(
                    [r.t_first - r.t_admit for r in t_fin if r.t_first and r.t_admit]
                ),
                **_dispositions(rs),
            }
        return {
            "iterations": self.iteration,
            "steps": self.stats["steps"],
            "prefill_tokens": self.stats["prefill_tokens"],
            "generated_tokens": self.stats["generated_tokens"],
            "mean_occupancy": self.stats["occupancy_sum"] / d,
            "evictions": self.sched.n_evictions,
            "traces": dict(self.trace_counts),
            "fused_attention": self.fused,
            "wall_s": self.stats.get("wall_s"),
            "device_s": self.stats["device_s"],
            "step_ms": self.sched.step_ms,
            "tok_per_s": (
                self.stats["generated_tokens"] / wall if wall else None
            ),
            "rolled": {
                "enabled": self._rolled is not None,
                "cap": self.rolled_cap,
                "dispatches": self.stats["rolled_dispatches"],
                "rolled_steps": self.stats["rolled_steps"],
                "mean_span": (
                    self.stats["rolled_steps"] / self.stats["rolled_dispatches"]
                    if self.stats["rolled_dispatches"]
                    else None
                ),
            },
            "latency_s": _percentiles(
                [r.t_done - r.t_admit for r in fin if r.t_done and r.t_admit]
            ),
            "ttft_s": _percentiles(
                [r.t_first - r.t_admit for r in fin if r.t_first and r.t_admit]
            ),
            "tenants": tenants,
            "requests": {
                "finished": len(fin),
                **_dispositions(shed),
            },
            "faults": {
                "rung": self.rung,
                "rung_name": LADDER[self.rung],
                "retries": self.stats["retries"],
                "transient_faults": self.stats["transient_faults"],
                "rung_escalations": self.stats["rung_escalations"],
                "rung_recoveries": self.stats["rung_recoveries"],
                "quarantines": self.stats["quarantines"],
                "injected_nans": self.stats["injected_nans"],
                "shed": self.stats["shed"],
                "expired": self.stats["expired"],
                "cancelled": self.stats["cancelled"],
                "poisoned": self.stats["poisoned"],
                "injector": (
                    self.injector.summary() if self.injector is not None else None
                ),
            },
            "prefix": {
                "enabled": self.sched.index is not None,
                "admissions": self.sched.n_admissions,
                "hits": self.sched.n_prefix_hits,
                "hit_rate": (
                    self.sched.n_prefix_hits / self.sched.n_admissions
                    if self.sched.n_admissions
                    else None
                ),
                "tokens_saved": self.sched.prefix_tokens_saved,
                "forks": self.sched.n_forks,
                "fork_copies": self.stats["fork_copies"],
                "peak_blocks": self.sched.alloc.peak_in_use,
                "double_frees": self.sched.alloc.double_frees,
            },
            # planner drift meter (obs/calibrate.py): measured dispatch wall
            # time vs the predict_point roofline, per phase — the signal
            # that explains whether modeled orderings survive this backend
            "calibration": self.obs.drift.report(),
            "spec": {
                "enabled": spec_on,
                "spec_len": self.spec_len,
                "draft": self.serve.draft,
                "draft_rows": self.stats["draft_rows"],
                "accepted_drafts": self.stats["accepted_drafts"],
                "acceptance_rate": (
                    self.stats["accepted_drafts"] / self.stats["draft_rows"]
                    if self.stats["draft_rows"]
                    else None
                ),
                # mean output tokens per speculating slot-step (> 1 means
                # speculation is beating plain decode on those steps)
                "tokens_per_spec_step": (
                    self.stats["spec_generated"] / self.stats["spec_slots"]
                    if self.stats["spec_slots"]
                    else None
                ),
                "draft_traces": (
                    dict(self.draft.trace_counts)
                    if spec_on and hasattr(self.draft, "trace_counts")
                    else None
                ),
            },
            "serve_plan": self.serve.to_record(),
        }
