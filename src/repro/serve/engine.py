"""Serving: the continuous-batching engine + the eager prefill/decode steps.

Two layers:

* ``make_prefill_step`` / ``make_decode_step`` / ``greedy_generate`` — the
  eager whole-batch path (dense cache, every request in lockstep).  The
  dry-run lowers these for the decode_32k / long_500k / prefill_32k cells
  and non-attention archs (RWKV/RG-LRU/enc-dec) serve through it.
* ``ServingEngine`` — continuous batching over the paged KV cache
  (``models/cache.init_paged_cache``): a fixed grid of decode slots, chunked
  prefill interleaved with batched decode, both as static-shape jitted steps
  so request churn never retraces.  Scheduling policy lives host-side in
  ``serve/scheduler.py``; the knobs (decode batch, block size, KV dtype,
  prefill chunk) come from ``core/plan.derive_serve_plan``.
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan, ServePlan, serve_feasible
from repro.models.cache import cache_from_prefill, init_paged_cache
from repro.models.transformer import forward, logits_fn
from repro.serve.scheduler import Request, Scheduler

PyTree = Any
Identity = lambda x, name=None: x


def make_prefill_step(cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity):
    def prefill_step(params, batch):
        x, pc, _ = forward(
            params, batch, cfg=cfg, plan=plan, collect_cache=True, shard=shard
        )
        logits = logits_fn(params, x[:, -1:], cfg)
        return logits, pc

    return prefill_step


def make_decode_step(cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity):
    def decode_step(params, token, cache):
        x, new_cache, _ = forward(
            params, {"tokens": token}, cfg=cfg, plan=plan, cache=cache, shard=shard
        )
        logits = logits_fn(params, x, cfg)
        return logits, new_cache

    return decode_step


def greedy_generate(
    params: PyTree,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    batch: dict,
    n_steps: int,
    cache_len: int,
    shard: Callable = Identity,
    cache_dtype=jnp.bfloat16,
):
    """Eager helper for the examples/tests (prefill then greedy decode).

    ``shard`` is a ``Shardings.constrain``-style callable; the default keeps
    single-device behaviour unchanged."""
    prefill = make_prefill_step(cfg, plan, shard=shard)
    decode = jax.jit(make_decode_step(cfg, plan, shard=shard))
    logits, pc = prefill(params, batch)
    cache = cache_from_prefill(cfg, plan, pc, cache_len, dtype=cache_dtype)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    for _ in range(n_steps - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------
class ServingEngine:
    """Continuous-batching serving over the paged KV cache.

    Exactly two jitted device programs, both with static shapes:

    * ``prefill_step(params, pools, tokens (1,C), table_row, start, last_idx)``
      — one prompt chunk for one slot, writing its pages into the shared
      pool; on the final chunk ``last_idx`` points at the true last prompt
      token and the returned greedy token is the request's first output.
    * ``decode_step(params, pools, tokens (B,1), tables, lens)`` — one token
      for every slot at once; idle slots point at the trash block and cost
      one lane of the batch (their output is discarded).

    The scheduler interleaves them per iteration: admit, (maybe) one prefill
    chunk, one batched decode.  ``trace_counts`` proves there is no
    per-request retracing — it stays at 1/1 however the stream churns.
    """

    def __init__(
        self,
        params: PyTree,
        cfg: ArchConfig,
        plan: ExecutionPlan,
        serve: ServePlan,
        *,
        shardings=None,
    ):
        ok, reason = serve_feasible(cfg)
        if not ok:
            raise ValueError(f"{cfg.name} cannot serve continuously: {reason}")
        self.cfg, self.plan, self.serve = cfg, plan, serve
        self.sched = Scheduler(serve)
        self.params = params
        self.pools = init_paged_cache(cfg, plan, serve)
        if shardings is not None:
            self.pools = jax.device_put(
                self.pools, shardings.cache_shardings(self.pools)
            )
        shard = shardings.constrain if shardings is not None else Identity
        self.trace_counts = {"prefill": 0, "decode": 0}
        self.iteration = 0
        self.stats = {
            "prefill_steps": 0, "decode_steps": 0, "prefill_tokens": 0,
            "decode_tokens": 0, "occupancy_sum": 0.0,
        }
        bs = serve.block_size

        def prefill_fn(params, pools, tokens, table_row, start, last_idx):
            self.trace_counts["prefill"] += 1
            cache = {"layers": pools["layers"], "t": start}
            x, nc, _ = forward(
                params, {"tokens": tokens}, cfg=cfg, plan=plan, cache=cache,
                shard=shard, page_state={"table": table_row, "block_size": bs},
            )
            xl = lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
            tok = jnp.argmax(logits_fn(params, xl, cfg)[:, -1], axis=-1)
            return tok, {"layers": nc["layers"]}

        def decode_fn(params, pools, tokens, tables, lens):
            self.trace_counts["decode"] += 1
            cache = {"layers": pools["layers"], "t": lens}
            x, nc, _ = forward(
                params, {"tokens": tokens}, cfg=cfg, plan=plan, cache=cache,
                shard=shard, page_state={"table": tables, "block_size": bs},
            )
            tok = jnp.argmax(logits_fn(params, x, cfg)[:, -1], axis=-1)
            return tok, {"layers": nc["layers"]}

        self._prefill = jax.jit(prefill_fn, donate_argnums=(1,))
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    def reset_stats(self) -> None:
        """Zero the throughput counters and the iteration clock (e.g. after a
        jit-warmup stream) — request arrivals are absolute iterations, so the
        clock must restart or a post-warmup 'staggered' stream arrives as a
        burst.  Compiled step caches and pool contents are left alone."""
        for k in self.stats:
            self.stats[k] = 0.0 if isinstance(self.stats[k], float) else 0
        self.stats.pop("wall_s", None)
        self.iteration = 0

    def step(self) -> None:
        """One engine iteration: admit -> one prefill chunk -> batched decode."""
        s = self.sched
        s.admit(self.iteration)
        req = s.next_prefill()
        if req is not None:
            c = self.serve.prefill_chunk
            chunk = req.prompt[req.pos : req.pos + c]
            tokens = np.zeros((1, c), np.int32)
            tokens[0, : len(chunk)] = chunk
            is_last = req.pos + c >= len(req.prompt)
            last_idx = np.int32(len(req.prompt) - 1 - req.pos if is_last else 0)
            tok, self.pools = self._prefill(
                self.params, self.pools, tokens,
                s.table[req.slot : req.slot + 1],
                np.asarray([req.pos], np.int32), last_idx,
            )
            s.prefill_chunk_done(req, int(tok[0]) if is_last else None)
            self.stats["prefill_steps"] += 1
            self.stats["prefill_tokens"] += len(chunk)
        if s.running():
            s.grow_for_decode()
            n_active = len(s.running())
            tables, lens = s.decode_view()
            sampled, self.pools = self._decode(
                self.params, self.pools, s.last_tokens()[:, None], tables, lens,
            )
            s.decode_done(np.asarray(sampled))
            self.stats["decode_steps"] += 1
            self.stats["decode_tokens"] += n_active
            self.stats["occupancy_sum"] += n_active / self.serve.decode_batch
        self.iteration += 1

    def run(self, requests=(), max_iterations: int = 100_000) -> dict:
        """Drive the stream to completion; returns {rid: generated tokens}."""
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while not self.sched.idle and self.iteration < max_iterations:
            self.step()
        self.stats["wall_s"] = time.perf_counter() - t0
        if not self.sched.idle:
            raise RuntimeError(f"stream not drained after {max_iterations} iters")
        return {r.rid: list(r.out) for r in self.sched.finished}

    def summary(self) -> dict:
        d = max(self.stats["decode_steps"], 1)
        return {
            "iterations": self.iteration,
            "prefill_steps": self.stats["prefill_steps"],
            "decode_steps": self.stats["decode_steps"],
            "prefill_tokens": self.stats["prefill_tokens"],
            "decode_tokens": self.stats["decode_tokens"],
            "mean_occupancy": self.stats["occupancy_sum"] / d,
            "evictions": self.sched.n_evictions,
            "traces": dict(self.trace_counts),
            "wall_s": self.stats.get("wall_s"),
            "tok_per_s": (
                (self.stats["prefill_tokens"] + self.stats["decode_tokens"])
                / self.stats["wall_s"]
                if self.stats.get("wall_s")
                else None
            ),
            "serve_plan": self.serve.to_record(),
        }
