"""Deterministic chaos injection + fault-tolerance error types.

The serving engine consults a :class:`FaultInjector` at its dispatch
boundaries to simulate the failure modes real accelerators produce:

* **transient dispatch errors** — raised *before* the jitted call (so
  donated pool buffers are never consumed by a failed dispatch), in
  bursts of configurable length, driving the engine's retry ladder;
* **non-finite logits** — a per-slot additive poison vector folded into
  the jitted step as *data* (no shape change, no retrace), caught by the
  on-device finiteness check and answered with quarantine + replay;
* **block-pool pressure** — the injector temporarily holds blocks from
  the allocator's free list, squeezing admission and rolled-horizon
  planning;
* **step-time spikes** — real sleeps inside the timed dispatch window,
  stressing the SLO/EMA feedback loop.

Every decision is a pure function of ``(seed, kind, iteration)`` via a
freshly seeded generator per draw, so a schedule replays identically
regardless of how many times or in what order the engine asks — the
property the chaos-parity tests lean on.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

# Degradation-ladder rungs, in escalation order.
LADDER = ("rolled", "mixed", "gather")

# RNG stream salts, one per fault kind — the (seed, salt, iteration) triple
# is the whole determinism key, and trace events carry it verbatim so a
# chaos run is visually replayable from its Chrome trace alone.
SALTS = {"transient": 1, "nan": 2, "pressure": 3, "spike": 4}


class TransientDeviceError(RuntimeError):
    """Simulated (or mapped) transient device failure for one dispatch."""


class StallError(RuntimeError):
    """The engine made no progress for ``stall_limit`` consecutive steps."""

    def __init__(self, message: str, health: Optional[dict] = None):
        super().__init__(message)
        self.health = dict(health or {})


class LadderExhausted(RuntimeError):
    """Transient faults persisted through every rung of the retry ladder."""

    def __init__(self, message: str, health: Optional[dict] = None):
        super().__init__(message)
        self.health = dict(health or {})


class FaultInjector:
    """Seeded, deterministic fault schedule consulted by the engine.

    Rates are per-engine-iteration probabilities in ``[0, 1]``. With
    ``horizon`` set, no *new* fault fires at or after that iteration
    (in-flight bursts and held pool blocks still unwind), which
    guarantees chaotic streams eventually drain.
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        transient_rate: float = 0.0,
        transient_burst: int = 1,
        nan_rate: float = 0.0,
        pressure_rate: float = 0.0,
        pressure_frac: float = 0.5,
        pressure_steps: int = 4,
        spike_rate: float = 0.0,
        spike_ms: float = 5.0,
        horizon: Optional[int] = None,
    ):
        if transient_burst < 1:
            raise ValueError("transient_burst: must be >= 1")
        for name, rate in (
            ("transient_rate", transient_rate),
            ("nan_rate", nan_rate),
            ("pressure_rate", pressure_rate),
            ("spike_rate", spike_rate),
        ):
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name}: must be in [0, 1], got {rate}")
        self.seed = int(seed)
        self.transient_rate = float(transient_rate)
        self.transient_burst = int(transient_burst)
        self.nan_rate = float(nan_rate)
        self.pressure_rate = float(pressure_rate)
        self.pressure_frac = float(pressure_frac)
        self.pressure_steps = int(pressure_steps)
        self.spike_rate = float(spike_rate)
        self.spike_ms = float(spike_ms)
        self.horizon = horizon
        self.counts = {"transient": 0, "nan": 0, "squeeze": 0, "spike": 0}
        self._burst_left = 0
        self._tripped: set[int] = set()  # iterations whose transient already drew
        self.held: list[int] = []  # blocks squeezed out of the pool
        self._release_at = -1
        self.obs = None  # Observability bundle (engine binds its own)

    # -- observability ---------------------------------------------------
    def bind(self, obs) -> None:
        """Attach an ``repro.obs.Observability`` bundle: every injection
        fires a metric + a trace instant tagged (seed, salt, iteration).
        The engine binds its bundle at construction; NaN poisons are the
        one kind the *engine* emits instead (only it knows how many landed
        on occupied slots)."""
        self.obs = obs

    def _emit(self, kind: str, iteration: int, **extra) -> None:
        if self.obs is not None:
            self.obs.on_fault(
                kind, seed=self.seed, salt=SALTS[kind],
                iteration=int(iteration), **extra,
            )

    # -- determinism core ------------------------------------------------
    def _rng(self, iteration: int, salt: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, salt, int(iteration)])

    def _armed(self, iteration: int) -> bool:
        return self.horizon is None or iteration < self.horizon

    # -- transient dispatch failures -------------------------------------
    def check_dispatch(self, iteration: int) -> None:
        """Raise :class:`TransientDeviceError` if this attempt should fail.

        Each scheduled fault fails ``transient_burst`` consecutive
        attempts (the initial one plus retries), so burst length vs the
        plan's ``retry_limit`` decides whether the engine recovers
        in-rung or escalates down the ladder.
        """
        if self._burst_left > 0:
            self._burst_left -= 1
            self.counts["transient"] += 1
            self._emit("transient", iteration, burst_left=self._burst_left)
            raise TransientDeviceError(f"injected transient fault @ iter {iteration}")
        if self.transient_rate <= 0 or not self._armed(iteration):
            return
        if iteration in self._tripped:
            return
        if self._rng(iteration, 1).random() < self.transient_rate:
            self._tripped.add(iteration)
            self._burst_left = self.transient_burst - 1
            self.counts["transient"] += 1
            self._emit("transient", iteration, burst_left=self._burst_left)
            raise TransientDeviceError(f"injected transient fault @ iter {iteration}")

    # -- NaN poison ------------------------------------------------------
    def nan_mask(self, iteration: int, n_slots: int) -> np.ndarray:
        """Boolean (B,) mask of slots whose logits are poisoned this iteration."""
        if self.nan_rate <= 0 or not self._armed(iteration):
            return np.zeros(n_slots, dtype=bool)
        return self._rng(iteration, 2).random(n_slots) < self.nan_rate

    def nan_in_span(self, iteration: int, k: int, n_slots: int) -> np.ndarray:
        """Per-slot offset in ``[0, k)`` of the first poisoned rolled
        iteration, or -1 — the same schedule :meth:`nan_mask` would
        produce if the span ran as K separate dispatches."""
        off = np.full(n_slots, -1, dtype=np.int32)
        for t in range(int(k)):
            mask = self.nan_mask(iteration + t, n_slots) & (off < 0)
            off[mask] = t
        return off

    # -- block-pool pressure ---------------------------------------------
    def pressure(self, iteration: int, alloc) -> None:
        """Maybe squeeze the free list; release a previous squeeze when due."""
        if self.held and iteration >= self._release_at:
            alloc.free(self.held)
            self.held = []
        if self.held or self.pressure_rate <= 0 or not self._armed(iteration):
            return
        if self._rng(iteration, 3).random() < self.pressure_rate:
            n = int(self.pressure_frac * alloc.available)
            if n > 0:
                got = alloc.alloc(n)
                if got:
                    self.held = got
                    self._release_at = iteration + self.pressure_steps
                    self.counts["squeeze"] += 1
                    self._emit(
                        "pressure", iteration,
                        blocks_held=len(got), release_at=self._release_at,
                    )

    def release(self, alloc) -> None:
        """Hand back any squeezed blocks (e.g. after the stream drained)."""
        if self.held:
            alloc.free(self.held)
            self.held = []

    # -- step-time spikes ------------------------------------------------
    def spike_s(self, iteration: int) -> float:
        """Seconds of artificial device latency for this dispatch (0 = none)."""
        if self.spike_rate <= 0 or not self._armed(iteration):
            return 0.0
        if self._rng(iteration, 4).random() < self.spike_rate:
            self.counts["spike"] += 1
            self._emit("spike", iteration, ms=self.spike_ms)
            return self.spike_ms / 1e3
        return 0.0

    # -- reporting -------------------------------------------------------
    def to_record(self) -> dict:
        return {
            "seed": self.seed,
            "transient_rate": self.transient_rate,
            "transient_burst": self.transient_burst,
            "nan_rate": self.nan_rate,
            "pressure_rate": self.pressure_rate,
            "pressure_frac": self.pressure_frac,
            "pressure_steps": self.pressure_steps,
            "spike_rate": self.spike_rate,
            "spike_ms": self.spike_ms,
            "horizon": self.horizon,
        }

    def summary(self) -> dict:
        return {"spec": self.to_record(), "injected": dict(self.counts)}
