"""Multi-tenant trace workloads: heterogeneous request mixes for the engine.

The serving analogue of CAT's workload-shaped customization: one engine
carries a *family* of traffic classes the way the paper's one framework
carries a family of accelerators.  A trace is composed from named workload
classes (lumos-style kernel-mix composition applied to requests):

* ``chat``      — medium prompts, long generations, interactive priority
                  and a TTFT target (a human is watching the first token).
* ``summarize`` — long-document prompts, short generations, batch priority
                  (throughput work; no TTFT target).
* ``classify``  — short prompts, tiny generations, the strictest TTFT
                  target and top priority (an online feature extractor).

Every tenant gets its own shared system prompt prepended to each of its
requests — the realistic N-users-one-prefix shape that prefix sharing
(``serve/prefix.py``) turns into one set of pages and one prefill.  Tokens
are synthetic (uniform over the vocab) but *content-correlated within a
tenant*, which is all the radix index cares about.

``make_trace`` builds the request list, ``parse_mix`` reads CLI specs like
``"chat:4,summarize:2,classify:2"``, and ``per_class_report`` turns the
engine's finished requests into per-class p50/p90/p99 latency/TTFT tables
(the PR 5 stats, grouped by ``Request.tag``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.serve.scheduler import Request


@dataclasses.dataclass(frozen=True)
class WorkloadClass:
    """One traffic class: prompt/generation shape + scheduling descriptors."""

    name: str
    prompt_len: tuple[int, int]  # uniform [lo, hi) user-turn tokens
    gen: tuple[int, int]  # uniform [lo, hi) max_new_tokens
    priority: int = 0
    slo_ttft_ms: Optional[float] = None

    def scaled(self, max_tokens: int) -> "WorkloadClass":
        """Shrink prompt/gen ranges to fit a small-context test plan while
        keeping the classes' relative shapes (long-doc stays the longest)."""
        lo, hi = self.prompt_len
        glo, ghi = self.gen
        f = min(1.0, max_tokens / 1024.0)
        cap = lambda x: max(2, int(x * f))
        return dataclasses.replace(
            self,
            prompt_len=(cap(lo), max(cap(hi), cap(lo) + 1)),
            gen=(max(1, int(glo * f)), max(2, int(ghi * f))),
        )


WORKLOADS: dict[str, WorkloadClass] = {
    "chat": WorkloadClass(
        "chat", prompt_len=(48, 160), gen=(32, 128), priority=1, slo_ttft_ms=200.0
    ),
    "summarize": WorkloadClass(
        "summarize", prompt_len=(512, 1024), gen=(16, 48), priority=0
    ),
    "classify": WorkloadClass(
        "classify", prompt_len=(8, 32), gen=(1, 4), priority=2, slo_ttft_ms=50.0
    ),
}


def parse_mix(spec: str) -> dict[str, int]:
    """``"chat:4,summarize:2"`` -> {"chat": 4, "summarize": 2}."""
    mix: dict[str, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, count = part.partition(":")
        if name not in WORKLOADS:
            raise ValueError(
                f"unknown workload class {name!r}; have {sorted(WORKLOADS)}"
            )
        mix[name] = mix.get(name, 0) + (int(count) if count else 1)
    if not mix:
        raise ValueError(f"empty workload mix spec {spec!r}")
    return mix


def make_trace(
    cfg,
    mix: dict[str, int],
    *,
    tenants: int = 2,
    system_prompt_len: int = 32,
    stagger: int = 1,
    seed: int = 0,
    max_tokens: Optional[int] = None,
) -> list[Request]:
    """Compose a multi-tenant request trace from a workload-class mix.

    ``mix`` maps class name -> request count; requests round-robin over
    ``tenants`` tenants, each of which owns one ``system_prompt_len``-token
    system prompt shared verbatim by all its requests.  Arrivals interleave
    the classes (sorted by a per-request jittered clock) and stagger by
    ``stagger`` engine iterations; ``max_tokens`` (usually the plan's
    ``max_seq_len``) shrinks the class shapes to fit small test contexts.
    """
    rng = np.random.default_rng(seed)
    sys_prompts = [
        [int(t) for t in rng.integers(0, cfg.vocab_size, system_prompt_len)]
        for _ in range(tenants)
    ]
    raw = []
    for name in sorted(mix):
        wc = WORKLOADS[name]
        if max_tokens is not None:
            wc = wc.scaled(max(max_tokens - system_prompt_len, 8))
        for i in range(mix[name]):
            n = int(rng.integers(*wc.prompt_len))
            gen = int(rng.integers(wc.gen[0], wc.gen[1] + 1))
            tenant = len(raw) % tenants
            raw.append(
                (
                    float(rng.uniform()),  # arrival jitter: interleave classes
                    Request(
                        rid=f"{name[:4]}-t{tenant}-{i:03d}",
                        prompt=sys_prompts[tenant]
                        + [int(t) for t in rng.integers(0, cfg.vocab_size, n)],
                        max_new_tokens=gen,
                        tenant=f"tenant{tenant}",
                        priority=wc.priority,
                        slo_ttft_ms=wc.slo_ttft_ms,
                        tag=name,
                    ),
                )
            )
    raw.sort(key=lambda t: (t[0], t[1].rid))
    reqs = []
    for i, (_, r) in enumerate(raw):
        reqs.append(dataclasses.replace(r, arrival=i * stagger))
    return reqs


def _percentiles(xs: list) -> Optional[dict]:
    if not xs:
        return None
    arr = np.asarray(xs, np.float64)
    return {
        "mean": float(arr.mean()),
        "p50": float(np.percentile(arr, 50)),
        "p90": float(np.percentile(arr, 90)),
        "p99": float(np.percentile(arr, 99)),
    }


def per_class_report(finished: list[Request]) -> dict:
    """Per-workload-class latency table from the engine's finished requests.

    {class tag: {count, tokens, latency_s: {p50, p90, p99}, ttft_s: ...}} —
    the per-class view the multi-tenant benchmark publishes next to the
    engine's aggregate summary."""
    by_tag: dict[str, list[Request]] = {}
    for r in finished:
        by_tag.setdefault(r.tag or "untagged", []).append(r)
    return {
        tag: {
            "count": len(rs),
            "tokens": sum(len(r.out) for r in rs),
            "latency_s": _percentiles(
                [r.t_done - r.t_admit for r in rs if r.t_done and r.t_admit]
            ),
            "ttft_s": _percentiles(
                [r.t_first - r.t_admit for r in rs if r.t_first and r.t_admit]
            ),
        }
        for tag, rs in sorted(by_tag.items())
    }
