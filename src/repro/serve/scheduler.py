"""Continuous-batching scheduler (host side).

The device side of serving is ONE static-shape jitted step (the unified
mixed prefill/decode slab — ``serve/engine.py``); everything dynamic lives
here as plain Python: request admission, block accounting, slab packing,
completion and eviction.  The scheduler owns the block tables and per-slot
lengths as numpy arrays and hands device copies to each step, so the step
never retraces on request churn.

Policy (Orca-style iteration-level scheduling, token-level batching):

* **admission** — priority classes with per-tenant fair shares: among the
  arrived waiting requests the scheduler repeatedly admits the one with the
  highest ``priority``, breaking ties toward the tenant holding the fewest
  slots (work-conserving max-min fairness), then by arrival.  A request is
  admitted when a decode slot is free and the pool can cover the un-shared
  part of its prompt.
* **prefix sharing** — a :class:`~repro.serve.prefix.PrefixIndex` maps the
  prompt to already-resident block runs: fully-matched blocks are shared by
  refcount (no pages, no prefill), a mid-block divergence forks the block
  (copy-on-write: the engine copies the pages before its next step — see
  ``pending_copies``), and only the divergent tail is prefilled.
* **slab packing** — every slot contributes rows to one (B, W) token slab
  per iteration: a mid-prefill slot fills its row with its next prompt
  chunk, a running slot carries its last sampled token in row 0, and idle
  rows are dead (``kinds`` = live rows per slot; dead rows write to the
  trash block).  Chunk sizing is SLO-aware: a prefill with a TTFT target
  always takes the full width, and when one of them is at risk (measured
  step time says the target needs more than half the slab's rate) the
  SLO-less prefills throttle to one block per step so every step stays
  short.
* **growth/eviction** — decode slots grow their block list lazily, one
  block at a time; when the pool is exhausted a requester may only evict
  runners strictly weaker than itself (lower priority, then younger), so
  the most senior request always finishes (no eviction livelock).
  Releasing a victim only returns blocks with no remaining sharers — a
  shared prefix survives its evicted co-owner.
* **completion** — a slot that reaches ``max_new_tokens`` frees its blocks
  and the slot is immediately reusable (padding-free slot reuse: the other
  slots never see it).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional

import numpy as np

from repro.core.plan import ServePlan
from repro.serve.prefix import PrefixIndex

WAITING, PREFILL, RUNNING, DONE = "waiting", "prefill", "running", "done"


def random_stream(
    cfg,
    n_requests: int,
    prompt_len,
    gen: int,
    stagger: int = 0,
    seed: int = 0,
    rid_prefix: str = "req",
) -> list["Request"]:
    """Synthetic staggered request stream (launcher, benchmarks, examples all
    share this so they exercise the same arrival semantics).

    ``prompt_len`` is an int for fixed-length prompts or an (lo, hi) tuple
    for mixed lengths drawn uniformly."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        n = (
            int(rng.integers(prompt_len[0], prompt_len[1]))
            if isinstance(prompt_len, tuple)
            else prompt_len
        )
        reqs.append(
            Request(
                rid=f"{rid_prefix}{i:03d}",
                prompt=list(rng.integers(0, cfg.vocab_size, n)),
                max_new_tokens=gen,
                arrival=i * stagger,
            )
        )
    return reqs


class BlockAllocator:
    """Refcounted free-list allocator over the shared block pool.

    Block 0 is reserved as the trash block (idle decode slots write there),
    so ids 1..n_blocks-1 are allocatable.  ``alloc`` hands out blocks with
    one reference; ``share`` adds a sharer (prefix sharing: N requests on
    one resident prefix hold the same physical block); ``free`` drops one
    reference per listed block and only returns a block to the pool when
    its last sharer lets go.  Freed blocks are handed out again
    (wraparound) — stale page contents are simply overwritten by the next
    owner's writes.

    Double-free safety: with refcounts a stray second ``free`` of the same
    list would silently steal a block still owned by a sharer, so freeing
    a block with no live references is a counted, warned no-op
    (``double_frees``) instead of trusting callers.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need at least one allocatable block + trash")
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, 0, -1))  # pop() yields 1 first
        self._ref = [0] * n_blocks
        self.double_frees = 0
        self.peak_in_use = 0

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Physical blocks currently owned (however many sharers each has)."""
        return self.n_blocks - 1 - len(self._free)

    def refcount(self, block: int) -> int:
        return self._ref[block]

    def alloc(self, n: int) -> Optional[list[int]]:
        """n blocks, or None when the pool cannot host them (caller evicts)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._ref[b] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return got

    def share(self, blocks: list[int]) -> None:
        """Add one reference per block (must already be live)."""
        for b in blocks:
            if self._ref[b] < 1:
                raise ValueError(f"cannot share unowned block {b}")
        for b in blocks:
            self._ref[b] += 1

    def free(self, blocks: list[int]) -> list[int]:
        """Drop one reference per block; returns the blocks actually
        released to the pool (refcount hit zero) so the caller can
        invalidate the prefix index precisely."""
        released = []
        for b in blocks:
            if not 0 < b < self.n_blocks:
                raise ValueError(f"block {b} out of range")
            if self._ref[b] < 1:
                self.double_frees += 1
                warnings.warn(
                    f"double free of block {b} ignored", RuntimeWarning,
                    stacklevel=2,
                )
                continue
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free.append(b)
                released.append(b)
        return released


@dataclasses.dataclass
class Request:
    """One serving request (the ``repro.serve`` public request record).

    Construct with the prompt and generation budget; the multi-tenant
    descriptors are keyword-only:

    * ``tenant`` — fair-share accounting key (per-tenant slot shares).
    * ``priority`` — admission/eviction class; higher wins.
    * ``slo_ttft_ms`` — time-to-first-token target; feeds SLO-aware prefill
      chunk sizing (and, via the plan, slab width / draft depth).
    * ``tag`` — free-form workload-class label for per-class reporting
      (``serve.workload.per_class_report``); never read by the scheduler.
    * ``deadline_ms`` — wall-clock budget from submit; expiry cancels the
      request wherever it is (queue or slot) and releases its resources.
      None inherits the plan's fleet default.

    Every field after the marker comment is scheduler-owned runtime state —
    internal, reset on eviction, not part of the construction API.
    """

    rid: str
    prompt: list[int]
    max_new_tokens: int
    arrival: int = 0  # engine iteration at which the request becomes visible
    _: dataclasses.KW_ONLY
    tenant: str = "default"
    priority: int = 0
    slo_ttft_ms: Optional[float] = None
    tag: str = ""
    deadline_ms: Optional[float] = None
    # -- scheduler-owned state --
    state: str = WAITING
    slot: int = -1
    blocks: list[int] = dataclasses.field(default_factory=list)
    pos: int = 0  # prompt tokens resident (shared prefix + prefilled) so far
    out: list[int] = dataclasses.field(default_factory=list)
    shared: int = 0  # leading blocks held by refcount share (stats only)
    registered: int = 0  # prefix-index high-water mark (full blocks indexed)
    # -- robustness state --
    # terminal disposition: "ok" (finished normally) | "shed" (admission
    # backpressure) | "expired" (deadline) | "cancelled" (caller) |
    # "poisoned" (quarantine_limit consecutive non-finite steps)
    status: str = "ok"
    retry_after_s: Optional[float] = None  # hint attached when shed
    quarantines: int = 0  # total non-finite steps absorbed (stats)
    quarantine_streak: int = 0  # consecutive; reset by any progress
    blocked_since: Optional[int] = None  # iteration admission first starved
    # -- latency bookkeeping (wall clock; summary percentiles) --
    t_submit: Optional[float] = None  # entered the queue (deadline clock t0)
    t_admit: Optional[float] = None  # first admitted into a slot
    t_first: Optional[float] = None  # first output token sampled
    t_done: Optional[float] = None  # generation complete

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens

    @property
    def prefill_target(self) -> list[int]:
        """Tokens that must be cache-resident before this slot decodes:
        the prompt, plus — after a crash-restore replay resumed mid-stream
        — all but the last already-emitted token (that one re-enters as
        the decode row).  KV pages are a pure function of the token
        prefix, so replaying this target rebuilds them byte-exactly."""
        return (self.prompt + self.out[:-1]) if self.out else self.prompt


def _seniority(r: Request) -> tuple:
    """Total order for admission/eviction: higher priority first, then
    older arrival, then rid.  Smaller = more senior."""
    return (-r.priority, r.arrival, r.rid)


class Scheduler:
    """Owns slots, block tables and the request queues for one engine."""

    def __init__(self, serve: ServePlan, *, obs=None):
        self.serve = serve
        # shared with the owning engine (which passes its bundle in); a
        # bare Scheduler builds its own so lifecycle accounting always has
        # somewhere to land.  Tracing stays disabled unless the bundle
        # enables it — every hook is host-side only.
        if obs is None:
            from repro.obs import Observability

            obs = Observability()
        self.obs = obs
        self.alloc = BlockAllocator(serve.n_blocks)
        self.index = (
            PrefixIndex(serve.block_size) if serve.prefix_sharing else None
        )
        self.table = np.zeros(
            (serve.decode_batch, serve.max_blocks_per_seq), np.int32
        )  # all-trash until a slot is owned
        self.lens = np.zeros((serve.decode_batch,), np.int32)
        self.slots: list[Optional[Request]] = [None] * serve.decode_batch
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        # requests retired *without* completing (shed / expired / cancelled /
        # poisoned) — kept separate so goodput accounting cannot conflate
        # them with finished streams
        self.shed: list[Request] = []
        self.n_evictions = 0
        # copy-on-write forks the engine must apply (device page copies)
        # BEFORE its next step: (src block, dst block) pairs, appended at
        # admission and drained by ``drain_copies``.  Nothing may free the
        # source between admission and the drain (the engine drains right
        # after ``admit``; growth/eviction only run later in the iteration).
        self.pending_copies: list[tuple[int, int]] = []
        self.n_forks = 0
        self.n_admissions = 0
        self.n_prefix_hits = 0
        self.prefix_tokens_saved = 0
        # measured step wall time (EMA, engine-fed) for SLO chunk sizing
        self.step_ms: Optional[float] = None

    # ------------------------------------------------------------- helpers
    def _blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.serve.block_size)

    def submit(self, req: Request) -> None:
        limit = self.serve.max_blocks_per_seq * self.serve.block_size
        if len(req.prompt) + req.max_new_tokens > limit:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)}"
                f" + {req.max_new_tokens} new tokens exceeds max_seq {limit}"
            )
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        self.obs.on_submit(req)
        self.waiting.append(req)

    # ----------------------------------------------------------- admission
    def _tenant_load(self) -> dict:
        load: dict = {}
        for r in self._active():
            load[r.tenant] = load.get(r.tenant, 0) + 1
        return load

    def admit(self, iteration: int) -> None:
        """Priority + per-tenant fair-share admission over arrived waiters.

        Dead slab rows write to the trash block, so a prompt needs exactly
        ``ceil(len / block_size)`` blocks — minus whatever prefix the index
        finds resident.  Admission stops at the first pool-full candidate
        (no bypass: a starved head-of-line request keeps its turn)."""
        while True:
            arrived = [r for r in self.waiting if r.arrival <= iteration]
            if not arrived:
                return
            slot = next((i for i, s in enumerate(self.slots) if s is None), None)
            if slot is None:
                self._mark_blocked(arrived, iteration)
                return
            load = self._tenant_load()
            req = min(
                arrived,
                key=lambda r: (-r.priority, load.get(r.tenant, 0), r.arrival, r.rid),
            )
            if not self._admit_one(req, slot):
                self._mark_blocked(arrived, iteration)
                return  # pool full: keep order, try next iteration
            # the queue moved: nobody still waiting is starving *yet*
            for r in self.waiting:
                r.blocked_since = None

    def _mark_blocked(self, arrived: list[Request], iteration: int) -> None:
        """Start (or continue) the starvation clock for arrived waiters the
        pool/slots cannot take; ``shed_starved`` sheds them once the clock
        exceeds the plan's admission patience."""
        for r in arrived:
            if r.blocked_since is None:
                r.blocked_since = iteration

    def _admit_one(self, req: Request, slot: int) -> bool:
        """Place one request into a slot, sharing whatever prefix is
        resident.  Returns False (no side effects) when the pool cannot
        host the un-shared blocks.

        Admission prefills ``req.prefill_target`` — the prompt for a fresh
        or evicted request, prompt + emitted-so-far for a crash-restore
        replay (``out`` is preserved; the replayed KV is byte-identical
        because pages are a pure function of the token prefix)."""
        target = req.prefill_target
        total = self._blocks_for(len(target))
        full: list[int] = []
        partial = None
        p = 0
        if self.index is not None:
            full, partial, p = self.index.match(target)
        fresh = self.alloc.alloc(total - len(full))
        if fresh is None:
            return False
        self.alloc.share(full)
        if partial is not None:
            # copy-on-write fork: the divergence point sits inside a
            # resident block — copy its pages to fresh[0], prefill the tail
            self.pending_copies.append((partial[0], fresh[0]))
            self.n_forks += 1
        blocks = full + fresh
        self.waiting.remove(req)
        req.state, req.slot, req.blocks, req.pos = PREFILL, slot, blocks, p
        req.blocked_since = None
        req.quarantine_streak = 0
        req.shared = len(full)
        req.registered = len(full)
        self.n_admissions += 1
        if p > 0:
            self.n_prefix_hits += 1
            self.prefix_tokens_saved += p
        now = time.perf_counter()
        if req.t_admit is None:  # re-admission after eviction keeps t0
            req.t_admit = now
        self.obs.on_admit(
            req, now, prefix_tokens=p, forked=partial is not None
        )
        self.slots[slot] = req
        self.table[slot] = 0
        self.table[slot, : len(blocks)] = blocks
        self.lens[slot] = 0
        return True

    def drain_copies(self) -> list[tuple[int, int]]:
        """Hand the engine the pending fork copies (and forget them)."""
        out, self.pending_copies = self.pending_copies, []
        return out

    # ------------------------------------------------------------ the slab
    def busy(self) -> bool:
        return any(s is not None for s in self.slots)

    def _slo_pressure(self) -> bool:
        """True while some SLO'd prefill is at risk: at the measured step
        time its TTFT target needs more than half the slab's row rate, so
        SLO-less prefills should yield chunk width (shorter steps)."""
        if self.step_ms is None:
            return False
        now = time.perf_counter()
        W = self.serve.mixed_slab_width
        for r in self.prefilling():
            if r.slo_ttft_ms is None or r.t_admit is None:
                continue
            left_ms = r.slo_ttft_ms - (now - r.t_admit) * 1e3
            steps_left = max(left_ms, 0.0) / max(self.step_ms, 1e-9)
            if len(r.prefill_target) - r.pos > 0.5 * W * steps_left:
                return True
        return False

    def _chunk_for(self, req: Request, width: int, pressure: bool) -> int:
        """SLO-aware prefill chunk sizing: TTFT-targeted requests always
        take the full slab width; SLO-less ones throttle to one block per
        step while an SLO'd prefill is at risk."""
        rem = len(req.prefill_target) - req.pos
        if req.slo_ttft_ms is None and pressure:
            return min(rem, width, self.serve.block_size)
        return min(rem, width)

    def _slab_view(self, width: int, drafts: Optional[dict] = None):
        """[internal] Pack one engine iteration's (B, W) token slab.

        Returns (tokens, tables, lens, kinds) as numpy arrays:
        ``kinds[b]`` is the number of live query rows of slot b — 0 for an
        idle slot (whole row dead, table zeroed to the trash block), 1 for
        a decode slot (its last sampled token), up to W for a prefill slot
        (its next prompt chunk).  ``lens[b]`` is the absolute position of
        the row's first token.

        ``drafts`` ({rid: [draft tokens]}, speculative decoding) turns a
        running slot's row into a gamma+1-token verification chunk: its
        last sampled token followed by the drafted continuation.  Keyed by
        rid, not slot, so drafts for a request evicted (or recycled) between
        proposal and packing are dropped on the floor instead of riding an
        unrelated slot."""
        B = self.serve.decode_batch
        tokens = np.zeros((B, width), np.int32)
        tables = np.zeros_like(self.table)
        lens = np.zeros((B,), np.int32)
        kinds = np.zeros((B,), np.int32)
        pressure = self._slo_pressure()
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            tables[b] = self.table[b]
            if req.state == RUNNING:
                row = [req.out[-1]]
                if drafts:
                    row += list(drafts.get(req.rid, ()))[: width - 1]
                tokens[b, : len(row)] = row
                lens[b] = self.lens[b]
                kinds[b] = len(row)
            elif req.state == PREFILL:
                n = self._chunk_for(req, width, pressure)
                chunk = req.prefill_target[req.pos : req.pos + n]
                tokens[b, : len(chunk)] = chunk
                lens[b] = req.pos
                kinds[b] = len(chunk)
        return tokens, tables, lens, kinds

    def _slab_done(
        self,
        sampled: np.ndarray,
        kinds: np.ndarray,
        vtok: Optional[np.ndarray] = None,
        drafts: Optional[dict] = None,
        finite: Optional[np.ndarray] = None,
        span: Optional[tuple] = None,
    ) -> dict:
        """[internal] Consume one unified step's per-slot sampled tokens.

        ``sampled[b]`` is the greedy token at the slot's last live row — a
        running slot's next token, or (on the final prompt chunk) the
        request's first output token; mid-chunk samples are discarded.

        Speculative slots (``drafts[rid]`` rode the slab) are verified
        against ``vtok`` ((B, spec_len+1): the greedy argmax at each of the
        slot's leading rows): the longest draft prefix matching the target's
        own greedy choices is accepted, and every emitted token is one the
        target would have produced serially — acceptance changes speed,
        never tokens.  Rollback past rejected rows is just the per-slot
        length vector (`lens[b] += len(emitted)` instead of += gamma+1);
        the block table is untouched and the stale KV the dead rows wrote
        past the new length is masked by the kernel and overwritten when
        the slot next advances.

        Newly *full* blocks (their whole extent below the slot's accepted
        length) are registered in the prefix index here — only accepted
        tokens, so rejected draft rows never leak into a shared prefix.

        ``finite[b]`` (the on-device finiteness scalar, when the engine
        passes it) gates everything: a non-finite slot is *quarantined* —
        no token is emitted, no position advances, and the slot simply
        replays the same rows next iteration (the KV it wrote is a pure
        function of the token prefix, so the replay is byte-exact).
        ``quarantine_limit`` consecutive quarantines cancel the request as
        poisoned instead of replaying forever.

        Returns this step's accounting: output tokens actually emitted
        (``generated``), prompt rows consumed (``prefill``), quarantine
        outcomes, and the speculation counters (draft rows submitted /
        accepted, slots that speculated, tokens they emitted).

        ``span`` is the engine's (t0, t1) dispatch window; when given, each
        busy slot gets a per-request lifecycle span over that window
        (``prefill-chunk`` / ``decode`` / ``spec-verify``) so request
        timelines nest under step spans in the Chrome trace."""
        now = time.perf_counter()
        tr = self.obs.tracer
        c = {
            "generated": 0, "prefill": 0, "draft_rows": 0,
            "accepted_drafts": 0, "spec_slots": 0, "spec_generated": 0,
            "quarantined": 0, "poisoned": 0,
        }

        def finish(b, req):
            self._finish(req, now)

        for b, req in enumerate(self.slots):
            if req is None or kinds[b] == 0:
                continue
            if finite is not None and not bool(finite[b]):
                c["quarantined"] += 1
                if self._note_quarantine(req, now):
                    c["poisoned"] += 1
                continue
            req.quarantine_streak = 0
            if req.state == RUNNING:
                k = int(kinds[b])
                d = list((drafts or {}).get(req.rid, ()))[: k - 1] if k > 1 else []
                if d:
                    v = vtok[b]
                    a = 0
                    while a < len(d) and int(v[a]) == int(d[a]):
                        a += 1
                    room = req.max_new_tokens - len(req.out)
                    emit = [int(v[i]) for i in range(min(a + 1, room))]
                    c["draft_rows"] += len(d)
                    c["accepted_drafts"] += a
                    c["spec_slots"] += 1
                    c["spec_generated"] += len(emit)
                    if span is not None:
                        tr.request_span(
                            "spec-verify", req.rid, span[0], span[1],
                            {"drafted": len(d), "accepted": a,
                             "emitted": len(emit)},
                        )
                        if a < len(d):
                            tr.request_instant(
                                "rollback", req.rid, span[1],
                                {"rejected": len(d) - a},
                            )
                else:
                    emit = [int(sampled[b])]
                    if span is not None:
                        tr.request_span(
                            "decode", req.rid, span[0], span[1],
                            {"pos": int(self.lens[b])},
                        )
                self.lens[b] += len(emit)
                req.out.extend(emit)
                c["generated"] += len(emit)
                if req.done:
                    finish(b, req)
                else:
                    self._register_full_blocks(req, int(self.lens[b]))
            elif req.state == PREFILL:
                target = req.prefill_target
                req.pos += int(kinds[b])
                c["prefill"] += int(kinds[b])
                if span is not None:
                    tr.request_span(
                        "prefill-chunk", req.rid, span[0], span[1],
                        {"rows": int(kinds[b]), "pos": req.pos},
                    )
                if req.pos >= len(target):
                    if not req.out:
                        req.out.append(int(sampled[b]))
                        c["generated"] += 1
                        req.t_first = now
                        tr.request_instant("first-token", req.rid, now)
                    # else: crash-restore replay — the sample at the last
                    # target row is out[-1]'s already-known predecessor
                    # argmax; the preserved tail re-enters as the decode row
                    req.state = RUNNING
                    self.lens[b] = len(target)
                    if req.done:  # max_new_tokens == 1
                        finish(b, req)
                        continue
                self._register_full_blocks(req, req.pos)
        return c

    def _note_quarantine(self, req: Request, now: float) -> bool:
        """Count one quarantined (non-finite) step; cancel the request as
        poisoned when the streak exhausts the plan's quarantine limit.
        Returns True if the request was cancelled."""
        req.quarantines += 1
        req.quarantine_streak += 1
        self.obs.on_quarantine(req, now)
        if req.quarantine_streak >= self.serve.quarantine_limit:
            self.cancel(req, status="poisoned", now=now)
            return True
        return False

    def _finish(self, req: Request, now: float) -> None:
        """Retire a completed request: release its blocks/slot, record it.
        Shared by the K=1 slab path and the rolled-span path."""
        req.t_done = now
        req.state = DONE
        self._release(req)
        self.finished.append(req)
        self.obs.on_finish(req, now)

    # ----------------------------------------------- cancellation / shedding
    def cancel(
        self,
        req: Request,
        status: str = "cancelled",
        retry_after: Optional[float] = None,
        now: Optional[float] = None,
    ) -> None:
        """Retire a request *without* completing it, wherever it lives —
        the waiting queue or an active slot.  Blocks and radix references
        release exactly as on completion; a pending copy-on-write fork
        targeting a released block is dropped before it can write into a
        reallocated page."""
        if req.state == DONE:
            return
        if req in self.waiting:
            self.waiting.remove(req)
        if req.blocks or req.slot >= 0:
            mine = set(req.blocks)
            self.pending_copies = [
                (s, d) for s, d in self.pending_copies if d not in mine
            ]
            self._release(req)
        req.state = DONE
        req.status = status
        req.retry_after_s = retry_after
        req.t_done = now if now is not None else time.perf_counter()
        self.shed.append(req)
        self.obs.on_cancel(req, status, req.t_done)

    def expire_deadlines(self, now: float) -> int:
        """Cancel every queued or active request whose wall-clock deadline
        (ms since submit) has passed; returns how many expired."""
        n = 0
        candidates = [r for r in self.waiting] + [
            s for s in self.slots if s is not None
        ]
        for r in candidates:
            if r.deadline_ms is None or r.t_submit is None:
                continue
            if (now - r.t_submit) * 1e3 > r.deadline_ms:
                self.cancel(r, status="expired", now=now)
                n += 1
        return n

    def shed_starved(self, iteration: int) -> int:
        """Admission backpressure: shed arrived waiters that have been
        admission-blocked for longer than the plan's patience, attaching a
        retry-after hint, instead of livelocking behind eviction."""
        n = 0
        for r in list(self.waiting):
            if r.arrival > iteration or r.blocked_since is None:
                continue
            if iteration - r.blocked_since >= self.serve.admission_patience:
                self.cancel(r, status="shed", retry_after=self._retry_after())
                n += 1
        return n

    def _retry_after(self) -> float:
        """Seconds until admission plausibly unblocks: the earliest runner
        completion at the measured step rate, or one patience window when
        nothing is running (pure pool pressure)."""
        ms = self.step_ms if self.step_ms is not None else 1.0
        rem = [r.max_new_tokens - len(r.out) for r in self.running()]
        steps = min(rem) if rem else self.serve.admission_patience
        return max(steps, 1) * ms / 1e3

    # Back-compat aliases: PR 6 consolidated the public serving surface on
    # ``ServingEngine.submit/run/summary`` — slab packing and growth are
    # engine internals, kept reachable under their old names.
    slab_view = _slab_view
    slab_done = _slab_done

    # ------------------------------------------------------- rolled horizon
    def plan_rolled(self, iteration: int, cap: int):
        """Event horizon + block pre-reservation for one rolled dispatch.

        Returns ``(k, steps)``: the decode-iteration count the device may
        run before the host must intervene again, and the per-slot
        iteration budgets (B,) int32 — or ``(1, None)`` when the next
        host-required event is immediate, so the engine falls back to the
        ordinary K=1 mixed step transparently.

        What forces K=1 (each is host work the loop cannot do):

        * a mid-prefill slot — chunk packing / SLO throttling is host-side;
        * no runners — nothing to decode;
        * an arrival due next iteration, or pool pressure the reservation
          below cannot cover without evicting (the K=1 path owns eviction).

        The horizon itself is the distance to the next host event:

        * an **unarrived** waiter bounds it by ``arrival - iteration``
          (admission is a host event);
        * an **arrived-but-blocked** waiter (no free slot / pool too full
          now) bounds it by the earliest runner completion — a completion
          is exactly the admission opportunity it is waiting for;
        * otherwise every runner gets its own remaining generation budget
          and simply dies mid-span on device while the rest continue.

        Pre-reservation: each runner is granted blocks for its *whole*
        span before dispatch (positions up to ``lens + steps[b]``), so K
        iterations can never outgrow a block table mid-loop.  If the pool
        cannot cover the spans without eviction the horizon shrinks until
        it can; at k == 1 nothing is reserved and the caller falls back.
        """
        if cap <= 1 or self.prefilling():
            return 1, None
        runners = self.running()
        if not runners:
            return 1, None
        budgets = {r.rid: r.max_new_tokens - len(r.out) for r in runners}
        k = min(int(cap), max(budgets.values()))  # nobody can use more
        unarrived = [r.arrival for r in self.waiting if r.arrival > iteration]
        if unarrived:
            k = min(k, min(unarrived) - iteration)
        if any(r.arrival <= iteration for r in self.waiting):
            # an arrived waiter is blocked on slots/pool: the earliest
            # completion is its admission opportunity, stop there
            k = min(k, min(budgets.values()))

        def need(kk: int) -> dict:
            per = {}
            for r in runners:
                span = min(kk, budgets[r.rid])
                n = self._blocks_for(int(self.lens[r.slot]) + span)
                n -= len(r.blocks)
                if n > 0:
                    per[r.rid] = n
            return per

        while k > 1 and sum(need(k).values()) > self.alloc.available:
            k -= 1
        if k <= 1:
            return 1, None
        per = need(k)
        for r in runners:
            n = per.get(r.rid, 0)
            if n:
                got = self.alloc.alloc(n)  # covered: sum(per) <= available
                start = len(r.blocks)
                r.blocks.extend(got)
                self.table[r.slot, start : len(r.blocks)] = got
        steps = np.zeros((self.serve.decode_batch,), np.int32)
        for r in runners:
            steps[r.slot] = min(k, budgets[r.rid])
        return k, steps

    def _rolled_done(
        self,
        out: np.ndarray,
        steps: np.ndarray,
        span: Optional[tuple] = None,
    ) -> dict:
        """[internal] Consume one rolled dispatch: append each slot's span
        of sampled tokens, advance its length, retire exhausted requests and
        register newly-full blocks — the K=1 bookkeeping, span-sized.
        ``out[b, :steps[b]]`` are slot b's tokens in order; a -1 marks the
        first non-finite iteration (the rolled loop freezes the slot from
        there), so a truncated span is a quarantine — the slot keeps its
        last-good length and replays from it next dispatch."""
        now = time.perf_counter()
        c = {"generated": 0, "quarantined": 0, "poisoned": 0}
        for b, req in enumerate(self.slots):
            if req is None or steps[b] == 0:
                continue
            row = out[b, : int(steps[b])]
            neg = np.flatnonzero(row < 0)
            emit = [int(t) for t in (row[: neg[0]] if len(neg) else row)]
            if span is not None:
                self.obs.tracer.request_span(
                    "decode-span", req.rid, span[0], span[1],
                    {"k": int(steps[b]), "emitted": len(emit)},
                )
            self.lens[b] += len(emit)
            req.out.extend(emit)
            c["generated"] += len(emit)
            if emit:
                req.quarantine_streak = 0
            if len(neg):
                c["quarantined"] += 1
                if self._note_quarantine(req, now):
                    c["poisoned"] += 1
                    continue
            if req.done:
                self._finish(req, now)
            else:
                self._register_full_blocks(req, int(self.lens[b]))
        return c

    def _register_full_blocks(self, req: Request, n_written: int) -> None:
        """Index every newly *full* block of a live request.

        KV below ``n_written`` (accepted tokens only) is final: per-slot
        lengths are monotone, so a full block's pages never change again
        and its token run identifies them exactly."""
        if self.index is None:
            return
        n_full = n_written // self.serve.block_size
        if n_full <= req.registered:
            return
        toks = (req.prompt + req.out)[: n_full * self.serve.block_size]
        self.index.register(toks, req.blocks[:n_full])
        req.registered = n_full

    # -------------------------------------------------------------- decode
    def running(self) -> list[Request]:
        return [s for s in self.slots if s is not None and s.state == RUNNING]

    def prefilling(self) -> list[Request]:
        return [s for s in self.slots if s is not None and s.state == PREFILL]

    def _active(self) -> list[Request]:
        """Slot holders that own blocks (running *or* mid-prefill) — the
        eviction candidate pool."""
        return [
            s for s in self.slots if s is not None and s.state in (PREFILL, RUNNING)
        ]

    def _grow_for_decode(self, extra_rows: Optional[dict] = None) -> None:
        """[internal] Ensure every running slot has a block for the position
        it is about to write; when the pool runs dry a requester may only
        evict holders strictly *weaker* than itself (lower priority, then
        younger) — if there is none it preempts itself instead.  The most
        senior request therefore always keeps its pages and finishes (no
        eviction livelock).  Evicting a sharer releases only its exclusive
        blocks — a shared prefix stays resident for its co-owners, so a
        victim may free less than it holds.

        ``extra_rows`` ({rid: n}) covers speculative slots: a slot about to
        verify n draft rows writes KV at n positions past its real token,
        so its block run must reach that high-water mark *before* the step
        (rejected rows roll back the length only — the blocks stay)."""
        extra_rows = extra_rows or {}
        for req in sorted(self.running(), key=_seniority):
            if req.state != RUNNING:  # evicted as a victim earlier in this loop
                continue
            rows = 1 + int(extra_rows.get(req.rid, 0))
            need = self._blocks_for(int(self.lens[req.slot]) + rows) - len(req.blocks)
            while need > 0:
                got = self.alloc.alloc(need)
                if got is not None:
                    start = len(req.blocks)
                    req.blocks.extend(got)
                    self.table[req.slot, start : len(req.blocks)] = got
                    need = 0
                    break
                victims = sorted(self._active(), key=_seniority, reverse=True)
                victim = next(
                    (
                        v for v in victims
                        if v is not req and _seniority(v) > _seniority(req)
                    ),
                    None,
                )
                if victim is None:
                    if len(self._active()) == 1:
                        raise RuntimeError(
                            "KV pool exhausted by a single request; "
                            "raise n_blocks or lower max_new_tokens"
                        )
                    self.evict(req)  # yield to the elders
                    break
                self.evict(victim)

    grow_for_decode = _grow_for_decode  # back-compat alias (internal)

    def evict(self, req: Request) -> None:
        """Recompute-style preemption: back to the waiting queue from scratch.

        Blocks the victim shares with live co-owners are only dereferenced
        (eviction refuses to release pages somebody else still reads); on
        re-admission the prefix index may hand them straight back."""
        self._release(req)
        req.state, req.pos, req.out = WAITING, 0, []
        req.quarantine_streak = 0
        self.waiting.append(req)
        self.n_evictions += 1
        self.obs.on_evict(req, time.perf_counter())

    def _release(self, req: Request) -> None:
        for b in self.alloc.free(req.blocks):
            if self.index is not None:
                self.index.forget(b)
        req.blocks = []
        req.shared = 0
        req.registered = 0
        if req.slot >= 0:
            self.table[req.slot] = 0
            self.lens[req.slot] = 0
            self.slots[req.slot] = None
            req.slot = -1

    # ------------------------------------------------------------- queries
    @property
    def occupancy(self) -> float:
        return len(self._active()) / self.serve.decode_batch

    @property
    def idle(self) -> bool:
        return not self.waiting and all(s is None for s in self.slots)
