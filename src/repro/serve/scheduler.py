"""Continuous-batching scheduler (host side).

The device side of serving is ONE static-shape jitted step (the unified
mixed prefill/decode slab — ``serve/engine.py``); everything dynamic lives
here as plain Python: request admission, block accounting, slab packing,
completion and eviction.  The scheduler owns the block tables and per-slot
lengths as numpy arrays and hands device copies to each step, so the step
never retraces on request churn.

Policy (Orca-style iteration-level scheduling, token-level batching):

* **admission** — FCFS by arrival; a waiting request is admitted when a
  decode slot is free and the pool can cover its prompt.
* **slab packing** — every slot contributes rows to one (B, W) token slab
  per iteration: a mid-prefill slot fills its row with the next <= W prompt
  tokens, a running slot carries its last sampled token in row 0, and idle
  rows are dead (``kinds`` = live rows per slot; dead rows write to the
  trash block).  Prefill chunks therefore ride in whatever slots the decode
  batch isn't using — prefilling a new request never stalls the runners.
* **growth/eviction** — decode slots grow their block list lazily, one
  block at a time; when the pool is exhausted the *youngest* running
  request is evicted back to the waiting queue (recompute-style preemption,
  its blocks freed for the older requests).
* **completion** — a slot that reaches ``max_new_tokens`` frees its blocks
  and the slot is immediately reusable (padding-free slot reuse: the other
  slots never see it).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.plan import ServePlan

WAITING, PREFILL, RUNNING, DONE = "waiting", "prefill", "running", "done"


def random_stream(
    cfg,
    n_requests: int,
    prompt_len,
    gen: int,
    stagger: int = 0,
    seed: int = 0,
    rid_prefix: str = "req",
) -> list["Request"]:
    """Synthetic staggered request stream (launcher, benchmarks, examples all
    share this so they exercise the same arrival semantics).

    ``prompt_len`` is an int for fixed-length prompts or an (lo, hi) tuple
    for mixed lengths drawn uniformly."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        n = (
            int(rng.integers(prompt_len[0], prompt_len[1]))
            if isinstance(prompt_len, tuple)
            else prompt_len
        )
        reqs.append(
            Request(
                rid=f"{rid_prefix}{i:03d}",
                prompt=list(rng.integers(0, cfg.vocab_size, n)),
                max_new_tokens=gen,
                arrival=i * stagger,
            )
        )
    return reqs


class BlockAllocator:
    """Free-list allocator over the shared block pool.

    Block 0 is reserved as the trash block (idle decode slots write there),
    so ids 1..n_blocks-1 are allocatable.  Freed blocks return to the pool
    and are handed out again (wraparound) — stale page contents are simply
    overwritten by the next owner's writes.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need at least one allocatable block + trash")
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, 0, -1))  # pop() yields 1 first

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[list[int]]:
        """n blocks, or None when the pool cannot host them (caller evicts)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if not 0 < b < self.n_blocks:
                raise ValueError(f"block {b} out of range")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)


@dataclasses.dataclass
class Request:
    rid: str
    prompt: list[int]
    max_new_tokens: int
    arrival: int = 0  # engine iteration at which the request becomes visible
    # -- scheduler-owned state --
    state: str = WAITING
    slot: int = -1
    blocks: list[int] = dataclasses.field(default_factory=list)
    pos: int = 0  # prompt tokens prefilled so far
    out: list[int] = dataclasses.field(default_factory=list)
    # -- latency bookkeeping (wall clock; summary percentiles) --
    t_admit: Optional[float] = None  # first admitted into a slot
    t_first: Optional[float] = None  # first output token sampled
    t_done: Optional[float] = None  # generation complete

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens


class Scheduler:
    """Owns slots, block tables and the request queues for one engine."""

    def __init__(self, serve: ServePlan):
        self.serve = serve
        self.alloc = BlockAllocator(serve.n_blocks)
        self.table = np.zeros(
            (serve.decode_batch, serve.max_blocks_per_seq), np.int32
        )  # all-trash until a slot is owned
        self.lens = np.zeros((serve.decode_batch,), np.int32)
        self.slots: list[Optional[Request]] = [None] * serve.decode_batch
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        self.n_evictions = 0

    # ------------------------------------------------------------- helpers
    def _blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.serve.block_size)

    def submit(self, req: Request) -> None:
        limit = self.serve.max_blocks_per_seq * self.serve.block_size
        if len(req.prompt) + req.max_new_tokens > limit:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)}"
                f" + {req.max_new_tokens} new tokens exceeds max_seq {limit}"
            )
        self.waiting.append(req)

    # ----------------------------------------------------------- admission
    def admit(self, iteration: int) -> None:
        """FCFS: move waiting requests into free slots while blocks last.

        Dead slab rows write to the trash block, so a prompt needs exactly
        ``ceil(len / block_size)`` blocks — no chunk-padding waste."""
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))
        for req in list(self.waiting):
            if req.arrival > iteration:
                continue
            slot = next((i for i, s in enumerate(self.slots) if s is None), None)
            if slot is None:
                return
            blocks = self.alloc.alloc(self._blocks_for(len(req.prompt)))
            if blocks is None:
                return  # pool full: keep FCFS order, try next iteration
            self.waiting.remove(req)
            req.state, req.slot, req.blocks, req.pos, req.out = (
                PREFILL, slot, blocks, 0, [],
            )
            if req.t_admit is None:  # re-admission after eviction keeps t0
                req.t_admit = time.perf_counter()
            self.slots[slot] = req
            self.table[slot] = 0
            self.table[slot, : len(blocks)] = blocks
            self.lens[slot] = 0

    # ------------------------------------------------------------ the slab
    def busy(self) -> bool:
        return any(s is not None for s in self.slots)

    def slab_view(self, width: int, drafts: Optional[dict] = None):
        """Pack one engine iteration's (B, W) token slab.

        Returns (tokens, tables, lens, kinds) as numpy arrays:
        ``kinds[b]`` is the number of live query rows of slot b — 0 for an
        idle slot (whole row dead, table zeroed to the trash block), 1 for
        a decode slot (its last sampled token), up to W for a prefill slot
        (its next prompt chunk).  ``lens[b]`` is the absolute position of
        the row's first token.

        ``drafts`` ({rid: [draft tokens]}, speculative decoding) turns a
        running slot's row into a gamma+1-token verification chunk: its
        last sampled token followed by the drafted continuation.  Keyed by
        rid, not slot, so drafts for a request evicted (or recycled) between
        proposal and packing are dropped on the floor instead of riding an
        unrelated slot."""
        B = self.serve.decode_batch
        tokens = np.zeros((B, width), np.int32)
        tables = np.zeros_like(self.table)
        lens = np.zeros((B,), np.int32)
        kinds = np.zeros((B,), np.int32)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            tables[b] = self.table[b]
            if req.state == RUNNING:
                row = [req.out[-1]]
                if drafts:
                    row += list(drafts.get(req.rid, ()))[: width - 1]
                tokens[b, : len(row)] = row
                lens[b] = self.lens[b]
                kinds[b] = len(row)
            elif req.state == PREFILL:
                chunk = req.prompt[req.pos : req.pos + width]
                tokens[b, : len(chunk)] = chunk
                lens[b] = req.pos
                kinds[b] = len(chunk)
        return tokens, tables, lens, kinds

    def slab_done(
        self,
        sampled: np.ndarray,
        kinds: np.ndarray,
        vtok: Optional[np.ndarray] = None,
        drafts: Optional[dict] = None,
    ) -> dict:
        """Consume one unified step's per-slot sampled tokens ((B,) int).

        ``sampled[b]`` is the greedy token at the slot's last live row — a
        running slot's next token, or (on the final prompt chunk) the
        request's first output token; mid-chunk samples are discarded.

        Speculative slots (``drafts[rid]`` rode the slab) are verified
        against ``vtok`` ((B, spec_len+1): the greedy argmax at each of the
        slot's leading rows): the longest draft prefix matching the target's
        own greedy choices is accepted, and every emitted token is one the
        target would have produced serially — acceptance changes speed,
        never tokens.  Rollback past rejected rows is just the per-slot
        length vector (`lens[b] += len(emitted)` instead of += gamma+1);
        the block table is untouched and the stale KV the dead rows wrote
        past the new length is masked by the kernel and overwritten when
        the slot next advances.

        Returns this step's accounting: output tokens actually emitted
        (``generated``), prompt rows consumed (``prefill``), and the
        speculation counters (draft rows submitted / accepted, slots that
        speculated, tokens they emitted)."""
        now = time.perf_counter()
        c = {
            "generated": 0, "prefill": 0, "draft_rows": 0,
            "accepted_drafts": 0, "spec_slots": 0, "spec_generated": 0,
        }

        def finish(b, req):
            req.t_done = now
            req.state = DONE
            self._release(req)
            self.finished.append(req)

        for b, req in enumerate(self.slots):
            if req is None or kinds[b] == 0:
                continue
            if req.state == RUNNING:
                k = int(kinds[b])
                d = list((drafts or {}).get(req.rid, ()))[: k - 1] if k > 1 else []
                if d:
                    v = vtok[b]
                    a = 0
                    while a < len(d) and int(v[a]) == int(d[a]):
                        a += 1
                    room = req.max_new_tokens - len(req.out)
                    emit = [int(v[i]) for i in range(min(a + 1, room))]
                    c["draft_rows"] += len(d)
                    c["accepted_drafts"] += a
                    c["spec_slots"] += 1
                    c["spec_generated"] += len(emit)
                else:
                    emit = [int(sampled[b])]
                self.lens[b] += len(emit)
                req.out.extend(emit)
                c["generated"] += len(emit)
                if req.done:
                    finish(b, req)
            elif req.state == PREFILL:
                req.pos += int(kinds[b])
                c["prefill"] += int(kinds[b])
                if req.pos >= len(req.prompt):
                    req.out.append(int(sampled[b]))
                    c["generated"] += 1
                    req.t_first = now
                    req.state = RUNNING
                    self.lens[b] = len(req.prompt)
                    if req.done:  # max_new_tokens == 1
                        finish(b, req)
        return c

    # -------------------------------------------------------------- decode
    def running(self) -> list[Request]:
        return [s for s in self.slots if s is not None and s.state == RUNNING]

    def prefilling(self) -> list[Request]:
        return [s for s in self.slots if s is not None and s.state == PREFILL]

    def _active(self) -> list[Request]:
        """Slot holders that own blocks (running *or* mid-prefill) — the
        eviction candidate pool."""
        return [
            s for s in self.slots if s is not None and s.state in (PREFILL, RUNNING)
        ]

    def grow_for_decode(self, extra_rows: Optional[dict] = None) -> None:
        """Ensure every running slot has a block for the position it is
        about to write; when the pool runs dry a requester may only evict
        runners strictly *younger* than itself — if there is none it
        preempts itself instead.  The oldest request therefore always keeps
        its pages and finishes (no eviction livelock).

        ``extra_rows`` ({rid: n}) covers speculative slots: a slot about to
        verify n draft rows writes KV at n positions past its real token,
        so its block run must reach that high-water mark *before* the step
        (rejected rows roll back the length only — the blocks stay)."""
        extra_rows = extra_rows or {}
        for req in sorted(self.running(), key=lambda r: (r.arrival, r.rid)):
            if req.state != RUNNING:  # evicted as a victim earlier in this loop
                continue
            rows = 1 + int(extra_rows.get(req.rid, 0))
            need = self._blocks_for(int(self.lens[req.slot]) + rows) - len(req.blocks)
            while need > 0:
                got = self.alloc.alloc(need)
                if got is not None:
                    start = len(req.blocks)
                    req.blocks.extend(got)
                    self.table[req.slot, start : len(req.blocks)] = got
                    need = 0
                    break
                victims = sorted(
                    self._active(), key=lambda r: (r.arrival, r.rid), reverse=True
                )
                victim = next(
                    (
                        v for v in victims
                        if v is not req and (v.arrival, v.rid) > (req.arrival, req.rid)
                    ),
                    None,
                )
                if victim is None:
                    if len(self._active()) == 1:
                        raise RuntimeError(
                            "KV pool exhausted by a single request; "
                            "raise n_blocks or lower max_new_tokens"
                        )
                    self.evict(req)  # yield to the elders
                    break
                self.evict(victim)

    def evict(self, req: Request) -> None:
        """Recompute-style preemption: back to the waiting queue from scratch."""
        self._release(req)
        req.state, req.pos, req.out = WAITING, 0, []
        self.waiting.append(req)
        self.n_evictions += 1

    def _release(self, req: Request) -> None:
        self.alloc.free(req.blocks)
        req.blocks = []
        if req.slot >= 0:
            self.table[req.slot] = 0
            self.lens[req.slot] = 0
            self.slots[req.slot] = None
            req.slot = -1

    # ------------------------------------------------------------- queries
    @property
    def occupancy(self) -> float:
        return len(self._active()) / self.serve.decode_batch

    @property
    def idle(self) -> bool:
        return not self.waiting and all(s is None for s in self.slots)
