"""The jitted training step.

Microbatch gradient accumulation (plan.microbatches, the Factor2' outcome)
runs as a lax.scan so activation memory scales with the microbatch, not the
global batch; remat of the layer scan is plan.remat.  Optimizer update and
optional gradient compression happen once per step.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan
from repro.models.transformer import lm_loss
from repro.train.compression import CompressionConfig, compress_grads
from repro.train.optimizer import OptimizerConfig, TrainState, adamw_update

PyTree = Any
Identity = lambda x, name=None: x


def _split_micro(batch: dict, n: int) -> dict:
    def r(x):
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])

    return {k: r(v) for k, v in batch.items()}


def make_loss_fn(cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity):
    def loss_fn(params, batch):
        return lm_loss(params, batch, cfg=cfg, plan=plan, shard=shard)

    return loss_fn


def make_train_step(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    opt: OptimizerConfig,
    shard: Callable = Identity,
    compression: Optional[CompressionConfig] = None,
    grad_shardings=None,
):
    loss_fn = make_loss_fn(cfg, plan, shard)
    _vg = jax.value_and_grad(loss_fn)
    n_micro = max(1, plan.microbatches)
    cc = compression or CompressionConfig()

    def vg(params, batch):
        loss, grads = _vg(params, batch)
        if grad_shardings is not None:
            # Pin gradient layout at the autodiff boundary: the backward scan
            # then reduce-scatters per layer instead of all-reducing a full
            # fp32 partial-gradient buffer (§Perf iteration 7).
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        return loss, grads

    def train_step(state: TrainState, batch: dict):
        if n_micro == 1:
            loss, grads = vg(state.params, batch)
        else:
            micro = _split_micro(batch, n_micro)

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = vg(state.params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, lsum), _ = lax.scan(acc, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        residual = state.residual
        if residual is not None:
            grads, residual = compress_grads(grads, residual, cc)
        new_state, metrics = adamw_update(state, grads, opt)
        new_state = new_state._replace(residual=residual)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
