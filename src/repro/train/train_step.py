"""The jitted training step.

Microbatch gradient accumulation (plan.microbatches, the Factor2' outcome)
runs as a lax.scan so activation memory scales with the microbatch, not the
global batch; remat of the layer scan is plan.remat.  Optimizer update and
optional gradient compression happen once per step.

The ExecutionPlan is the control plane here (docs/ARCHITECTURE.md):

* ``plan.pod_role == "pipeline"`` routes the loss through
  ``models.transformer.pipeline_lm_loss`` — the stacked layer-groups run
  as pipeline stages over the ``pod`` axis via
  ``dist.pipeline.pipeline_forward`` and the pipeline does its own
  microbatching (the outer accumulation scan is disabled).
* ``plan.grad_compression`` picks the gradient wire format.  On a pure
  data-parallel mesh the step runs the exchange itself — per-replica
  gradients inside shard_map, summed by
  ``dist.collectives.compressed_psum`` — so compression happens once, on
  the wire.  On meshes the manual region cannot host (tensor/sequence
  parallel weights, ZeRO shards) the same mode falls back to
  ``train/compression.py``'s accumulation-dtype quantization with error
  feedback.
"""
from __future__ import annotations

import dataclasses
import logging
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan
from repro.dist.collectives import compressed_psum
from repro.models.transformer import lm_loss, pipeline_lm_loss
from repro.train.compression import CompressionConfig, compress_grads
from repro.train.optimizer import OptimizerConfig, TrainState, adamw_update

PyTree = Any
Identity = lambda x, name=None: x

logger = logging.getLogger(__name__)


def _split_micro(batch: dict, n: int) -> dict:
    def r(x):
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])

    return {k: r(v) for k, v in batch.items()}


def make_loss_fn(
    cfg: ArchConfig, plan: ExecutionPlan, shard: Callable = Identity, mesh=None
):
    if plan.pod_role == "pipeline" and plan.pod_axis > 1:
        if mesh is None:
            raise ValueError(
                "plan.pod_role == 'pipeline' needs a real mesh to execute; "
                "pass mesh= to make_train_step"
            )

        def loss_fn(params, batch):
            return pipeline_lm_loss(
                params, batch, cfg=cfg, plan=plan, mesh=mesh, shard=shard
            )

        return loss_fn

    def loss_fn(params, batch):
        return lm_loss(params, batch, cfg=cfg, plan=plan, shard=shard, mesh=mesh)

    return loss_fn


def wire_compression_axes(
    plan: ExecutionPlan, mesh, batch: Optional[int] = None
) -> Optional[tuple[str, ...]]:
    """Mesh axes the compressed gradient exchange runs over, or None when
    the wire path cannot host this plan.

    The manual region computes loss/grads on *replicated* params with only
    the batch sharded, so every weight-sharding feature (tensor parallel,
    ZeRO, FSDP-folded model axis, sequence parallel) and the pipeline
    scheduler disqualify it — those plans keep the dtype-level fallback.
    Pass ``batch`` (the global batch size) to also apply the runtime
    divisibility requirement — launchers should, so they allocate the
    error-feedback residual whenever the fallback will actually run.
    """
    if mesh is None or plan.grad_compression == "none":
        return None
    if (
        plan.pod_role == "pipeline"
        or plan.zero_weights
        or plan.dp_over_model
        or plan.seq_shard
        or plan.seq_parallel_acts
    ):
        return None
    sizes = dict(mesh.shape)
    axes = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
    if not axes:
        return None
    if any(v > 1 for k, v in sizes.items() if k not in axes):
        return None  # a >1 model axis means params are not replicated
    if batch is not None:
        n_dp = math.prod(sizes[a] for a in axes)
        if batch % (n_dp * max(1, plan.microbatches)):
            return None  # local batch would not split into microbatches
    return axes


def make_train_step(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    opt: OptimizerConfig,
    shard: Callable = Identity,
    compression: Optional[CompressionConfig] = None,
    grad_shardings=None,
    mesh=None,
):
    pipelined = plan.pod_role == "pipeline" and plan.pod_axis > 1
    loss_fn = make_loss_fn(cfg, plan, shard, mesh=mesh)
    _vg = jax.value_and_grad(loss_fn)
    # The pipeline schedules its own microbatches; no outer accumulation.
    n_micro = 1 if pipelined else max(1, plan.microbatches)
    # plan.grad_compression is the control-plane knob; an explicit
    # CompressionConfig only overrides its error-feedback detail, so
    # plan-only callers still get the dtype fallback on wire-less meshes.
    cc = compression or CompressionConfig(mode=plan.grad_compression)
    wire_axes = wire_compression_axes(plan, mesh)
    if wire_axes:
        # The wire path recomputes grads per replica: constraints and the
        # GSPMD shard callable are not legal inside the manual region.
        _vg_local = jax.value_and_grad(make_loss_fn(cfg, plan, Identity))
        n_dp = math.prod(dict(mesh.shape)[a] for a in wire_axes)
        wire_entry = wire_axes if len(wire_axes) > 1 else wire_axes[0]

    def vg(params, batch):
        loss, grads = _vg(params, batch)
        if grad_shardings is not None:
            # Pin gradient layout at the autodiff boundary: the backward scan
            # then reduce-scatters per layer instead of all-reducing a full
            # fp32 partial-gradient buffer (§Perf iteration 7).
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        return loss, grads

    def accumulate(vg_fn, params, batch):
        """loss/grads with the microbatch accumulation scan when n_micro>1."""
        if n_micro == 1:
            return vg_fn(params, batch)
        micro = _split_micro(batch, n_micro)

        def acc(carry, mb):
            gsum, lsum = carry
            l, g = vg_fn(params, mb)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = lax.scan(acc, (g0, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        return lsum / n_micro, grads

    def train_step(state: TrainState, batch: dict):
        use_wire = bool(wire_axes)
        if use_wire:
            b0 = jax.tree.leaves(batch)[0].shape[0]
            # local batch must still split into microbatches on each replica
            use_wire = b0 % (n_dp * n_micro) == 0
        if use_wire:
            batch_specs = jax.tree.map(lambda _: P(wire_entry), batch)

            def local(params, b):
                loss, g = accumulate(_vg_local, params, b)
                g = jax.tree.map(
                    lambda x: compressed_psum(x, wire_axes, plan.grad_compression)
                    / n_dp,
                    g,
                )
                return lax.pmean(loss, wire_axes), g

            loss, grads = shard_map(
                local,
                mesh=mesh,
                in_specs=(P(), batch_specs),
                out_specs=(P(), P()),
                check_rep=False,
            )(state.params, batch)
            if grad_shardings is not None:
                grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
            residual = state.residual  # wire mode: compression already done
        else:
            loss, grads = accumulate(vg, state.params, batch)
            residual = state.residual
            if residual is not None:
                grads, residual = compress_grads(grads, residual, cc)
            elif cc.mode != "none":
                # Compression requested but no error-feedback residual in
                # the train state (wire path disqualified at trace time, or
                # a plan-only caller on a weight-sharded mesh): still honor
                # the requested mode statelessly rather than silently
                # training uncompressed.
                logger.warning(
                    "gradient compression (mode=%s) running statelessly: "
                    "no error-feedback residual in the train state and the "
                    "wire path is unavailable on this mesh/batch", cc.mode,
                )
                zeros = jax.tree.map(
                    lambda g: jnp.zeros(g.shape, jnp.float32), grads
                )
                grads, _ = compress_grads(
                    grads, zeros,
                    dataclasses.replace(cc, error_feedback=False),
                )
        new_state, metrics = adamw_update(state, grads, opt)
        new_state = new_state._replace(residual=residual)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
