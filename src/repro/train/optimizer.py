"""AdamW + cosine schedule + global-norm clipping, from scratch.

State layout keeps m/v in fp32 with the same shardings as the params
(optimizer state shards with the weights — ZeRO-1 comes free from the
weight sharding the plan already chose).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class TrainState(NamedTuple):
    step: jax.Array  # () int32
    params: PyTree
    m: PyTree  # fp32 first moment
    v: PyTree  # fp32 second moment
    residual: PyTree = None  # fp32 error-feedback residual (grad compression)


def init_state(params: PyTree, with_residual: bool = False) -> TrainState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        residual=jax.tree.map(zeros, params) if with_residual else None,
    )


def lr_at(opt: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = opt.peak_lr * step / max(opt.warmup_steps, 1)
    frac = jnp.clip(
        (step - opt.warmup_steps) / max(opt.total_steps - opt.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = opt.min_lr + 0.5 * (opt.peak_lr - opt.min_lr) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < opt.warmup_steps, warm, cos)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(
    state: TrainState, grads: PyTree, opt: OptimizerConfig
) -> tuple[TrainState, dict]:
    if opt.clip_norm > 0:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
    else:
        gnorm = jnp.zeros(())
        scale = jnp.ones(())
    step = state.step + 1
    lr = lr_at(opt, step)
    b1, b2 = opt.b1, opt.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + opt.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree.flatten(state.params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return TrainState(step, new_params, new_m, new_v, state.residual), metrics
