"""Gradient compression: the quantization grid + the dtype-level fallback.

At 1000+ node scale the gradient all-reduce over the ``data``/``pod`` axes is
the exposure window for stragglers; compressing it shrinks that window.
There are two execution points, both driven by ``plan.grad_compression``
(docs/ARCHITECTURE.md §"Communication schedule"):

* **Wire path** (preferred): on a pure data-parallel mesh the train step
  exchanges per-replica gradients itself through
  ``dist.collectives.compressed_psum``, which reuses this module's
  ``quantize`` with a shared cross-replica scale — compression happens
  once, on the wire, and no error-feedback state is needed (the exchange
  is the only lossy step and its noise is zero-mean by construction).
* **Dtype fallback**: when the mesh also shards weights (TP/ZeRO/SP) the
  all-reduce lives inside the GSPMD backward where we cannot intercept it,
  so ``compress_grads`` quantizes at the accumulation boundary instead,
  with an fp32 error-feedback residual carried in the train state so the
  quantization noise is unbiased over steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    mode: str = "none"  # none | bf16 | int8
    error_feedback: bool = True


def quantize(g: jax.Array, mode: str, scale: jax.Array | None = None):
    """Quantize one gradient leaf into the wire format.

    ``scale=None`` (the dtype-level path) derives a local per-leaf grid from
    ``max |g|``.  The shard_map wire path (``dist.collectives.compressed_psum``)
    passes a *shared* cross-replica scale (a pmax) so every replica's payload
    sits on the same int8 grid and the exchange can sum raw integers.
    """
    if mode == "bf16":
        return g.astype(jnp.bfloat16), None
    if mode == "int8":
        if scale is None:
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale
    return g, None


def dequantize(q: jax.Array, scale, mode: str) -> jax.Array:
    if mode == "bf16":
        return q.astype(jnp.float32)
    if mode == "int8":
        return q.astype(jnp.float32) * scale
    return q


def init_residual(grads_shape: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)


def compress_grads(
    grads: PyTree, residual: PyTree, cc: CompressionConfig
) -> tuple[PyTree, PyTree]:
    """Quantize+dequantize each grad leaf (the wire format), carrying the
    quantization error into the next step's residual (error feedback)."""
    if cc.mode == "none":
        return grads, residual
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    new_g, new_r = [], []
    for g, r in zip(flat_g, flat_r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize(g32, cc.mode)
        deq = dequantize(q, scale, cc.mode)
        new_r.append((g32 - deq) if cc.error_feedback else jnp.zeros_like(g32))
        new_g.append(deq.astype(g.dtype))
    return jax.tree.unflatten(treedef, new_g), jax.tree.unflatten(treedef, new_r)
