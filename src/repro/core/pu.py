"""MM PU tile solver — the paper's Eq. 3/4 re-derived for VMEM + MXU.
(Equation cross-reference: docs/ARCHITECTURE.md.)

Paper (§IV.B): an AIE MM PU is sized by two constraints
  (Eq. 3)  MMSZ_AIE^2 x bit_data <= M_Window / 4     (double-buffered in/out)
           MMSZ_AIE in powers of two                 (vector ISA alignment)
  (Eq. 4)  PLIO_AIE <= floor(T_Calc / T_Window)      (stream bw never starves cores)

TPU analog: a Pallas matmul tile (block_m, block_n, block_k) is sized so
  (Eq. 3') the VMEM working set (x-tile + w-tile + out-tile, double buffered)
           fits in vmem_bytes / vmem_fraction, with dims multiples of the MXU
           native 128 (the ISA-alignment analog);
  (Eq. 4') the arithmetic intensity of a tile step is at least the machine
           balance so the HBM->VMEM stream keeps the MXU busy
           (2*bm*bn*bk FLOPs) / (bytes(bm*bk) + bytes(bk*bn)) >= balance.

Like the paper we derive a small named family — LARGE / STANDARD / SMALL —
instead of exposing the raw design space, then pick per MM-site.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core.hardware import DEFAULT_HARDWARE, HardwareSpec


@dataclasses.dataclass(frozen=True)
class MMTileSpec:
    """One member of the MM PU family (paper Fig. 4)."""

    name: str
    block_m: int
    block_n: int
    block_k: int
    dtype_bytes: int = 2

    @property
    def vmem_bytes(self) -> int:
        """Working set of one grid step, double buffered (Eq. 3' LHS)."""
        x = self.block_m * self.block_k
        w = self.block_k * self.block_n
        o = self.block_m * self.block_n
        # x/w tiles stream (2x for double buffering); out accumulates in fp32.
        return 2 * self.dtype_bytes * (x + w) + 4 * o

    @property
    def arithmetic_intensity(self) -> float:
        """FLOPs per streamed byte of one k-step (Eq. 4' LHS)."""
        flops = 2.0 * self.block_m * self.block_n * self.block_k
        streamed = self.dtype_bytes * (
            self.block_m * self.block_k + self.block_k * self.block_n
        )
        return flops / streamed


def _round_down_multiple(x: int, mult: int) -> int:
    return max(mult, (x // mult) * mult)


def is_compute_bound(spec: MMTileSpec, hw: HardwareSpec) -> bool:
    """Eq. 4' — the HBM stream keeps the MXU busy for this tile shape.

    Note the analysis that replaces the paper's PLIO formula: with the output
    tile resident in VMEM and the k-grid innermost, streamed bytes per output
    tile are K*(bm+bn)*dtype while FLOPs are 2*bm*bn*K, so the intensity
    bm*bn/((bm+bn)) / dtype-adjustment depends only on (bm, bn) — block_k sets
    pipeline granularity, not intensity.  The constraint therefore bounds the
    tile *edge* from below (edge/2 >= machine balance, i.e. edge >= ~482 on
    v5e bf16), exactly how Eq. 4 bounds PLIO_AIE from above.
    """
    if spec.dtype_bytes >= 2:
        balance = hw.machine_balance_bf16  # inf when hbm_bandwidth == 0
    elif hw.hbm_bandwidth > 0:
        balance = hw.peak_ops_int8 / hw.hbm_bandwidth
    else:
        balance = math.inf
    return spec.arithmetic_intensity >= balance


def solve_mm_tiles(
    hw: HardwareSpec = DEFAULT_HARDWARE,
    dtype_bytes: int = 2,
    vmem_fraction: float = 0.5,
    candidates: Iterable[int] = (128, 256, 512, 1024, 2048),
) -> list[MMTileSpec]:
    """Enumerate the feasible square tile family (largest volume first).

    Eq. 3' — VMEM fit with double buffering, MXU-aligned edges; block_k is the
    largest power-of-two <= edge that still fits (pipeline granularity).
    """
    budget = hw.vmem_bytes * vmem_fraction
    out: list[MMTileSpec] = []
    for edge in candidates:
        if edge % hw.mxu_dim:
            continue
        bk = edge
        while (
            bk > hw.mxu_dim
            and MMTileSpec("cand", edge, edge, bk, dtype_bytes).vmem_bytes > budget
        ):
            bk //= 2
        spec = MMTileSpec(f"sq{edge}", edge, edge, bk, dtype_bytes)
        if spec.vmem_bytes <= budget:
            out.append(spec)
    out.sort(key=lambda s: -(s.block_m * s.block_n * s.block_k))
    return out


def derive_pu_family(
    hw: HardwareSpec = DEFAULT_HARDWARE, dtype_bytes: int = 2
) -> dict[str, MMTileSpec]:
    """The LARGE / STANDARD / SMALL family (paper Fig. 4 a/b/c).

    LARGE    — largest feasible tile (paper: 64-core PU);
    STANDARD — smallest *compute-bound* tile, the balance point
               (paper: 16-core PU);
    SMALL    — smallest feasible tile, for MMs that would otherwise pad
               (paper: 4-core PU for the per-head attention MMs).
    """
    feas = solve_mm_tiles(hw, dtype_bytes)
    if not feas:
        raise RuntimeError("no feasible MM tile for this hardware")
    large = feas[0]
    small = feas[-1]
    bound = [s for s in feas if is_compute_bound(s, hw)]
    std = bound[-1] if bound else feas[len(feas) // 2]
    return {
        "LARGE": dataclasses.replace(large, name="LARGE"),
        "STANDARD": dataclasses.replace(std, name="STANDARD"),
        "SMALL": dataclasses.replace(small, name="SMALL"),
    }


def pick_pu(
    m: int,
    n: int,
    k: int,
    hw: HardwareSpec = DEFAULT_HARDWARE,
    dtype_bytes: int = 2,
) -> MMTileSpec:
    """Select the PU spec for one MM site (paper: "select the appropriate
    AIE MM PU specification according to the Transformer model specification").

    Rule: the biggest family member whose block dims do not overhang the
    problem by more than one MXU tile of padding per dim — the paper's
    ViT padding observation (L=197 pads to 256 and costs throughput) made
    into a selection criterion.
    """
    family = derive_pu_family(hw, dtype_bytes)
    for name in ("LARGE", "STANDARD", "SMALL"):
        s = family[name]
        pad_m = _padded(m, s.block_m) / max(m, 1)
        pad_n = _padded(n, s.block_n) / max(n, 1)
        if pad_m <= 1.25 and pad_n <= 1.25:
            return s
    return family["SMALL"]


def _padded(dim: int, block: int) -> int:
    return int(math.ceil(dim / block)) * block
