"""Plan autotuner — the CAT design-space search made explicit.

The paper derives one accelerator instance from closed-form rules (Eq. 3-8;
paper-to-code map: docs/ARCHITECTURE.md).
This module closes the loop the paper leaves open ("a more complete automatic
deployment framework", §VI): enumerate a small candidate set of plan
overrides, dry-run-compile each, score by the roofline step time, and return
the winner with its full iteration log — the §Perf hypothesis loop as a
subroutine.

    from repro.core.autotune import autotune
    best = autotune("mixtral-8x7b", TRAIN_4K, multi_pod=False)

Requires the 512-device XLA flag (run under repro.launch.dryrun's process or
any process that set xla_force_host_platform_device_count before jax import).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.configs import get_config
from repro.core.hardware import TPU_V5E, HardwareSpec
from repro.core.hlo_cost import analyze_hlo
from repro.core.pu import MMTileSpec, pick_pu
from repro.core.roofline import _ring_seconds, analytic_memory_floor


@dataclasses.dataclass
class Candidate:
    name: str
    overrides: dict
    step_s: Optional[float] = None
    compute_s: Optional[float] = None
    collective_s: Optional[float] = None
    fits: Optional[bool] = None
    error: Optional[str] = None


def default_candidates(cfg) -> list[Candidate]:
    cands = [
        Candidate("planner-default", {}),
        Candidate("force-spatial", {"force_mode": "spatial"}),
        Candidate("force-temporal", {"force_mode": "temporal"}),
        Candidate("split-qkv", {"fuse_qkv": False}),
    ]
    if cfg.is_moe:
        cands.append(Candidate("moe-sort-dispatch", {"moe_dispatch": "sort"}))
    return cands


def score_candidate(cfg, shape, mesh, cand: Candidate, hw=TPU_V5E) -> Candidate:
    from repro.launch.dryrun import build_cell  # deferred: needs device flag

    try:
        fn, args, plan = build_cell(cfg, shape, mesh, plan_overrides=cand.overrides)
        compiled = fn.lower(*args).compile()
        hc = analyze_hlo(compiled.as_text())
        n_chips = 1
        for v in mesh.shape.values():
            n_chips *= v
        compute_s = hc.flops / hw.peak_flops_bf16
        coll_s = sum(
            _ring_seconds(o, b, g, hw.ici_bandwidth_per_link) * m
            for o, b, g, m in hc.collectives
        )
        floor_bytes = analytic_memory_floor(cfg, shape, plan, n_chips)
        floor_s = floor_bytes / hw.hbm_bandwidth if hw.hbm_bandwidth > 0 else 0.0
        ma = compiled.memory_analysis()
        cand.compute_s = compute_s
        cand.collective_s = coll_s
        cand.step_s = max(compute_s, coll_s, floor_s)
        cand.fits = (
            ma.argument_size_in_bytes + ma.temp_size_in_bytes
        ) <= hw.hbm_bytes
    except Exception as e:  # infeasible candidate = informative result
        cand.error = f"{type(e).__name__}: {e}"
    return cand


def resolve_serve_tile(cfg, serve, hw: HardwareSpec = TPU_V5E) -> MMTileSpec:
    """Pallas MM tile for one serving design point (family-search hook).

    The unified step's dominant MM site is the fused QKV projection over the
    live slab rows: every decode slot contributes its 1 + gamma verify rows,
    so m = decode_batch * (1 + spec_len), n = the fused QKV width, and
    k = d_model.  ``pick_pu`` applies the paper's padding-overhang rule to
    that site on the *target* device, which is how each Pareto frontier
    point carries its own autotuned tile parameters
    (core/search.py attaches the result to the point's record)."""
    rows = serve.decode_batch * (1 + serve.spec_len)
    qkv_width = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.d_head
    return pick_pu(max(rows, 1), qkv_width, cfg.d_model, hw, dtype_bytes=2)


def autotune(arch: str, shape, *, multi_pod: bool = False, hw=TPU_V5E,
             candidates=None, prefer_fitting: bool = True):
    """Returns (best_candidate, all_candidates) sorted by step time."""
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cands = candidates or default_candidates(cfg)
    scored = [score_candidate(cfg, shape, mesh, c, hw) for c in cands]
    ok = [c for c in scored if c.step_s is not None]
    if prefer_fitting and any(c.fits for c in ok):
        ok = [c for c in ok if c.fits] or ok
    ok.sort(key=lambda c: c.step_s)
    best = ok[0] if ok else None
    return best, scored
