"""Design-space search: derive a *family* of serving accelerators per device.

The paper's framework derives one customized accelerator from
(model, hardware).  This module is the step the paper motivates but leaves
manual: sweep the customizable attributes the serving planner already owns —
mesh shape (model-axis TP degree), ``decode_batch``, ``kv_dtype``,
``block_size``, ``mixed_slab_width``, ``pages_per_tile``, ``spec_len``
(draft depth gamma), ``rolled_steps`` — through the same roofline and
feasibility models ``derive_serve_plan`` uses, cost every candidate on three
axes (tokens/s, $/token, J/token), and keep the Pareto frontier.  Each
frontier point carries its full :class:`~repro.core.plan.ServePlan` plus the
autotune-resolved MM tile for its dominant GEMM site, so a point is directly
runnable by the serving engine (benchmarks/family_search.py replays one).

Everything here is pure host arithmetic — no jax, no compilation — so a full
sweep over a few hundred candidates is milliseconds.  The cost model and
every swept attribute are documented in docs/PLANNER.md; the CLI surface is
``python -m repro.launch.dryrun --family --hardware <name>``.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
import pathlib
from typing import Optional, Union

from repro.core.hardware import HardwareSpec, energy_params, get_hardware
from repro.core.plan import ServePlan, derive_serve_plan, serve_feasible

# Representative decode context for the steady-state cost model: requests are
# half-way through ``max_seq_len`` on average over their lifetime.
CTX_FRACTION = 0.5


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Candidate values per customizable attribute.

    ``None`` in a value tuple means "let ``derive_serve_plan`` derive it" —
    a space of all-``None`` singletons therefore degenerates to exactly the
    single plan the planner derives today (tested invariant).  Attribute
    order here is the candidate enumeration order, so a search is
    deterministic for a fixed space."""

    mesh_models: tuple[int, ...] = (1,)  # model-axis TP degree (n_chips)
    decode_batches: tuple[Optional[int], ...] = (None,)
    kv_dtypes: tuple[Optional[str], ...] = (None,)
    block_sizes: tuple[Optional[int], ...] = (None,)
    slab_widths: tuple[Optional[int], ...] = (None,)
    pages_per_tile: tuple[Optional[int], ...] = (None,)
    spec_lens: tuple[Optional[int], ...] = (0,)  # draft depth gamma
    rolled_steps: tuple[Optional[int], ...] = (None,)
    max_seq_len: int = 2048
    draft: str = "ngram"  # source used whenever a candidate speculates
    # Modeled per-row draft acceptance probability (alpha).  Expected
    # accepted tokens per slot per step is (1 - a^(g+1)) / (1 - a) — the
    # standard speculative-decoding expectation; 0.6 matches the NGram
    # draft's measured mid-range on BENCH_spec.json.
    acceptance: float = 0.6


def default_space(hw: HardwareSpec, *, max_seq_len: int = 2048) -> SearchSpace:
    """The stock sweep: TP degree where the device has ICI, both KV dtypes,
    the gamma ladder, and rolling on/off.  ~100 candidates."""
    models = (1, 2, 4) if hw.ici_links_per_chip > 0 else (1,)
    return SearchSpace(
        mesh_models=models,
        kv_dtypes=(None, "bf16", "int8"),
        spec_lens=(0, 2, 4, 8),
        rolled_steps=(None, 1),
        max_seq_len=max_seq_len,
    )


@dataclasses.dataclass
class DesignPoint:
    """One costed candidate: the plan plus its three Pareto coordinates."""

    hardware: str
    arch: str
    mesh: dict
    plan: ServePlan
    tile: str  # autotune-resolved MM tile for the dominant decode GEMM
    tokens_per_s: float
    usd_per_mtok: float  # $/token axis, scaled to $ per 1e6 tokens
    mj_per_tok: float  # J/token axis, scaled to millijoules
    step_s: float
    tokens_per_step: float
    bound: str  # "memory" | "compute" | "ici" — the step's binding term
    feasible: bool
    reason: str = ""

    def to_record(self) -> dict:
        return {
            "hardware": self.hardware,
            "arch": self.arch,
            "mesh": dict(self.mesh),
            "plan": self.plan.to_record(),
            "tile": self.tile,
            "tokens_per_s": round(self.tokens_per_s, 1),
            "usd_per_mtok": round(self.usd_per_mtok, 4),
            "mj_per_tok": round(self.mj_per_tok, 4),
            "step_s": self.step_s,
            "tokens_per_step": round(self.tokens_per_step, 3),
            "bound": self.bound,
            "feasible": self.feasible,
            "reason": self.reason,
        }


def expected_accepted(gamma: int, alpha: float) -> float:
    """Expected emitted tokens per speculating slot per step (>= 1)."""
    if gamma <= 0:
        return 1.0
    if alpha >= 1.0:
        return gamma + 1.0
    return (1.0 - alpha ** (gamma + 1)) / (1.0 - alpha)


def predict_point(
    cfg,
    hw: HardwareSpec,
    plan: ServePlan,
    *,
    mesh_model: int = 1,
    acceptance: float = 0.6,
) -> DesignPoint:
    """Cost one (plan, device, mesh) candidate on the three Pareto axes.

    Steady-state decode roofline (derivation + worked example in
    docs/PLANNER.md §Cost model):

    * memory   — weight stream (2 bytes/param / TP degree) + each slot's KV
      pages at the representative context (+ the dense gather tax when the
      fused kernel is off);
    * compute  — 2 FLOPs/param/row over decode_batch x (1 + gamma) rows;
    * ici      — one ring all-reduce of the slab activations per layer when
      the model axis is sharded;
    * step     — max of the three, plus dispatch overhead amortized over the
      rolled span;
    * tokens   — decode_batch x E[accepted | gamma, alpha] per step;
    * $/token  — n_chips x $/hr x step / tokens;
    * J/token  — per-op dynamic energy (tech-node table) + static TDP share,
      over emitted (not drafted) tokens: rejected draft rows burn real
      energy, which is exactly the tokens/s-vs-J/token trade the frontier
      exposes.  With no energy table the whole TDP is charged (power model).
    """
    ma = max(1, int(mesh_model))
    n_chips = ma
    mesh = {"data": 1, "model": ma}
    b = plan.decode_batch
    rows = b * (1 + plan.spec_len)
    p_active = cfg.param_count(active_only=True)

    # ---- feasibility: pool + weights must fit each chip's HBM. ----------
    weight_bytes_chip = 2.0 * p_active / ma
    pool_bytes_chip = (
        plan.n_blocks * plan.block_size * plan.kv_bytes_per_token / ma
    )
    if weight_bytes_chip + pool_bytes_chip > hw.hbm_bytes:
        return DesignPoint(
            hardware=hw.name, arch=cfg.name, mesh=mesh, plan=plan,
            tile="", tokens_per_s=0.0, usd_per_mtok=math.inf,
            mj_per_tok=math.inf, step_s=math.inf, tokens_per_step=0.0,
            bound="memory", feasible=False,
            reason="weights + KV pool exceed HBM",
        )
    if ma > 1 and hw.ici_bandwidth <= 0:
        return DesignPoint(
            hardware=hw.name, arch=cfg.name, mesh=mesh, plan=plan,
            tile="", tokens_per_s=0.0, usd_per_mtok=math.inf,
            mj_per_tok=math.inf, step_s=math.inf, tokens_per_step=0.0,
            bound="ici", feasible=False,
            reason="model-sharded mesh on a device with no interconnect",
        )

    # ---- per-step traffic / compute. ------------------------------------
    ctx = plan.max_seq_len * CTX_FRACTION
    kv_bytes_chip = b * ctx * plan.kv_bytes_per_token / ma
    if not plan.fused_attention:
        # gather fallback: dense write + re-read of the full-context cache
        kv_bytes_chip += 2.0 * b * plan.max_seq_len * plan.kv_bytes_per_token / ma
    mem_bytes_chip = weight_bytes_chip + kv_bytes_chip
    flops_chip = 2.0 * p_active / ma * rows
    ici_bytes_chip = 0.0
    if ma > 1:
        # one ring all-reduce of the (rows, d_model) activations per layer:
        # ring moves 2*(g-1)/g of the operand per chip
        operand = rows * cfg.d_model * 2.0 * cfg.n_layers
        ici_bytes_chip = 2.0 * operand * (ma - 1) / ma

    t_mem = mem_bytes_chip / hw.hbm_bandwidth if hw.hbm_bandwidth > 0 else math.inf
    t_compute = flops_chip / hw.peak_flops_bf16 if hw.peak_flops_bf16 > 0 else math.inf
    t_ici = ici_bytes_chip / hw.ici_bandwidth if ici_bytes_chip else 0.0
    terms = {"memory": t_mem, "compute": t_compute, "ici": t_ici}
    bound = max(terms, key=terms.get)
    t_step = max(t_mem, t_compute, t_ici) + hw.dispatch_overhead_s / max(
        plan.rolled_steps, 1
    )
    if not math.isfinite(t_step) or t_step <= 0:
        return DesignPoint(
            hardware=hw.name, arch=cfg.name, mesh=mesh, plan=plan,
            tile="", tokens_per_s=0.0, usd_per_mtok=math.inf,
            mj_per_tok=math.inf, step_s=math.inf, tokens_per_step=0.0,
            bound=bound, feasible=False,
            reason="unserviceable step (no off-chip bandwidth)",
        )

    tokens_per_step = b * expected_accepted(plan.spec_len, acceptance)
    tokens_per_s = tokens_per_step / t_step

    # ---- $/token. --------------------------------------------------------
    usd_per_tok = (
        n_chips * hw.dollars_per_hour / 3600.0 * t_step / tokens_per_step
    )

    # ---- J/token. --------------------------------------------------------
    ep = energy_params(hw)
    if ep:
        joules = (
            flops_chip * ma * ep.get("flop_bf16", 0.0) * 1e-12
            + mem_bytes_chip * ma * ep.get("mem_byte", 0.0) * 1e-12
            + ici_bytes_chip * ma * ep.get("ici_byte", 0.0) * 1e-12
            + hw.tdp_watts * ep.get("static_fraction", 0.3) * t_step * n_chips
        )
    else:
        joules = hw.tdp_watts * t_step * n_chips
    j_per_tok = joules / tokens_per_step

    from repro.core.autotune import resolve_serve_tile  # cycle-free: deferred

    tile = resolve_serve_tile(cfg, plan, hw)
    return DesignPoint(
        hardware=hw.name,
        arch=cfg.name,
        mesh=mesh,
        plan=plan,
        tile=f"{tile.name}({tile.block_m}x{tile.block_n}x{tile.block_k})",
        tokens_per_s=tokens_per_s,
        usd_per_mtok=usd_per_tok * 1e6,
        mj_per_tok=j_per_tok * 1e3,
        step_s=t_step,
        tokens_per_step=tokens_per_step,
        bound=bound,
        feasible=True,
    )


def dominates(a: DesignPoint, b: DesignPoint) -> bool:
    """a dominates b: no worse on every axis, strictly better on one.
    tokens/s is maximized; $/Mtok and mJ/tok are minimized."""
    ge = (
        a.tokens_per_s >= b.tokens_per_s
        and a.usd_per_mtok <= b.usd_per_mtok
        and a.mj_per_tok <= b.mj_per_tok
    )
    gt = (
        a.tokens_per_s > b.tokens_per_s
        or a.usd_per_mtok < b.usd_per_mtok
        or a.mj_per_tok < b.mj_per_tok
    )
    return ge and gt


def pareto_frontier(points: list[DesignPoint]) -> list[DesignPoint]:
    """Non-dominated subset of the feasible points, sorted by tokens/s
    (descending) for a stable, deterministic report order.  Metric-identical
    duplicates keep only their first (enumeration-order) representative."""
    feas = [p for p in points if p.feasible]
    seen: set[tuple] = set()
    unique = []
    for p in feas:
        key = (p.tokens_per_s, p.usd_per_mtok, p.mj_per_tok)
        if key in seen:
            continue
        seen.add(key)
        unique.append(p)
    frontier = [
        p for p in unique if not any(dominates(q, p) for q in unique if q is not p)
    ]
    frontier.sort(key=lambda p: (-p.tokens_per_s, p.usd_per_mtok, p.mj_per_tok))
    return frontier


@dataclasses.dataclass
class FamilyResult:
    """Everything one search produced: all costed candidates + the frontier."""

    arch: str
    hardware: str
    space: SearchSpace
    points: list[DesignPoint]
    frontier: list[DesignPoint]

    def to_record(self) -> dict:
        return {
            "arch": self.arch,
            "hardware": self.hardware,
            "max_seq_len": self.space.max_seq_len,
            "acceptance": self.space.acceptance,
            "n_candidates": len(self.points),
            "n_feasible": sum(p.feasible for p in self.points),
            "frontier": [p.to_record() for p in self.frontier],
        }

    def render_markdown(self) -> str:
        """The frontier as a markdown table (the dryrun --family report)."""
        head = (
            f"## Accelerator family: {self.arch} on {self.hardware}\n\n"
            f"{len(self.frontier)} non-dominated points "
            f"({sum(p.feasible for p in self.points)} feasible of "
            f"{len(self.points)} candidates; "
            f"max_seq={self.space.max_seq_len}, "
            f"alpha={self.space.acceptance})\n\n"
        )
        cols = (
            "| # | mesh | B | kv | gamma | K | slab | tile "
            "| tok/s | $/Mtok | mJ/tok | bound |\n"
            "|---|------|---|----|-------|---|------|------"
            "|-------|--------|--------|-------|\n"
        )
        rows = []
        for i, p in enumerate(self.frontier):
            s = p.plan
            rows.append(
                f"| {i} | {p.mesh['data']}x{p.mesh['model']} "
                f"| {s.decode_batch} | {s.kv_dtype} | {s.spec_len} "
                f"| {s.rolled_steps} | {s.mixed_slab_width} | {p.tile} "
                f"| {p.tokens_per_s:.0f} | {p.usd_per_mtok:.2f} "
                f"| {p.mj_per_tok:.2f} | {p.bound} |"
            )
        return head + cols + "\n".join(rows) + "\n"


def search_family(
    arch_or_cfg: Union[str, object],
    hw: Union[str, HardwareSpec],
    space: Optional[SearchSpace] = None,
) -> FamilyResult:
    """Sweep the space and return all costed points + the Pareto frontier.

    Pure function of (arch, hardware, space): candidates are enumerated in
    attribute order, plans that collide after derivation are deduplicated to
    their first spelling, and the frontier sort is total — two calls return
    identical results."""
    if isinstance(arch_or_cfg, str):
        from repro.configs import get_config

        cfg = get_config(arch_or_cfg)
    else:
        cfg = arch_or_cfg
    if isinstance(hw, str):
        hw = get_hardware(hw)
    ok, reason = serve_feasible(cfg)
    if not ok:
        raise ValueError(f"no serving family for {cfg.name}: {reason}")
    space = space or default_space(hw)

    points: list[DesignPoint] = []
    seen_plans: set[tuple] = set()
    for ma, batch, kv, bs, slab, ppt, gamma, rolled in itertools.product(
        space.mesh_models,
        space.decode_batches,
        space.kv_dtypes,
        space.block_sizes,
        space.slab_widths,
        space.pages_per_tile,
        space.spec_lens,
        space.rolled_steps,
    ):
        mesh = {"data": 1, "model": ma}
        try:
            plan = derive_serve_plan(
                cfg,
                mesh,
                hw,
                max_seq_len=space.max_seq_len,
                decode_batch=batch,
                kv_dtype=kv,
                block_size=bs,
                mixed_slab_width=slab,
                pages_per_tile=ppt,
                spec_len=gamma,
                rolled_steps=rolled,
                draft=space.draft if (gamma is None or gamma > 0) else "none",
            )
        except (ValueError, ZeroDivisionError, OverflowError):
            continue  # infeasible spelling; the space may legally contain it
        key = (ma, plan)
        if key in seen_plans:
            continue  # different spellings deriving the same plan
        seen_plans.add(key)
        points.append(
            predict_point(
                cfg, hw, plan, mesh_model=ma, acceptance=space.acceptance
            )
        )
    return FamilyResult(
        arch=cfg.name,
        hardware=hw.name,
        space=space,
        points=points,
        frontier=pareto_frontier(points),
    )


def family_report(
    arch: str,
    hardware: str,
    *,
    space: Optional[SearchSpace] = None,
    max_seq_len: int = 2048,
    out_dir: Optional[Union[str, pathlib.Path]] = None,
) -> tuple[FamilyResult, dict]:
    """The ``dryrun --family`` engine: search, write JSON, return markdown.

    Returns (result, record); ``record`` is what lands in
    ``<out_dir>/<hardware>__<arch>.json`` (record["markdown"] carries the
    rendered table so the artifact is self-contained)."""
    hw = get_hardware(hardware)
    if space is None:
        space = default_space(hw, max_seq_len=max_seq_len)
    result = search_family(arch, hw, space)
    record = result.to_record()
    record["markdown"] = result.render_markdown()
    if out_dir is not None:
        out = pathlib.Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        (out / f"{hw.name}__{arch}.json").write_text(
            json.dumps(record, indent=1, default=str)
        )
    return result, record
