"""Hardware description for the CAT planner.

The paper's "Intrinsic hardware parameters" (Table III) — AIE Window size,
PLIO bandwidth, core count, on-chip buffer — become the TPU-chip analogues
below.  Everything the planner decides is a pure function of
(ArchConfig, Mesh, HardwareSpec), which is the paper's top-down customization
contract: the underlying hardware and the upper model jointly constrain the
customizable attributes.

Since the family planner (core/search.py) the spec also carries the *cost*
side of the contract — TDP, rental price, and a per-op dynamic-energy table
keyed by tech node (the BCE-table idiom: a dict of per-node constants, each
device naming its node and optionally overriding single entries).  Devices
live in a registry: ``get_hardware`` resolves any registered name, and
variant devices (a bandwidth-doubled v5e, an int8-heavy VCK5000 analog) are
declarative ``HARDWARE_VARIANTS`` entries, not code.  Field-by-field
reference with the paper Table III analogies: docs/PLANNER.md.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip + interconnect constants of the target platform."""

    name: str
    # Compute (paper: AIE core count x per-core throughput).
    peak_flops_bf16: float  # FLOP/s per chip
    peak_ops_int8: float  # OP/s per chip
    # Memory hierarchy (paper: AIE Window / PL BRAM+URAM / DDR).
    vmem_bytes: int  # on-chip vector memory per chip  (AIE Window analog)
    hbm_bytes: int  # off-chip HBM capacity per chip   (DDR analog)
    hbm_bandwidth: float  # bytes/s per chip             (DDR bandwidth analog)
    # Interconnect (paper: PLIO / NoC).
    ici_bandwidth_per_link: float  # bytes/s per ICI link
    ici_links_per_chip: int  # links per chip on a torus axis pair
    # MXU native tile edge (paper: AIE vector instruction length, power of 2).
    mxu_dim: int = 128
    # Host dispatch overhead per device program launch (runtime call +
    # host-side scheduling between steps).  The paper's AIE pipeline streams
    # many iterations per host intervention precisely because this cost is
    # fixed per dispatch; the serving planner uses it to size the rolled
    # on-device decode loop (``ServePlan.rolled_steps``).
    dispatch_overhead_s: float = 100e-6
    # ---- Cost / energy (family-search axes; docs/PLANNER.md) --------------
    # Board/chip power envelope; with no per-op energy table the search
    # charges tdp_watts for the full step (power-model fallback).
    tdp_watts: float = 0.0
    # Rental/amortized price per chip-hour ($/token numerator).  0 = free
    # (the device never appears on the $/token axis).
    dollars_per_hour: float = 0.0
    # Tech node naming a row of ENERGY_PJ (per-op dynamic energy, the
    # BCE-table idiom).  "" = no table; the search falls back to TDP.
    tech_node: str = ""
    # Per-device overrides of single ENERGY_PJ entries, e.g. a DDR-attached
    # device re-pricing "mem_byte".  Tuple-of-pairs so the spec stays
    # hashable (plans ride as static jit arguments).
    energy_pj: tuple[tuple[str, float], ...] = ()

    @property
    def machine_balance_bf16(self) -> float:
        """FLOPs per HBM byte needed to stay compute bound (Eq. 4 analog;
        docs/ARCHITECTURE.md).  ``inf`` for a device with no off-chip
        bandwidth (degenerate SRAM-only variants): every tile is then
        bandwidth-starved and no shape is compute-bound."""
        if self.hbm_bandwidth <= 0:
            return math.inf
        return self.peak_flops_bf16 / self.hbm_bandwidth

    @property
    def ici_bandwidth(self) -> float:
        """Aggregate interconnect bytes/s per chip (0 = single device)."""
        return self.ici_bandwidth_per_link * self.ici_links_per_chip

    def matmul_time_s(self, m: int, n: int, k: int, dtype_bytes: int = 2) -> float:
        """Roofline time for one MxKxN matmul on one chip."""
        flops = 2.0 * m * n * k
        peak = self.peak_flops_bf16 if dtype_bytes >= 2 else self.peak_ops_int8
        t_compute = flops / peak if peak > 0 else math.inf
        bytes_moved = dtype_bytes * (m * k + k * n + m * n)
        t_memory = (
            bytes_moved / self.hbm_bandwidth if self.hbm_bandwidth > 0 else math.inf
        )
        return max(t_compute, t_memory)


# Per-op dynamic energy by tech node, picojoules (the lumos/BCE-table idiom:
# one table row per node, devices reference a row by name).  Values are
# order-of-magnitude engineering constants — bf16 MAC ~1 pJ/FLOP at 7 nm,
# HBM2e access ~4 pJ/bit, inter-chip serdes ~3x on-package DRAM — chosen so
# the *ratios* (compute vs memory vs wire, 7 nm vs 16 nm) are right; absolute
# J/token from the search is a model, not a measurement.  "static_fraction"
# is the share of TDP burned regardless of activity (leakage + clocks +
# uncore), charged per second of step time.
ENERGY_PJ: dict[str, dict[str, float]] = {
    "7nm": {
        "flop_bf16": 0.8,
        "op_int8": 0.2,
        "mem_byte": 35.0,
        "ici_byte": 90.0,
        "static_fraction": 0.35,
    },
    # Dennard-scaled ancestor node for what-if variants: dynamic energy
    # roughly 2.2x the 7 nm row, leakier static share.
    "16nm": {
        "flop_bf16": 1.8,
        "op_int8": 0.45,
        "mem_byte": 40.0,
        "ici_byte": 110.0,
        "static_fraction": 0.45,
    },
}


def energy_params(hw: HardwareSpec) -> dict[str, float]:
    """Resolved per-op energy table for a device: its tech-node row overlaid
    with the device's own ``energy_pj`` overrides.  Empty dict = no table
    (callers fall back to the TDP power model)."""
    table = dict(ENERGY_PJ.get(hw.tech_node, {}))
    table.update(dict(hw.energy_pj))
    return table


# TPU v5e constants per the task spec (197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI); VMEM/HBM capacities are the public v5e numbers.
# TDP and $/hr are public-ballpark serving figures (docs/PLANNER.md).
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_ops_int8=394e12,
    vmem_bytes=128 * 1024 * 1024,
    hbm_bytes=16 * 1024**3,
    hbm_bandwidth=819e9,
    ici_bandwidth_per_link=50e9,
    ici_links_per_chip=4,
    tdp_watts=215.0,
    dollars_per_hour=1.20,
    tech_node="7nm",
)

# The paper's platform, kept for the Table VI/VII benchmark analogs
# (VCK5000: 400 AIE cores, 145 TOPS int8, 23.9 MB SRAM @ 23.5 TB/s,
#  16 GB DDR @ 102.4 GB/s; Versal ACAP is TSMC 7 nm).  DDR4 access energy
# is far above HBM, hence the per-device "mem_byte" override.
VCK5000 = HardwareSpec(
    name="vck5000",
    peak_flops_bf16=145e12 / 4,  # no native bf16 MM at full rate; int8 is the paper's mode
    peak_ops_int8=145e12,
    vmem_bytes=int(23.9e6),
    hbm_bytes=16 * 1024**3,
    hbm_bandwidth=102.4e9,
    ici_bandwidth_per_link=0.0,  # single device
    ici_links_per_chip=0,
    tdp_watts=225.0,
    dollars_per_hour=0.35,  # card price amortized over ~3y of service
    tech_node="7nm",
    energy_pj=(("mem_byte", 150.0),),
)

DEFAULT_HARDWARE = TPU_V5E

_REGISTRY: dict[str, HardwareSpec] = {}


def register_hardware(spec: HardwareSpec) -> HardwareSpec:
    """Add a device to the registry ``get_hardware`` resolves.  Re-registering
    a name replaces it (tests register throwaway variants)."""
    _REGISTRY[spec.name] = spec
    return spec


def register_variant(name: str, base: str, **fields) -> HardwareSpec:
    """Declare a device variant: the ``base`` spec with ``fields`` replaced.

    This is how the family search gets its hardware axis — a variant is
    data, not a subclass (docs/PLANNER.md "Adding a device variant")."""
    return register_hardware(
        dataclasses.replace(get_hardware(base), name=name, **fields)
    )


def get_hardware(name: str) -> HardwareSpec:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown hardware {name!r}; registered: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name]


def registered_hardware() -> tuple[str, ...]:
    """Names the family search can sweep (sorted, deterministic)."""
    return tuple(sorted(_REGISTRY))


register_hardware(TPU_V5E)
register_hardware(VCK5000)

# Variant devices as declarative data: (base, replaced fields).  Each is an
# analytic what-if the family search can answer — "what does the frontier
# look like if HBM keeps up / on a cheaper serving bin / with the paper's
# int8 mode doubled" — not a claim about a shipping SKU.
HARDWARE_VARIANTS: dict[str, tuple[str, dict]] = {
    # Bandwidth-doubled v5e: decode is weight-stream-bound, so this is the
    # highest-leverage single knob for tokens/s.
    "tpu_v5e-hbm2x": (
        "tpu_v5e",
        dict(hbm_bandwidth=1638e9, tdp_watts=240.0, dollars_per_hour=1.45),
    ),
    # Serving-binned v5e: half the MXU clock, ~2/3 power, ~half price —
    # decode rarely misses the FLOPs, the $/token axis does notice.
    "tpu_v5e-lite": (
        "tpu_v5e",
        dict(
            peak_flops_bf16=98.5e12,
            peak_ops_int8=197e12,
            tdp_watts=150.0,
            dollars_per_hour=0.65,
        ),
    ),
    # Int8-heavy VCK5000 analog: the paper's int8 deployment mode with the
    # AIE array doubled toward int8 MACs.
    "vck5000-int8w": (
        "vck5000",
        dict(peak_ops_int8=290e12, tdp_watts=300.0),
    ),
}

for _name, (_base, _delta) in HARDWARE_VARIANTS.items():
    register_variant(_name, _base, **_delta)
