"""Hardware description for the CAT planner.

The paper's "Intrinsic hardware parameters" (Table III) — AIE Window size,
PLIO bandwidth, core count, on-chip buffer — become the TPU-chip analogues
below.  Everything the planner decides is a pure function of
(ArchConfig, Mesh, HardwareSpec), which is the paper's top-down customization
contract: the underlying hardware and the upper model jointly constrain the
customizable attributes.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip + interconnect constants of the target platform."""

    name: str
    # Compute (paper: AIE core count x per-core throughput).
    peak_flops_bf16: float  # FLOP/s per chip
    peak_ops_int8: float  # OP/s per chip
    # Memory hierarchy (paper: AIE Window / PL BRAM+URAM / DDR).
    vmem_bytes: int  # on-chip vector memory per chip  (AIE Window analog)
    hbm_bytes: int  # off-chip HBM capacity per chip   (DDR analog)
    hbm_bandwidth: float  # bytes/s per chip             (DDR bandwidth analog)
    # Interconnect (paper: PLIO / NoC).
    ici_bandwidth_per_link: float  # bytes/s per ICI link
    ici_links_per_chip: int  # links per chip on a torus axis pair
    # MXU native tile edge (paper: AIE vector instruction length, power of 2).
    mxu_dim: int = 128
    # Host dispatch overhead per device program launch (runtime call +
    # host-side scheduling between steps).  The paper's AIE pipeline streams
    # many iterations per host intervention precisely because this cost is
    # fixed per dispatch; the serving planner uses it to size the rolled
    # on-device decode loop (``ServePlan.rolled_steps``).
    dispatch_overhead_s: float = 100e-6

    @property
    def machine_balance_bf16(self) -> float:
        """FLOPs per HBM byte needed to stay compute bound (Eq. 4 analog;
        docs/ARCHITECTURE.md)."""
        return self.peak_flops_bf16 / self.hbm_bandwidth

    def matmul_time_s(self, m: int, n: int, k: int, dtype_bytes: int = 2) -> float:
        """Roofline time for one MxKxN matmul on one chip."""
        flops = 2.0 * m * n * k
        peak = self.peak_flops_bf16 if dtype_bytes >= 2 else self.peak_ops_int8
        t_compute = flops / peak
        bytes_moved = dtype_bytes * (m * k + k * n + m * n)
        t_memory = bytes_moved / self.hbm_bandwidth
        return max(t_compute, t_memory)


# TPU v5e constants per the task spec (197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI); VMEM/HBM capacities are the public v5e numbers.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_ops_int8=394e12,
    vmem_bytes=128 * 1024 * 1024,
    hbm_bytes=16 * 1024**3,
    hbm_bandwidth=819e9,
    ici_bandwidth_per_link=50e9,
    ici_links_per_chip=4,
)

# The paper's platform, kept for the Table VI/VII benchmark analogs
# (VCK5000: 400 AIE cores, 145 TOPS int8, 23.9 MB SRAM @ 23.5 TB/s,
#  16 GB DDR @ 102.4 GB/s).
VCK5000 = HardwareSpec(
    name="vck5000",
    peak_flops_bf16=145e12 / 4,  # no native bf16 MM at full rate; int8 is the paper's mode
    peak_ops_int8=145e12,
    vmem_bytes=int(23.9e6),
    hbm_bytes=16 * 1024**3,
    hbm_bandwidth=102.4e9,
    ici_bandwidth_per_link=0.0,  # single device
    ici_links_per_chip=0,
)

DEFAULT_HARDWARE = TPU_V5E


def get_hardware(name: str) -> HardwareSpec:
    table = {"tpu_v5e": TPU_V5E, "vck5000": VCK5000}
    if name not in table:
        raise KeyError(f"unknown hardware {name!r}; have {sorted(table)}")
    return table[name]
