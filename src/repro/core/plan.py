"""The CAT customization strategy: derive an accelerator instance from
(model config, mesh, hardware).

Paper §IV: three customizable attributes are decided top-down —
  1. AIE MM PU scale        -> per-MM-site Pallas tile specs (core/pu.py)
  2. Parallel mode (Eq.5/6) -> SPATIAL (TP, fully-pipelined analog) vs
                               TEMPORAL (ZeRO-DP, serial-using-all-resources
                               analog), plus remat/microbatch from Factor2'
  3. ATB parallelism (Eq.7/8) -> attention head-shard degree P_ATB

The plan is a frozen dataclass: a pure function of its inputs, hashable, and
used as a static argument of jitted step functions.  `design_case_vck5000`
reproduces the paper's §V.B BERT-Base walk-through numbers (Factor1 ~= 1.5,
Factor2 ~= 7.56 MB) on the paper's own hardware constants.

The plan is the system's control plane: every field here is consumed by an
executor — `dist.sharding.Shardings` (specs), `train/train_step.py`
(microbatching, gradient wire format, pipeline routing), and
`models/transformer.py` (SP layer stack).  Paper-to-code map with the
equation cross-references: docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

from repro.core.hardware import DEFAULT_HARDWARE, VCK5000, HardwareSpec
from repro.core.pu import MMTileSpec, derive_pu_family, pick_pu

# Paper constant: EDPU pipeline has at most 4 PRGs in flight per stage.
PRG_MAX_PIPELINE_DEPTH = 4

SPATIAL = "spatial"  # paper parallel mode (1): fully-pipelined, sliced fabric
TEMPORAL = "temporal"  # paper parallel mode (2): serial PRGs, each uses all chips


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """Per-stage (MHA / FFN) decision record."""

    mode: str  # SPATIAL | TEMPORAL
    factor1: float  # Eq.5/6 Factor1 analog (diagnostic, logged)
    factor2_bytes: int  # Eq.5/6 Factor2 analog: activation bytes/chip, no remat
    pu: MMTileSpec  # MM PU spec chosen for this stage's dominant MM


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The derived accelerator instance for one (arch x mesh x shape)."""

    arch: str
    mesh_axes: tuple[tuple[str, int], ...]  # e.g. (("data",16),("model",16))
    mha: StagePlan
    ffn: StagePlan
    # C5: Independent-Linear — aggregate per-head QKV into one MM.
    fuse_qkv: bool
    # C4/P_ATB: attention-block parallel degree (heads consumed in parallel).
    p_atb: int
    # Head sharding degree over the model axis (0 = heads not sharded).
    head_shards: int
    # Activation checkpointing + gradient accumulation (Factor2' outcome).
    remat: bool
    microbatches: int
    # Embedding partition dim: "vocab" | "embed" | "replicated".
    embed_shard: str
    # MoE execution mode: "ep" (experts sharded) | "tp" (d_ff sharded) | "none".
    moe_mode: str
    # Sequence parallelism for long-context cells (batch < data axis).
    seq_shard: bool
    # MoE dispatch algorithm: "gshard" grouped-einsum (baseline) | "sort".
    moe_dispatch: str = "gshard"
    # TEMPORAL mode folds the model axis into data parallelism (FSDP): the
    # paper's "each PRG uses ALL compute resources in turn" — without this
    # the model-axis chips would duplicate work (16/17 of FLOPs wasted).
    dp_over_model: bool = False
    # ZeRO/FSDP hybrid: weights + optimizer state also sharded over `data`
    # (needed when 12B/param x params / model_axis exceeds HBM).
    zero_weights: bool = False
    # Megatron-style sequence parallelism: the residual stream (and thus every
    # remat-saved layer input) is sharded over `model` on the seq dim.
    seq_parallel_acts: bool = False
    # Pod-axis role: "data" (extra DP) or "pipeline" (multi-EDPU pipelining, C9).
    # "pipeline" routes launch/train.py through dist.pipeline.pipeline_forward:
    # layer-groups slice over the pod axis, microbatches flow stage-to-stage.
    pod_role: str = "data"
    # Gradient exchange wire format ("none" | "bf16" | "int8").  When set, the
    # train step swaps GSPMD's fp32 gradient all-reduce for the shard_map
    # dist.collectives.compressed_psum exchange (compression happens once, on
    # the wire); when the mesh cannot host that path the same mode falls back
    # to train/compression.py's accumulation-dtype quantization.
    grad_compression: str = "none"

    @property
    def model_axis(self) -> int:
        return dict(self.mesh_axes).get("model", 1)

    @property
    def data_axis(self) -> int:
        return dict(self.mesh_axes).get("data", 1)

    @property
    def pod_axis(self) -> int:
        return dict(self.mesh_axes).get("pod", 1)

    def mode_for(self, stage: str) -> str:
        """Parallel mode the dist sharder executes for a stage ("mha"|"ffn").

        When dp_over_model folds the model axis into data parallelism the
        whole network runs TEMPORAL regardless of per-stage feasibility —
        the model axis is occupied by batch and cannot also carry TP.
        """
        if self.dp_over_model:
            return TEMPORAL
        if stage == "mha":
            return self.mha.mode
        if stage == "ffn":
            return self.ffn.mode
        raise KeyError(f"unknown stage {stage!r}; expected 'mha' or 'ffn'")

    def describe(self) -> str:
        rows = [
            f"accelerator instance for {self.arch}",
            f"  mesh            : {dict(self.mesh_axes)} (pod role: {self.pod_role})",
            f"  MHA stage       : mode={self.mha.mode} factor1={self.mha.factor1:.3f} "
            f"factor2={self.mha.factor2_bytes/1e6:.1f}MB pu={self.mha.pu.name}"
            f"({self.mha.pu.block_m}x{self.mha.pu.block_n}x{self.mha.pu.block_k})",
            f"  FFN stage       : mode={self.ffn.mode} factor1={self.ffn.factor1:.3f} "
            f"factor2={self.ffn.factor2_bytes/1e6:.1f}MB pu={self.ffn.pu.name}"
            f"({self.ffn.pu.block_m}x{self.ffn.pu.block_n}x{self.ffn.pu.block_k})",
            f"  fuse_qkv (C5)   : {self.fuse_qkv}",
            f"  P_ATB (C4)      : {self.p_atb} (head_shards={self.head_shards})",
            f"  remat/microbatch: {self.remat}/{self.microbatches}",
            f"  embed shard     : {self.embed_shard}   moe: {self.moe_mode}"
            f"   seq_shard: {self.seq_shard}",
            f"  seq-parallel/SP : {self.seq_parallel_acts}"
            f"   grad wire: {self.grad_compression}",
        ]
        return "\n".join(rows)


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0 and cap % d == 0:
            return d
    return 1


def derive_plan(
    cfg,
    mesh_shape: Mapping[str, int],
    hw: HardwareSpec = DEFAULT_HARDWARE,
    *,
    batch: int = 8,
    seq_len: int = 2048,
    training: bool = True,
    fuse_qkv: Optional[bool] = None,
    force_mode: Optional[str] = None,
    pod_role: str = "data",
    dtype_bytes: int = 2,
    moe_dispatch: str = "gshard",
    seq_parallel: Optional[bool] = None,
    grad_compression: str = "none",
) -> ExecutionPlan:
    """Top-down derivation (paper §IV): hardware + model jointly decide."""
    ma = mesh_shape.get("model", 1)
    da = mesh_shape.get("data", 1)
    family = derive_pu_family(hw, dtype_bytes)

    # ---- Eq.5 Factor1 (MHA): LB MM scale / engine one-shot MM scale. -------
    lb_mm_volume = 4.0 * seq_len * cfg.d_model * cfg.n_heads * cfg.d_head
    engine_volume = float(ma) * family["LARGE"].block_m * family[
        "LARGE"
    ].block_n * family["LARGE"].block_k
    mha_factor1 = lb_mm_volume / engine_volume

    # ---- Eq.6 Factor1 (FFN). ------------------------------------------------
    ffn_volume = 2.0 * seq_len * cfg.d_model * cfg.d_ff
    ffn_factor1 = ffn_volume / engine_volume

    # ---- GSPMD divisibility (needed by Factor2' and the mode decision). ----
    heads_div = cfg.n_heads % ma == 0 and (cfg.n_kv_heads % ma == 0 or cfg.n_kv_heads < ma)
    ffn_shard_w = cfg.effective_ff_width()
    ffn_div = ffn_shard_w % ma == 0 and (ffn_shard_w // ma) >= hw.mxu_dim
    tp_feasible = heads_div and cfg.d_model % ma == 0

    # ---- Factor2': activation bytes per chip if nothing is rematerialized. --
    tokens = batch * seq_len
    tokens_per_chip = tokens / max(da, 1)
    width_frac = 1.0 / ma  # hidden sharded over model axis in SPATIAL mode
    qkv_width = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.d_head
    mha_act = tokens_per_chip * (cfg.d_model + qkv_width + cfg.n_heads * cfg.d_head)
    ffn_act = tokens_per_chip * (cfg.d_model + 2 * cfg.effective_ff_width())
    mha_factor2 = int(mha_act * dtype_bytes * width_frac * cfg.n_layers)
    ffn_factor2 = int(ffn_act * dtype_bytes * width_frac * cfg.n_layers)
    # Attention probabilities (fp32) are the big saved residual without remat:
    # tokens_per_chip x kv-extent x heads x 4B per attention layer.
    attn_layers = sum(
        1 for i in range(cfg.n_layers) if cfg.layer_kind(i) in ("attn", "swa", "local")
    )
    eff_kv = min(seq_len, cfg.sliding_window or seq_len)
    probs = tokens_per_chip * eff_kv * cfg.n_heads * 4.0
    mha_factor2 += int(probs * attn_layers / (ma if tp_feasible else 1))

    # The batch can fold over the model axis (TEMPORAL -> FSDP, no duplicate
    # compute) only when it divides the full dp extent.
    can_fold = batch % max(da * ma, 1) == 0 and batch >= da

    def decide(factor1: float, factor2: int, feasible: bool) -> str:
        if force_mode:
            return force_mode
        return SPATIAL if feasible else TEMPORAL

    mha_mode = decide(mha_factor1, mha_factor2, tp_feasible)
    ffn_mode = decide(ffn_factor1, ffn_factor2, ffn_div and cfg.d_model % ma == 0)

    # Paper Eq.5/6 restored (§Perf iteration 6): when the model's MM scale
    # dwarfs the engine's one-shot scale (Factor1 >= PRG depth), the paper
    # picks mode (2) — serial, each PRG using ALL compute.  On TPU that is
    # FSDP with the model axis folded into DP.  Measured on
    # mistral-large/train_4k: collective 112s -> (see EXPERIMENTS §Perf).
    # My earlier "spatial always wins when divisible" deviation was wrong for
    # compute-huge dense models.  MoE keeps its spatial/EP FFN (expert
    # weights are consumed by few tokens each — gathering them all per layer
    # would not amortize).
    if (
        training
        and not cfg.is_moe
        and can_fold
        and force_mode is None
        and max(mha_factor1, ffn_factor1) >= PRG_MAX_PIPELINE_DEPTH
    ):
        mha_mode = TEMPORAL
        ffn_mode = TEMPORAL

    seq_shard = batch % max(da, 1) != 0 or batch < da
    dp_over_model = (
        mha_mode == TEMPORAL
        and ffn_mode == TEMPORAL
        and not seq_shard
        and batch % max(da * ma, 1) == 0
    )

    # ---- P_ATB (Eq.7/8): heads consumed in parallel per fused-QKV output. --
    head_shards = _largest_divisor_leq(cfg.n_heads, ma) if mha_mode == SPATIAL else 1
    if cfg.n_heads % max(head_shards, 1):
        head_shards = 1
    p_atb = max(1, cfg.n_heads // max(head_shards, 1))

    # ---- PU selection per stage (C2). ---------------------------------------
    mha_m = seq_len * batch // max(da, 1)
    mha_pu = pick_pu(mha_m, qkv_width // max(head_shards, 1), cfg.d_model, hw, dtype_bytes)
    ffn_pu = pick_pu(
        mha_m,
        max(ffn_shard_w // (ma if ffn_mode == SPATIAL else 1), hw.mxu_dim),
        cfg.d_model,
        hw,
        dtype_bytes,
    )

    # ---- Factor2' outcome: ZeRO weights + remat + microbatches. -------------
    # Optimizer state (bf16 params + fp32 m/v + grad ~ 12B/param when
    # training; just the bf16 weights when serving) sharded over the model
    # axis only can exceed HBM for 100B-class models: shard the complementary
    # weight dim over `data` too (ZeRO/FSDP hybrid; for decode the act
    # all-reduces at tiny batch are ~free, so 2-D weight sharding is pure win).
    bytes_per_param = 12.0 if training else float(dtype_bytes)
    param_bytes_model_only = cfg.param_count() * bytes_per_param / ma
    # Inference threshold is deliberately high (§Perf cell-3 iteration): 2-D
    # weight sharding at decode forces per-token weight all-gathers over
    # `data` (measured 70x step-time regression on mistral decode when
    # applied below need).  Only shard 2-D when weights would not otherwise
    # fit; the designed answer for capacity-tight serving is the int8
    # mm_pu path (the paper's own Int8 deployment mode).
    zero_weights = param_bytes_model_only > (0.35 if training else 1.0) * hw.hbm_bytes
    param_bytes = param_bytes_model_only / (da if zero_weights else 1)
    act_budget = max(hw.hbm_bytes - param_bytes, hw.hbm_bytes * 0.25)
    total_act = mha_factor2 + ffn_factor2
    remat = training and total_act > 0.25 * act_budget
    # §Perf iteration log: Megatron-SP via a pjit sharding constraint alone
    # was REFUTED twice on mistral-large (112s -> 144s collective at micro=2;
    # 935s at micro=16 — GSPMD thrashes between seq-sharded residuals and
    # gathered attention inputs).  Proper SP needs shard_map-manual
    # collectives — which models/transformer.sp_stack_forward now supplies
    # (ring-overlap gather-matmul + reduce-scatter; docs/ARCHITECTURE.md
    # §"Megatron-SP").  The flag therefore stays opt-in (``seq_parallel=``)
    # rather than auto-derived, and only engages on meshes/models the manual
    # path supports: every projection must column/row-shard evenly and the
    # sequence must split over the model axis.
    sp_feasible = (
        ma > 1
        and not cfg.is_moe
        and not cfg.enc_dec
        and all(k in ("attn", "swa", "local") for k in cfg.layer_pattern)
        and mha_mode == SPATIAL
        and ffn_mode == SPATIAL
        and cfg.n_heads % ma == 0
        and cfg.n_kv_heads % ma == 0
        and seq_len % ma == 0
        and cfg.effective_ff_width() % ma == 0
        and not seq_shard
        and not zero_weights  # manual ring assumes whole column/row shards
    )
    seq_parallel_acts = bool(seq_parallel) and sp_feasible
    if seq_parallel_acts:
        # The manual ring needs per-projection column shards: a fused
        # (q|k|v) column split would hand each device a q/k/v mix.
        fuse_qkv = False
    # remat-saved layer inputs.  NOTE §Perf iteration log: crediting SP with
    # a /model_axis here (and so cutting microbatches 16->2) was REFUTED on
    # mistral-large — per-microbatch transients grew 8x and temp went 26->35
    # GB.  The SP constraint stays, the memory credit does not.
    saved_per_layer = tokens_per_chip * cfg.d_model * dtype_bytes
    resid = saved_per_layer * cfg.n_layers
    # per-microbatch global batch must stay divisible by the DP extent,
    # otherwise GSPMD replicates tokens (measured: 21x FLOPs waste).
    dp_total = da * (ma if dp_over_model else 1)
    micro_cap = max(1, batch // max(dp_total, 1))
    microbatches = 1
    while (
        training
        and resid / microbatches > 0.5 * act_budget
        and microbatches * 2 <= micro_cap
        and batch % (microbatches * 2) == 0
    ):
        microbatches *= 2

    # Pipeline pods need the pipe *full*: with M microbatches over S stages
    # the GPipe bubble is (S-1)/(M+S-1) (dist.pipeline.bubble_fraction), so
    # raise M to the largest batch divisor <= 4*S — 4x stages pushes the
    # bubble under ~1/5 while per-microbatch memory stays a plan-visible
    # trade (docs/ARCHITECTURE.md §"Pod axis").
    pa = mesh_shape.get("pod", 1)
    if training and pod_role == "pipeline" and pa > 1:
        for cand in range(min(batch, 4 * pa), microbatches, -1):
            # the microbatch must still fold over the data axis — token
            # replication across DP replicas (measured 21x FLOPs waste)
            # is worse than any bubble, so no fallback: an unfillable
            # pipe fails loudly in check_pipeline_supported instead.
            if batch % cand == 0 and cand >= pa and (batch // cand) % max(da, 1) == 0:
                microbatches = cand
                break

    # ---- Embedding + MoE + sequence sharding. -------------------------------
    if cfg.vocab_size % ma == 0:
        embed_shard = "vocab"
    elif cfg.d_model % ma == 0:
        embed_shard = "embed"
    else:
        embed_shard = "replicated"
    if cfg.n_experts > 1:
        if cfg.n_experts % ma == 0:
            moe_mode = "ep"
        elif cfg.moe_d_ff % ma == 0 and cfg.moe_d_ff // ma >= hw.mxu_dim:
            moe_mode = "tp"
        else:
            moe_mode = "none"
    else:
        moe_mode = "none"
    # C5 (Independent-Linear): fused QKV everywhere.  §Perf iteration log:
    # the hypothesis that a fused (q+2kv) column shard misaligned with GQA
    # boundaries causes resharding all-reduces was REFUTED on
    # mistral-large/train_4k — splitting the projections replaced XLA's cheap
    # collective-permutes (341 GB) with all-reduces (+567 GB): keep fused.
    if fuse_qkv is None:
        fuse_qkv = cfg.fused_qkv_ok()

    return ExecutionPlan(
        arch=cfg.name,
        mesh_axes=tuple(sorted(mesh_shape.items())),
        mha=StagePlan(mha_mode, mha_factor1, mha_factor2, mha_pu),
        ffn=StagePlan(ffn_mode, ffn_factor1, ffn_factor2, ffn_pu),
        fuse_qkv=fuse_qkv,
        p_atb=p_atb,
        head_shards=head_shards,
        remat=remat,
        microbatches=max(1, microbatches),
        embed_shard=embed_shard,
        moe_mode=moe_mode,
        moe_dispatch=moe_dispatch,
        seq_shard=seq_shard,
        dp_over_model=dp_over_model,
        zero_weights=zero_weights,
        seq_parallel_acts=seq_parallel_acts,
        pod_role=pod_role,
        grad_compression=grad_compression,
    )


# ---------------------------------------------------------------------------
# Serve mode: the plan layer for the continuous-batching engine.
#
# CAT is an *inference* framework — the same top-down contract that decides
# the training mesh (hardware + model jointly constrain) decides the serving
# knobs: how many decode slots run concurrently, how the paged KV cache is
# blocked, and what dtype the KV pages hold.  `serve/engine.py` executes
# these decisions; `launch/serve.py` and the dry-run surface them.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServePlan:
    """Derived serving configuration for one (arch x mesh x hardware).

    Frozen + hashable so it can ride as a static argument of the jitted
    prefill/decode steps exactly like :class:`ExecutionPlan`.
    """

    arch: str
    # Concurrent decode slots (the engine's static decode batch).
    decode_batch: int
    # Paged KV cache geometry: tokens per block / pool blocks per attention
    # layer / table width per request.  Block 0 is the trash block (writes
    # from idle slots land there), so the allocatable pool is n_blocks - 1.
    block_size: int
    n_blocks: int
    max_blocks_per_seq: int
    # KV page dtype: "bf16" | "int8" | "fp32" (int8 reuses
    # train/compression.quantize on a per-token, per-head grid).
    kv_dtype: str
    # Tokens per prefill chunk (derivation target for the mixed-slab width).
    prefill_chunk: int
    # Serving context bound: block tables cover exactly this many positions.
    max_seq_len: int
    # Width of the unified mixed prefill/decode slab: every slot owns this
    # many query rows per step (decode uses 1, a prefill chunk up to all of
    # them).  Wider slabs prefill faster but pay dead rows while decoding.
    mixed_slab_width: int = 1
    # KV pages streamed into one VMEM tile per kernel grid step (the fused
    # paged-attention kernel's tile height), from the VMEM budget.
    pages_per_tile: int = 1
    # Attention engine of the unified step: the fused Pallas paged-attention
    # kernel (True) vs the dense gather-then-attend fallback (False).  The
    # roofline charges the fallback its gather bytes, so this knob feeds the
    # decode-batch derivation too.
    fused_attention: bool = True
    # Rolled on-device decode loop: max decode iterations per host dispatch
    # (K).  One host round-trip costs ``hw.dispatch_overhead_s`` regardless
    # of how much work it launches — the CAT/EA4RCA communication-avoiding
    # argument — so at small decode batch (steps are short, overhead is a
    # large fraction) the engine rolls K sampling+repack+length-advance
    # iterations into ONE ``lax.while_loop`` dispatch.  Derived so the
    # dispatch overhead amortizes below ~10% of the rolled span; 1 disables
    # rolling (every step is a host round-trip, the pre-rolled contract).
    # The scheduler still chooses the *actual* K per dispatch from the
    # event horizon (next admission/prefill/speculation/growth boundary),
    # bounded above by this plan cap.
    rolled_steps: int = 1
    # Speculative decoding: draft depth per decode slot (gamma).  A
    # speculating slot submits spec_len drafted tokens + its real one as
    # gamma+1 slab rows — mechanically a prefill chunk — and the host keeps
    # the longest draft prefix matching the step's greedy argmax.  0 = off.
    # Derived from the roofline's compute-vs-bandwidth slack: decode is
    # bandwidth-bound, so the MXU has (machine_balance / decode_batch) rows
    # of free compute per slot before verification itself would go
    # compute-bound (then gamma stays 0).  Always <= mixed_slab_width - 1.
    spec_len: int = 0
    # Draft source label: "none" | "ngram" (prompt-lookup self-drafting) |
    # a config name (model drafting, e.g. "smollm-135m").  The engine takes
    # the actual DraftSource object; the plan records the decision.
    draft: str = "none"
    # Copy-on-write prefix sharing: the scheduler keeps a radix index over
    # resident token prefixes and admits prefix-hit requests with shared
    # (refcounted) blocks + only the divergent tail as prefill.  Greedy
    # outputs are byte-identical either way (KV pages are a pure function
    # of the token prefix); the knob exists for A/B accounting and as the
    # escape hatch, not because sharing changes results.
    prefix_sharing: bool = True
    # Fleet-default TTFT target (ms) that shaped this plan, when one did:
    # the derivation widens the mixed slab so a typical prompt prefils
    # within the target and reins in gamma (draft rows compete with prompt
    # chunks for slab width).  Per-request targets on ``Request.slo_ttft_ms``
    # drive the scheduler's runtime chunk sizing; this field records the
    # planning-time decision.  None = throughput-shaped plan.
    slo_ttft_ms: Optional[float] = None
    # --- robustness knobs (fault-tolerance ladder; see docs/ROBUSTNESS.md) ---
    # Fleet-default wall-clock deadline (ms from submit) after which a
    # request is cancelled and its blocks/radix refs released; per-request
    # ``Request.deadline_ms`` overrides.  None = no deadline.
    deadline_ms: Optional[float] = None
    # Transient-dispatch retries per ladder rung before stepping down
    # rolled-K -> K=1 mixed -> eager gather fallback (then giving up).
    retry_limit: int = 3
    # Base for the exponential retry backoff: sleep backoff * 2^(attempt-1)
    # seconds (capped at 0.25 s) between retries.  Tests set it to 0.
    retry_backoff_s: float = 0.001
    # Consecutive healthy dispatches before the engine climbs one rung
    # back up the ladder.
    ladder_recovery: int = 32
    # Iterations an *arrived* request may sit admission-blocked (pool or
    # slot saturation) before it is shed with a retry-after hint, instead
    # of livelocking behind eviction.
    admission_patience: int = 128
    # Consecutive no-progress engine iterations (no tokens, no admission,
    # no completion) before ``run()`` raises StallError carrying health().
    stall_limit: int = 256
    # Consecutive quarantined (non-finite logits) steps for one slot before
    # the request is cancelled as poisoned rather than replayed again.
    quarantine_limit: int = 8
    # Diagnostics (logged + dryrun records).
    kv_bytes_per_token: int = 0
    hbm_kv_budget_bytes: int = 0

    @property
    def max_concurrency(self) -> int:
        """Requests the block pool can hold at full context length."""
        return (self.n_blocks - 1) // self.max_blocks_per_seq

    def describe(self) -> str:
        return (
            f"serve plan for {self.arch}: decode_batch={self.decode_batch} "
            f"block_size={self.block_size} n_blocks={self.n_blocks} "
            f"kv_dtype={self.kv_dtype} prefill_chunk={self.prefill_chunk} "
            f"slab={self.mixed_slab_width} pages/tile={self.pages_per_tile} "
            f"fused={self.fused_attention} rolled_steps={self.rolled_steps} "
            f"spec_len={self.spec_len} "
            f"draft={self.draft} prefix_sharing={self.prefix_sharing} "
            f"slo_ttft_ms={self.slo_ttft_ms} max_seq={self.max_seq_len} "
            f"kv_bytes/token={self.kv_bytes_per_token}"
        )

    def to_record(self) -> dict:
        """Flat dict for dryrun / benchmark JSON records."""
        return {
            "decode_batch": self.decode_batch,
            "block_size": self.block_size,
            "n_blocks": self.n_blocks,
            "max_blocks_per_seq": self.max_blocks_per_seq,
            "kv_dtype": self.kv_dtype,
            "prefill_chunk": self.prefill_chunk,
            "mixed_slab_width": self.mixed_slab_width,
            "pages_per_tile": self.pages_per_tile,
            "fused_attention": self.fused_attention,
            "rolled_steps": self.rolled_steps,
            "spec_len": self.spec_len,
            "draft": self.draft,
            "prefix_sharing": self.prefix_sharing,
            "slo_ttft_ms": self.slo_ttft_ms,
            "deadline_ms": self.deadline_ms,
            "retry_limit": self.retry_limit,
            "retry_backoff_s": self.retry_backoff_s,
            "ladder_recovery": self.ladder_recovery,
            "admission_patience": self.admission_patience,
            "stall_limit": self.stall_limit,
            "quarantine_limit": self.quarantine_limit,
            "max_seq_len": self.max_seq_len,
            "kv_bytes_per_token": self.kv_bytes_per_token,
        }


def serve_feasible(cfg) -> tuple[bool, str]:
    """Can the continuous-batching engine host this arch?

    The paged path needs per-slot positions (rope/none) and a pure-attention
    layer stack (recurrent state is O(1)/request and needs no paging; those
    archs stay on the eager ``greedy_generate`` path for now).
    """
    if cfg.enc_dec or cfg.frontend != "none":
        return False, "enc-dec/frontend archs keep non-stack state"
    if not all(k in ("attn", "swa", "local") for k in cfg.layer_pattern):
        return False, f"layer pattern {cfg.layer_pattern} has recurrent blocks"
    if cfg.pos_embedding not in ("rope", "none"):
        return False, f"pos_embedding={cfg.pos_embedding} needs scalar offsets"
    if not cfg.causal or cfg.encoder_only:
        return False, "serving needs a causal decoder"
    return True, ""


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def largest_divisor_of(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1).  Unlike
    :func:`_largest_divisor_leq` it puts no divisibility demand on ``cap``."""
    for d in range(min(n, max(cap, 1)), 0, -1):
        if n % d == 0:
            return d
    return 1


def derive_serve_plan(
    cfg,
    mesh_shape: Mapping[str, int],
    hw: HardwareSpec = DEFAULT_HARDWARE,
    *,
    max_seq_len: int = 2048,
    decode_batch: Optional[int] = None,
    block_size: Optional[int] = None,
    kv_dtype: Optional[str] = None,
    prefill_chunk: Optional[int] = None,
    mixed_slab_width: Optional[int] = None,
    pages_per_tile: Optional[int] = None,
    fused_attention: bool = True,
    rolled_steps: Optional[int] = None,
    spec_len: Optional[int] = None,
    draft: str = "none",
    slack_blocks: int = 0,
    oversubscribe: float = 1.0,
    prefix_sharing: bool = True,
    slo_ttft_ms: Optional[float] = None,
    typical_prompt_len: Optional[int] = None,
    deadline_ms: Optional[float] = None,
    retry_limit: int = 3,
    retry_backoff_s: float = 0.001,
    ladder_recovery: int = 32,
    admission_patience: int = 128,
    stall_limit: int = 256,
    quarantine_limit: int = 8,
) -> ServePlan:
    """Pick decode batch / block size / KV dtype from the roofline model.

    * **decode batch** — decode is weight-streaming-bound; batching tokens
      amortizes the weight read until compute catches up at the machine
      balance point (Eq.4 analog): B* ~= machine_balance x bytes/param / 2.
      Capped by the HBM KV budget at full context.  With the fused
      paged-attention kernel each slot's HBM traffic is just its own pages
      read once; the gather fallback instead writes *and* re-reads a dense
      ``max_seq_len``-long cache per slot per step, so its per-slot byte tax
      (2 x ``max_seq_len`` x kv_bytes/token) stops the batch from amortizing
      the weight stream long before the balance point — the fallback batch
      is additionally capped at weight_bytes / gather_tax.  The fused
      kernel's plan simply drops that term.
    * **KV dtype** — bf16 unless the bf16 pool cannot hold the
      roofline-preferred batch at ``max_seq_len``; then the paper's Int8
      deployment grid halves the page bytes (C2's precision knob applied to
      the cache instead of the weights).
    * **block size** — one MXU sublane tile (``mxu_dim // 8``) so a page
      feeds the MM PU without re-tiling; never wider than the context.
    * **mixed-slab width** — query rows per slot in the unified step;
      defaults to ``prefill_chunk`` (prefill keeps its compute-bound chunk,
      decode slots carry the dead rows — the explicit latency/throughput
      trade, overridable).
    * **pages per VMEM tile** — the fused kernel double-buffers k+v page
      tiles in VMEM; the tile height is the largest block-table divisor
      whose tiles fit an eighth of the chip's VMEM (the rest holds q, the
      accumulator and the output block).
    * **rolled decode steps (K cap)** — how many decode iterations one host
      dispatch should carry.  A dispatch costs ``hw.dispatch_overhead_s``
      no matter how much it launches, while one decode step is
      weight-stream-bound (~ weight_bytes / hbm_bandwidth); the overhead
      fraction is therefore ``overhead / (K x step)``.  K is the smallest
      power of two holding that fraction under ~10% (1 when a single step
      already amortizes it — big models — and capped at 32: past that the
      host loses admission/completion responsiveness for < 0.4% more).  A
      TTFT target additionally caps K so a rolled span cannot blockade an
      arriving prompt past ~a quarter of its budget.
    * **speculative draft depth (gamma)** — the joint-constraint answer to
      "how many draft rows per slot can verification absorb for free":
      decode at batch B is bandwidth-bound (B below the machine balance
      point), so one weight stream amortizes ``machine_balance / B`` query
      rows per slot before the MXU goes compute-bound.  gamma+1 must stay
      within that slack *and* within the slab width, else gamma drops to 0
      (verification must never slow the step it is trying to speed up).
      Only derived when a ``draft`` source is named; explicit ``spec_len``
      overrides (still clamped to the slab).
    * **SLO feedback** — a fleet TTFT target (``slo_ttft_ms``) feeds back
      into the slab and gamma: steps are weight-stream-bound (>=
      weight_bytes / hbm_bandwidth each), so the target fixes a step
      budget, the slab widens until ``typical_prompt_len`` prefils inside
      it, and gamma is reined in to ``slack // 2 - 1`` (draft rows compete
      with prompt chunks for slab width).  Per-request targets
      (``Request.slo_ttft_ms``) additionally drive runtime chunk sizing in
      the scheduler against *measured* step times.

    ``oversubscribe`` scales the block pool relative to the worst case
    (every slot at ``max_seq_len``).  At the default 1.0 the pool can host
    every admitted request to full context, so derived plans are
    *eviction-free by construction* — the scheduler's eviction path only
    engages when an operator oversubscribes (< 1.0) to trade KV memory for
    admission capacity, betting that most requests stop early.
    """
    ok, reason = serve_feasible(cfg)
    if not ok:
        raise ValueError(f"no serve plan for {cfg.name}: {reason}")
    ma = mesh_shape.get("model", 1)
    n_attn = sum(
        1 for i in range(cfg.n_layers) if cfg.layer_kind(i) in ("attn", "swa", "local")
    )
    weight_bytes = cfg.param_count() * 2.0 / max(ma, 1)
    kv_budget = int(max(hw.hbm_bytes - weight_bytes, 0.1 * hw.hbm_bytes))

    def per_token(dtype: str) -> int:
        b = {"fp32": 4, "bf16": 2, "int8": 1}[dtype]
        tok = n_attn * 2 * cfg.n_kv_heads * cfg.d_head * b
        if dtype == "int8":  # per-(token, head) fp32 scale rides along
            tok += n_attn * 2 * cfg.n_kv_heads * 4
        return tok

    # Roofline batch: tokens per step needed to amortize the weight stream.
    # A degenerate device with no off-chip bandwidth reports an infinite
    # machine balance (nothing amortizes); clamp so the int() below is total
    # — the KV-capacity cap then decides the batch alone.
    balance = min(hw.machine_balance_bf16, 2.0**20)
    ridge = max(1, int(balance * 2.0 / (2.0 * max(ma, 1))))
    if kv_dtype is None:
        want = decode_batch or _pow2_floor(ridge)
        fits_bf16 = want * max_seq_len * per_token("bf16") <= kv_budget
        kv_dtype = "bf16" if fits_bf16 else "int8"
    kv_tok = per_token(kv_dtype)
    cap = max(1, kv_budget // max(max_seq_len * kv_tok, 1))
    if not fused_attention:
        # Gather-bytes term (fallback only): every slot drags a dense
        # write+read of its full-context cache through HBM each step.
        gather_tax = 2.0 * max_seq_len * kv_tok
        cap = max(1, min(cap, int(weight_bytes / max(gather_tax, 1.0))))
    if decode_batch is None:
        decode_batch = max(1, min(_pow2_floor(ridge), _pow2_floor(cap)))
    if block_size is None:
        block_size = max(8, hw.mxu_dim // 8)
    block_size = min(block_size, max_seq_len)
    max_blocks_per_seq = -(-max_seq_len // block_size)  # ceil
    pool = max(max_blocks_per_seq, int(decode_batch * max_blocks_per_seq * oversubscribe))
    n_blocks = 1 + pool + slack_blocks  # +1: block 0 is trash
    if prefill_chunk is None:
        prefill_chunk = min(max_seq_len, max(block_size, 256))
    if mixed_slab_width is None:
        mixed_slab_width = prefill_chunk
    if slo_ttft_ms is not None:
        # TTFT feedback (same joint-constraint style as the decode batch):
        # decode steps are weight-stream-bound, so one step costs at least
        # weight_bytes / hbm_bandwidth — that bounds how many steps fit in
        # the TTFT budget, and a typical prompt must prefill within them.
        # Widen the slab until it does (never narrow a wider request).
        est_step_s = weight_bytes / max(hw.hbm_bandwidth, 1.0)
        steps_budget = max(1, int((slo_ttft_ms / 1e3) / max(est_step_s, 1e-12)))
        need = -(-int(typical_prompt_len or max_seq_len) // steps_budget)
        mixed_slab_width = max(int(mixed_slab_width), need)
    mixed_slab_width = max(1, min(mixed_slab_width, max_seq_len))
    if pages_per_tile is None:
        # one pool page in VMEM: (block_size, n_kv_heads, d_head) values
        # (+ a (block_size, n_kv_heads, 1) fp32 scale for int8 pages)
        page_bytes = block_size * cfg.n_kv_heads * (
            cfg.d_head * {"fp32": 4, "bf16": 2, "int8": 1}[kv_dtype]
            + (4 if kv_dtype == "int8" else 0)
        )
        tile_cap = max(1, (hw.vmem_bytes // 8) // max(2 * page_bytes, 1))
        pages_per_tile = largest_divisor_of(max_blocks_per_seq, tile_cap)
    if rolled_steps is None:
        # Dispatch-overhead slack: one decode step streams the weights once
        # (est_step_s); the host round-trip costs dispatch_overhead_s on
        # top.  Roll K steps per dispatch until the overhead fraction
        # overhead / (K * step) drops under ~10%.
        est_step_s = weight_bytes / max(hw.hbm_bandwidth, 1.0)
        rolled_steps = 1
        while (
            hw.dispatch_overhead_s > 0.1 * rolled_steps * max(est_step_s, 1e-12)
            and rolled_steps < 32
        ):
            rolled_steps *= 2
        if slo_ttft_ms is not None:
            # an arriving prompt waits out the in-flight rolled span before
            # its first prefill chunk: keep that wait under ~1/4 of the
            # TTFT budget so rolling never blows the very target the plan
            # was shaped for
            step_budget = max(
                1, int((slo_ttft_ms / 4e3) / max(est_step_s, 1e-12))
            )
            rolled_steps = min(rolled_steps, _pow2_floor(step_budget))
    rolled_steps = max(1, int(rolled_steps))
    if spec_len is None:
        if draft == "none":
            spec_len = 0
        else:
            # Compute slack per decode slot: the weight stream takes
            # weight_bytes / bw while one verified row costs
            # decode_batch * 2P/ma flops across the batch — both scale the
            # same way with TP, so slack rows/slot = machine_balance / B.
            # gamma+1 <= slack keeps verification bandwidth-bound; the -1
            # converts rows to drafts, and the cap of 8 bounds the verify
            # logits width (diminishing returns far before the slab does).
            slack = min(hw.machine_balance_bf16, 2.0**20) / max(int(decode_batch), 1)
            spec_len = max(0, min(int(slack) - 1, 8))
    if slo_ttft_ms is not None:
        # Under a TTFT target draft rows compete with prompt chunks for the
        # slab and lengthen the very steps the target budgets, so gamma only
        # keeps the slack it can *halve*: rein it in to slack//2 - 1 (0 when
        # the roofline slack is thin).
        slack = min(hw.machine_balance_bf16, 2.0**20) / max(int(decode_batch), 1)
        spec_len = min(int(spec_len), max(0, int(slack) // 2 - 1))
    spec_len = max(0, min(int(spec_len), int(mixed_slab_width) - 1))
    return ServePlan(
        arch=cfg.name,
        decode_batch=int(decode_batch),
        block_size=int(block_size),
        n_blocks=int(n_blocks),
        max_blocks_per_seq=int(max_blocks_per_seq),
        kv_dtype=kv_dtype,
        prefill_chunk=int(prefill_chunk),
        mixed_slab_width=int(mixed_slab_width),
        pages_per_tile=int(pages_per_tile),
        fused_attention=bool(fused_attention),
        rolled_steps=int(rolled_steps),
        spec_len=int(spec_len),
        draft=str(draft),
        prefix_sharing=bool(prefix_sharing),
        slo_ttft_ms=None if slo_ttft_ms is None else float(slo_ttft_ms),
        deadline_ms=None if deadline_ms is None else float(deadline_ms),
        retry_limit=int(retry_limit),
        retry_backoff_s=float(retry_backoff_s),
        ladder_recovery=int(ladder_recovery),
        admission_patience=int(admission_patience),
        stall_limit=int(stall_limit),
        quarantine_limit=int(quarantine_limit),
        max_seq_len=int(max_seq_len),
        kv_bytes_per_token=int(kv_tok),
        hbm_kv_budget_bytes=kv_budget,
    )


# ---------------------------------------------------------------------------
# Paper §V.B design case, on the paper's own hardware numbers.
# ---------------------------------------------------------------------------
def design_case_vck5000(seq_len: int = 256, d_model: int = 768, d_ff: int = 3072,
                        n_heads: int = 12) -> dict:
    """Reproduce the BERT-Base walk-through: Factor1 ~= 1.5, Factor2 ~= 7.56 MB,
    P_ATB = 4, fully-pipelined mode selected (paper §V.B)."""
    plio_aie, mmsz, total_aie = 4, 64, 400
    engine = (total_aie // plio_aie**2) * (plio_aie * mmsz) ** 3
    factor1 = 4 * seq_len * d_model**2 / engine
    d_head = d_model // n_heads
    buf = (
        seq_len * 256 * 3  # QKV LB output cache (int8 paper accounting)
        + seq_len * d_head * 4 * 4  # ATB in/out cache
        + 128 * seq_len * 4  # ATB attention cache
        + seq_len * 256 * 4  # ATB KV cache
        + seq_len * d_model + seq_len * 256  # Proj LB in/out
        + d_model * d_model * 4 + d_model * d_ff * 2  # weight cache
    )
    p_atb = 256 // d_head  # QKV LB emits 256-wide tiles; one head needs d_head
    mode = (
        SPATIAL
        if factor1 < PRG_MAX_PIPELINE_DEPTH and buf <= VCK5000.vmem_bytes
        else TEMPORAL
    )
    return {
        "factor1": factor1,
        "factor2_bytes": buf,
        "factor2_mb": buf / 2**20,
        "p_atb": p_atb,
        "mode": mode,
        "prg_max_pipeline_depth": PRG_MAX_PIPELINE_DEPTH,
        "buffer_budget_mb": VCK5000.vmem_bytes / 2**20,
    }
