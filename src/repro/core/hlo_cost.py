"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE,
which under-counts a scanned-layer transformer by ~n_layers x microbatches.
This module re-derives FLOPs / HBM-byte / collective totals by walking the
optimized HLO text:

  * parse every computation into a symbol table (op name -> shape/dtype),
  * extract while-loop trip counts from their condition computations
    (the loop bound constant),
  * propagate multipliers along the call graph
    (entry=1; while body/cond x trip; fusion/call/to_apply inherit),
  * FLOPs from dot/convolution ops (2 x prod(out) x prod(contracting)),
  * HBM traffic from top-level op outputs + resolved operand reads
    (fusion-internal ops never touch HBM and are skipped),
  * collectives with their replica group size, multiplied like any other op.

Used by the dry-run roofline; ``cost_analysis()`` is kept alongside as a
cross-check (they agree on scan-free graphs).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_COLL_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# "  %name = bf16[1,2,3]{2,1,0} opcode(...)" or tuple results
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OPCODE_RE = re.compile(r"\)?\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w\.\-,% ]+)\}?"
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """Total (elements, bytes) across a (possibly tuple) HLO type string."""
    elems = byts = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    out_bytes: int
    type_str: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict  # name -> OpInfo
    order: list


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        # computation header: "%name (args) -> type {"  or "ENTRY %name ..."
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = Computation(m.group(1), {}, [])
                comps[cur.name] = cur
            continue
        if stripped == "}" or stripped.startswith("}"):
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # rhs = "bf16[..]{..} opcode(...)" — first shapes are the result type
        om = _OPCODE_RE.search(rhs)
        # opcode token: word right before '('
        opm = re.search(r"([\w\-]+)\(", rhs)
        opcode = opm.group(1) if opm else "unknown"
        # result type = rhs up to the opcode occurrence
        type_end = rhs.find(opcode + "(") if opm else len(rhs)
        type_str = rhs[:type_end]
        _, out_bytes = _shape_elems_bytes(type_str)
        info = OpInfo(name, opcode, out_bytes, type_str, stripped)
        cur.ops[name] = info
        cur.order.append(name)
    return comps


def _trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation (largest int constant)."""
    best = 1
    for name in cond.order:
        mm = _CONST_RE.search(cond.ops[name].line)
        if mm:
            best = max(best, int(mm.group(1)))
    return best


def _multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    entry = None
    for name in comps:
        if name.startswith("main") or name.startswith("%main"):
            entry = name
    if entry is None:  # fall back: computation not called by anyone
        called = set()
        for c in comps.values():
            for op in c.ops.values():
                for cm in _CALL_ATTR_RE.finditer(op.line):
                    for t in re.split(r"[ ,]+", cm.group(1)):
                        called.add(t.strip().lstrip("%"))
        roots = [n for n in comps if n not in called]
        entry = roots[0] if roots else next(iter(comps))

    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        if mult.get(name, 0) >= m:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        comp = comps[name]
        for opn in comp.order:
            op = comp.ops[opn]
            if op.opcode == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                bm = re.search(r"body=%?([\w\.\-]+)", op.line)
                trip = 1
                if cm and cm.group(1) in comps:
                    trip = _trip_count(comps[cm.group(1)])
                if bm:
                    visit(bm.group(1), m * trip)
                if cm:
                    visit(cm.group(1), m * trip)
            else:
                for cm in _CALL_ATTR_RE.finditer(op.line):
                    for t in re.split(r"[ ,]+", cm.group(1)):
                        t = t.strip().lstrip("%")
                        if t:
                            visit(t, m)

    visit(entry, 1.0)
    return mult


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    out_elems, _ = _shape_elems_bytes(op.type_str)
    cm = _CONTRACT_RE.search(op.line)
    if not cm:
        return 2.0 * out_elems  # degenerate
    cdims = [int(x) for x in cm.group(1).split(",") if x]
    # resolve lhs operand shape from the symbol table
    args = op.line[op.line.find("("):]
    ops_in = _OPERAND_RE.findall(args)
    contr = 1
    if ops_in:
        lhs = comp.ops.get(ops_in[0])
        if lhs is not None:
            shapes = _SHAPE_RE.findall(lhs.type_str)
            if shapes:
                dims = [int(d) for d in shapes[-1][1].split(",") if d]
                for c in cdims:
                    if c < len(dims):
                        contr *= dims[c]
    return 2.0 * out_elems * contr


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collectives: list  # (opcode, operand_bytes, group_size, multiplier)

    @property
    def collective_operand_bytes(self) -> float:
        return sum(b * m for _, b, _, m in self.collectives)


def analyze_hlo(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    mult = _multipliers(comps)
    flops = 0.0
    hbm = 0.0
    colls: list = []
    fusion_bodies = set()
    for comp in comps.values():
        for opn in comp.order:
            op = comp.ops[opn]
            if op.opcode == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", op.line)
                if cm:
                    fusion_bodies.add(cm.group(1))

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_bodies
        for opn in comp.order:
            op = comp.ops[opn]
            if op.opcode in ("dot", "convolution"):
                flops += m * _dot_flops(op, comp)
            if in_fusion:
                continue  # fusion-internal ops do not touch HBM
            if op.opcode in ("parameter", "constant", "tuple", "get-tuple-element",
                             "bitcast", "while", "conditional", "call", "reshape",
                             "iota", "after-all", "custom-call", "partition-id"):
                continue
            args = op.line[op.line.find("("):] if "(" in op.line else ""
            operands = [
                comp.ops[o]
                for o in _OPERAND_RE.findall(args)
                if o in comp.ops and comp.ops[o].opcode != "constant"
            ]
            if op.opcode == "dynamic-slice":
                # reads only the slice, not the sliced-from buffer
                hbm += m * 2 * op.out_bytes
            elif op.opcode == "dynamic-update-slice":
                # in-place: touches only the update window (operand[1])
                upd = operands[1].out_bytes if len(operands) > 1 else op.out_bytes
                hbm += m * 2 * upd
            elif op.opcode == "gather":
                hbm += m * 2 * op.out_bytes
            elif op.opcode == "scatter":
                upd = operands[-1].out_bytes if operands else op.out_bytes
                hbm += m * 2 * upd
            else:
                # writes: own output; reads: resolved operands
                hbm += m * op.out_bytes
                for src in operands:
                    hbm += m * src.out_bytes

            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if base in _COLL_OPS:
                g = 1
                gm = _GROUPS_IOTA_RE.search(op.line)
                if gm:
                    g = int(gm.group(2))
                else:
                    gb = _GROUPS_BRACES_RE.search(op.line)
                    if gb:
                        g = len(gb.group(1).split(","))
                out_b = op.out_bytes
                if base == "all-gather":
                    operand_b = out_b // max(g, 1)
                elif base == "reduce-scatter":
                    operand_b = out_b * g
                else:
                    operand_b = out_b
                colls.append((base, operand_b, g, m))
    return HloCost(flops=flops, hbm_bytes=hbm, collectives=colls)
