"""Roofline analysis from the compiled (partitioned, per-device) HLO.

Three terms per (arch x shape x mesh) cell:
    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
    memory_s     = HLO_bytes_per_device / HBM_bw
    collective_s = ring-model ICI seconds from the collective ops in the HLO

FLOPs / bytes / collective bytes come from the trip-count-aware HLO walker in
``repro.core.hlo_cost`` (XLA's ``cost_analysis()`` counts while-loop bodies
once — a scanned-layer transformer would be under-counted by ~n_layers x
microbatches; both numbers are recorded, the xla one as a cross-check).

Conventions (task spec):
  * collective_bytes = per-device summed operand bytes of every all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute;
    collective term (spec form) = collective_bytes / link_bw
    (== global bytes / (chips x link_bw)).
  * ring model (what §Perf iterates on): all-reduce 2x(g-1)/g, gathers
    (g-1)/g, permute 1x.

Also the paper's C8 metrics re-derived: MODEL_FLOPS / HLO_FLOPs =
effective-utilization analog (how much compiled compute is "useful").
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.hardware import HardwareSpec
from repro.core.hlo_cost import HloCost, analyze_hlo


def _ring_seconds(op: str, operand_bytes: float, g: int, link_bw: float) -> float:
    if g <= 1 or link_bw <= 0:
        return 0.0
    if op == "all-gather":
        # operand = out/g; ring moves out*(g-1)/g = operand*(g-1)
        return operand_bytes * (g - 1) / link_bw
    if op == "all-reduce":
        return 2.0 * operand_bytes * (g - 1) / g / link_bw
    if op == "reduce-scatter":
        return operand_bytes * (g - 1) / g / link_bw
    if op == "all-to-all":
        return operand_bytes * (g - 1) / g / link_bw
    return operand_bytes / link_bw  # collective-permute


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # raw (per device, trip-count corrected)
    flops_per_device: float
    bytes_per_device: float
    collective_operand_bytes_per_device: float
    n_collectives: int
    collectives_by_op: dict
    # xla cost_analysis cross-checks (loop bodies counted once)
    xla_flops_per_device: float
    xla_bytes_per_device: float
    # terms (seconds)
    compute_s: float
    memory_s: float  # HLO-derived (upper bound: CPU-backend fusion granularity)
    memory_floor_s: float  # analytic lower bound (params+acts+probs+CE traffic)
    collective_s: float  # ring model
    collective_s_spec: float  # task-spec convention
    # utilization
    model_flops: float
    model_flops_ratio: float  # MODEL_FLOPS / (HLO flops x chips)
    bottleneck: str
    # memory fit
    arg_bytes: float
    temp_bytes: float
    fits_hbm: bool

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time model: overlapped compute/memory/comm (memory
        enters via the analytic floor — see analyze())."""
        return max(self.compute_s, self.memory_floor_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs MFU at the roofline step time (the §Perf score)."""
        if self.step_time_s <= 0:
            return 0.0
        useful = self.model_flops / self.n_chips
        return useful / self.step_time_s / _PEAK_HOLDER["peak"]


_PEAK_HOLDER = {"peak": 197e12}


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_chips: int,
    cost: dict,
    hlo_text: str,
    hw: HardwareSpec,
    model_flops: float,
    arg_bytes: float = 0.0,
    temp_bytes: float = 0.0,
    memory_floor_bytes: float = 0.0,
) -> RooflineReport:
    _PEAK_HOLDER["peak"] = hw.peak_flops_bf16
    hc: HloCost = analyze_hlo(hlo_text)
    flops = hc.flops
    byts = hc.hbm_bytes
    link_bw = hw.ici_bandwidth_per_link
    ring_s = sum(_ring_seconds(o, b, g, link_bw) * m for o, b, g, m in hc.collectives)
    op_bytes = hc.collective_operand_bytes
    by_op: dict = {}
    for o, b, g, m in hc.collectives:
        d = by_op.setdefault(o, {"count": 0, "operand_bytes": 0.0})
        d["count"] += m
        d["operand_bytes"] += b * m

    compute_s = flops / hw.peak_flops_bf16
    # A zero-bandwidth device (degenerate SRAM-only variant) makes any HBM
    # traffic unserviceable: report inf rather than divide by zero.
    if hw.hbm_bandwidth > 0:
        memory_s = byts / hw.hbm_bandwidth
        floor_s = memory_floor_bytes / hw.hbm_bandwidth
    else:
        memory_s = math.inf if byts else 0.0
        floor_s = math.inf if memory_floor_bytes else 0.0
    spec_s = op_bytes / link_bw if link_bw else 0.0
    # Bottleneck attribution uses the analytic memory floor: the HLO-derived
    # byte count reflects CPU-backend fusion boundaries and would otherwise
    # swallow every cell into "memory".
    terms = {"compute": compute_s, "memory": floor_s or memory_s, "collective": ring_s}
    bottleneck = max(terms, key=terms.get)
    total_hlo_flops = flops * n_chips
    ratio = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_chips=n_chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_operand_bytes_per_device=float(op_bytes),
        n_collectives=int(sum(m for _, _, _, m in hc.collectives)),
        collectives_by_op=by_op,
        xla_flops_per_device=float(cost.get("flops", 0.0)),
        xla_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        compute_s=compute_s,
        memory_s=memory_s,
        memory_floor_s=floor_s,
        collective_s=ring_s,
        collective_s_spec=spec_s,
        model_flops=model_flops,
        model_flops_ratio=ratio,
        bottleneck=bottleneck,
        arg_bytes=arg_bytes,
        temp_bytes=temp_bytes,
        fits_hbm=(arg_bytes + temp_bytes) <= hw.hbm_bytes,
    )


def model_flops_for(cfg, shape, training: bool) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N_active*D (inference forward)."""
    n = cfg.param_count(active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    return (6.0 if training else 2.0) * n * tokens


def analytic_memory_floor(cfg, shape, plan, n_chips: int) -> float:
    """Minimum plausible HBM bytes per chip per step (roofline lower bound).

    Train:  weights fwd+bwd reads + grad write + optimizer r/w (~12B/param on
            its shard) + ~8 activation tensors/layer r+w (x3 with remat) +
            attention probs traffic + CE logits chunks.
    Decode: active weights read once + KV/state cache read + write.
    """
    mesh = dict(plan.mesh_axes)
    n_active = cfg.param_count(active_only=True)
    params_shard = n_active / n_chips
    if shape.kind == "decode":
        cache_elems = 0.0
        for i in range(cfg.n_layers):
            kind = cfg.layer_kind(i)
            if kind in ("attn", "swa", "local"):
                window = (
                    cfg.sliding_window if kind == "swa"
                    else cfg.local_window if kind == "local" else 0
                )
                sc = min(window, shape.seq_len) if window else shape.seq_len
                cache_elems += (
                    2 * shape.global_batch * sc * cfg.n_kv_heads * cfg.d_head
                )
            elif kind == "rwkv6":
                cache_elems += shape.global_batch * cfg.rnn_heads * cfg.d_head**2
            elif kind == "rglru":
                cache_elems += shape.global_batch * (cfg.lru_width or cfg.d_model)
        return 2.0 * n_active / n_chips + 2.0 * cache_elems / n_chips
    # training / prefill
    tokens_per_chip = shape.global_batch * shape.seq_len / max(
        mesh.get("data", 1)
        * (mesh.get("model", 1) if plan.dp_over_model else 1)
        * mesh.get("pod", 1),
        1,
    )
    passes = 3.0 if shape.kind == "train" else 1.0
    width_frac = 1.0 / (mesh.get("model", 1) if not plan.dp_over_model else 1)
    act = tokens_per_chip * cfg.d_model * 2.0 * 8 * cfg.n_layers * passes * (
        2.0 if plan.remat else 1.0
    ) * width_frac
    eff_kv = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
    attn_layers = sum(
        1
        for i in range(cfg.n_layers)
        if cfg.layer_kind(i) in ("attn", "swa", "local")
    )
    probs = tokens_per_chip * eff_kv * cfg.n_heads * 4.0 * attn_layers * passes * width_frac
    ce = tokens_per_chip * cfg.vocab_size * 4.0 * passes if shape.kind == "train" else 0.0
    weights = params_shard * (12.0 if shape.kind == "train" else 2.0)
    return weights + act + probs + ce
