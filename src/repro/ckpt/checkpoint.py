"""Checkpointing: mesh-independent layout, atomic manifests, async save,
elastic restore.

Layout on disk:
    <dir>/step_<k>/arrays.npz      flattened param/opt leaves ("a/b/c[i]" keys)
    <dir>/step_<k>/manifest.json   step, tree structure hash, config name
Manifest is written LAST via atomic rename -> a crashed save never yields a
"latest" checkpoint.  Arrays are saved in logical (unsharded) layout, so
restore re-shards onto whatever mesh the new job brings up (elastic scaling).
Async: the save runs on a background thread over host copies.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "|"


def _flatten(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.name in ("bfloat16", "float16"):  # npz-unfriendly dtypes
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(
    directory: str, step: int, tree: PyTree, *, meta: Optional[dict] = None,
    async_save: bool = False,
):
    d = pathlib.Path(directory)
    tmp = d / f"_tmp_step_{step}"
    final = d / f"step_{step}"
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)  # host copies happen here (device_get)

    def _write():
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "n_arrays": len(flat),
            "total_bytes": int(sum(a.nbytes for a in flat.values())),
            "time": time.time(),
            **(meta or {}),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            import shutil

            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    like: PyTree,
    shardings: Optional[PyTree] = None,
) -> PyTree:
    """Restore into the structure of ``like``; device_put with ``shardings``
    re-shards onto the *current* mesh (elastic restore)."""
    d = pathlib.Path(directory) / f"step_{step}"
    with np.load(d / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jax.numpy.asarray(arr).astype(leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree
