"""Serving launcher: continuous batching by default, eager lockstep as
fallback.

    # continuous batching (paged KV cache, plan-derived knobs):
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 8 --prompt-len 32 --gen 16 --stagger 2

    # speculative decoding: a small model drafts, the big model verifies
    # gamma+1 rows per slot in the same mixed slab (tokens are identical
    # to plain decode; only the speed changes):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --draft smollm-135m --requests 8 --prompt-len 32 --gen 16

    # eager whole-batch greedy decode (non-attention archs serve here):
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b-reduced \
        --engine eager --batch 4 --prompt-len 32 --gen 16

The batched path derives an :class:`ExecutionPlan` (mesh decisions) *and* a
:class:`ServePlan` (decode batch / block size / KV dtype / prefill chunk)
from the same (arch, mesh, hardware) triple, places params through
``dist.Shardings`` so a model-sharded mesh serves correctly, and prints the
plan + engine summary (tokens/s, batch occupancy) at the end.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan, serve_feasible
from repro.dist.sharding import Shardings
from repro.launch.mesh import make_host_mesh
from repro.models.params import init_params
from repro.serve.engine import ServingEngine, greedy_generate
from repro.serve.scheduler import random_stream
from repro.serve.speculative import make_draft_source


def run_batched(a, cfg, mesh) -> dict:
    plan = derive_plan(
        cfg, dict(mesh.shape), TPU_V5E,
        batch=a.batch, seq_len=a.prompt_len, training=False,
    )
    serve = derive_serve_plan(
        cfg, dict(mesh.shape), TPU_V5E,
        max_seq_len=a.max_seq,
        decode_batch=a.batch if a.fix_batch else None,
        prefill_chunk=a.prefill_chunk,
        mixed_slab_width=a.slab_width,
        pages_per_tile=a.pages_per_tile,
        fused_attention=not a.no_fused,
        kv_dtype=a.kv_dtype,
        draft=a.draft or "none",
        spec_len=a.spec_len,
    )
    print(plan.describe())
    print(serve.describe())
    sh = Shardings(mesh, plan, cfg)
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    params = jax.device_put(params, sh.param_shardings(params))
    draft = None
    if a.draft and serve.spec_len == 0:
        print("roofline slack leaves no free verification rows at this "
              "decode batch: speculation stays off (gamma = 0)")
    elif a.draft:
        draft = make_draft_source(a.draft, cfg, serve, hw=TPU_V5E, seed=2)
    engine = ServingEngine(params, cfg, plan, serve, shardings=sh, draft=draft)
    if engine.fused != serve.fused_attention:
        print("multi-device mesh: unified step falls back to the gather path "
              "(Pallas kernel is single-device for now)")
    reqs = random_stream(cfg, a.requests, a.prompt_len, a.gen, a.stagger, seed=1)
    out = engine.run(reqs)
    summary = engine.summary()
    first = next(iter(out))
    print(f"served {len(out)} requests; {first} -> {out[first]}")
    print(json.dumps(summary, indent=1, default=str))
    return summary


def run_eager(a, cfg, mesh) -> dict:
    plan = derive_plan(
        cfg, dict(mesh.shape), TPU_V5E,
        batch=a.batch, seq_len=a.prompt_len, training=False,
    )
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (a.batch, a.prompt_len), 0, cfg.vocab_size)
    }
    if cfg.frontend != "none":
        batch["prefix_embeds"] = jax.random.normal(
            key, (a.batch, cfg.n_prefix_embeds, cfg.d_model), jnp.float32
        )
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (a.batch, cfg.enc_seq, cfg.d_model), jnp.float32
        )
    t0 = time.time()
    out = greedy_generate(
        params, cfg, plan, batch, n_steps=a.gen,
        cache_len=a.prompt_len + a.gen,
    )
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({a.batch * a.gen / dt:.1f} tok/s)")
    print(out[0])
    return {"tok_per_s": a.batch * a.gen / dt}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--engine", default="batched", choices=["batched", "eager"])
    ap.add_argument("--batch", type=int, default=4,
                    help="eager batch / batched decode slots (with --fix-batch)")
    ap.add_argument("--fix-batch", action="store_true",
                    help="pin decode_batch to --batch instead of deriving it")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--stagger", type=int, default=2,
                    help="engine iterations between request arrivals")
    ap.add_argument("--max-seq", type=int, default=2048)
    ap.add_argument("--prefill-chunk", type=int, default=None)
    ap.add_argument("--slab-width", type=int, default=None,
                    help="mixed-slab query rows per slot (default: prefill chunk)")
    ap.add_argument("--pages-per-tile", type=int, default=None,
                    help="KV pages per VMEM tile of the fused kernel "
                         "(default: derived from the VMEM budget)")
    ap.add_argument("--no-fused", action="store_true",
                    help="use the dense gather path instead of the fused "
                         "Pallas paged-attention kernel")
    ap.add_argument("--kv-dtype", default=None,
                    choices=[None, "bf16", "int8", "fp32"])
    ap.add_argument("--draft", default=None,
                    help="speculative draft source: 'ngram' (prompt-lookup "
                         "self-drafting) or a config name (e.g. smollm-135m "
                         "drafting for a larger --arch)")
    ap.add_argument("--spec-len", type=int, default=None,
                    help="draft depth gamma per decode slot (default: derived "
                         "from the roofline's compute slack; 0 disables)")
    a = ap.parse_args()

    cfg = get_config(a.arch)
    mesh = make_host_mesh()
    if a.engine == "batched" and not serve_feasible(cfg)[0]:
        print(f"{a.arch}: {serve_feasible(cfg)[1]}; falling back to --engine eager")
        a.engine = "eager"
    if a.engine == "batched":
        run_batched(a, cfg, mesh)
    else:
        run_eager(a, cfg, mesh)


if __name__ == "__main__":
    main()
