"""Batched serving launcher (prefill + greedy decode).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b-reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan
from repro.launch.mesh import make_host_mesh
from repro.models.params import init_params
from repro.serve.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    a = ap.parse_args()

    cfg = get_config(a.arch)
    mesh = make_host_mesh()
    plan = derive_plan(
        cfg, dict(mesh.shape), TPU_V5E,
        batch=a.batch, seq_len=a.prompt_len, training=False,
    )
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (a.batch, a.prompt_len), 0, cfg.vocab_size)
    }
    if cfg.frontend != "none":
        batch["prefix_embeds"] = jax.random.normal(
            key, (a.batch, cfg.n_prefix_embeds, cfg.d_model), jnp.float32
        )
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (a.batch, cfg.enc_seq, cfg.d_model), jnp.float32
        )
    t0 = time.time()
    out = greedy_generate(
        params, cfg, plan, batch, n_steps=a.gen,
        cache_len=a.prompt_len + a.gen,
    )
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({a.batch * a.gen / dt:.1f} tok/s)")
    print(out[0])


if __name__ == "__main__":
    main()
