"""Serving launcher: continuous batching by default, eager lockstep as
fallback.

    # continuous batching (paged KV cache, plan-derived knobs):
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 8 --prompt-len 32 --gen 16 --stagger 2

    # speculative decoding: a small model drafts, the big model verifies
    # gamma+1 rows per slot in the same mixed slab (tokens are identical
    # to plain decode; only the speed changes):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --draft smollm-135m --requests 8 --prompt-len 32 --gen 16

    # multi-tenant trace replay: two tenants, each with a shared system
    # prompt, driving a heterogeneous class mix with per-class latency
    # percentiles (prefix sharing makes the shared prompts one prefill):
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --replay-trace chat:4,summarize:2,classify:2 --tenant-mix 2 --max-seq 512

    # observability: Prometheus-format metrics + a Chrome trace_event
    # export of the whole run (open in https://ui.perfetto.dev):
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 8 --gen 16 --metrics-out m.prom --trace-out trace.json

    # eager whole-batch greedy decode (non-attention archs serve here):
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b-reduced \
        --engine eager --batch 4 --prompt-len 32 --gen 16

All serving knobs live in one :class:`ServeArgs` record whose
``plan_overrides()`` maps 1:1 onto :func:`repro.core.plan.derive_serve_plan`
keyword arguments — the CLI flags are just its spellings (old flag names
all keep working).  The batched path derives an :class:`ExecutionPlan`
(mesh decisions) *and* a :class:`ServePlan` from the same (arch, mesh,
hardware) triple, places params through ``dist.Shardings`` so a
model-sharded mesh serves correctly, and prints the plan + engine summary
(tokens/s, batch occupancy, prefix-sharing hit rates) at the end.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.hardware import get_hardware
from repro.core.plan import derive_plan, derive_serve_plan, serve_feasible
from repro.dist.sharding import Shardings
from repro.launch.mesh import make_host_mesh
from repro.models.params import init_params
from repro.serve import (
    ServingEngine,
    greedy_generate,
    make_draft_source,
    make_trace,
    parse_mix,
    per_class_report,
    random_stream,
)


@dataclasses.dataclass
class ServeArgs:
    """Every serving-launcher knob, CLI-independent.

    The fields group into (a) workload shape (requests / prompt-len / gen /
    stagger, or a ``trace`` workload-mix spec with ``tenant_mix`` tenants)
    and (b) plan overrides — the latter map 1:1 onto
    :func:`derive_serve_plan` keywords via :meth:`plan_overrides`, so
    adding a plan knob means adding a field + one mapping entry, not new
    plumbing."""

    arch: str
    engine: str = "batched"
    batch: int = 4
    fix_batch: bool = False
    requests: int = 8
    prompt_len: int = 32
    gen: int = 16
    stagger: int = 2
    # ---- ServePlan overrides (1:1 with derive_serve_plan keywords) ----
    max_seq: int = 2048
    prefill_chunk: Optional[int] = None
    slab_width: Optional[int] = None
    pages_per_tile: Optional[int] = None
    no_fused: bool = False
    kv_dtype: Optional[str] = None
    draft: Optional[str] = None
    spec_len: Optional[int] = None
    no_prefix_sharing: bool = False
    slo_ttft_ms: Optional[float] = None
    rolled_steps: Optional[int] = None
    deadline_ms: Optional[float] = None
    retry_limit: int = 3
    stall_limit: int = 256
    # ---- chaos injection (serve/faults.py; docs/ROBUSTNESS.md) ----
    chaos_seed: Optional[int] = None  # None = no injector
    chaos_transient: float = 0.0
    chaos_burst: int = 1
    chaos_nan: float = 0.0
    chaos_pressure: float = 0.0
    chaos_spike_ms: float = 0.0
    chaos_horizon: Optional[int] = None
    # ---- device + family pick ----
    hardware: str = "tpu_v5e"  # registered HardwareSpec the plans derive from
    # Pick the serving plan off the design-space Pareto frontier instead of
    # deriving one: "throughput" | "cost" | "energy" (core/search.py;
    # docs/PLANNER.md).  Individual plan-override flags are ignored then.
    from_family: Optional[str] = None
    # ---- multi-tenant trace replay ----
    # ``replay_trace`` is the canonical spelling (PR 10 freed ``--trace``
    # for execution tracing); ``trace`` remains a deprecation alias and the
    # two fields are kept mirrored in ``__post_init__`` so old callers and
    # old flags keep working unchanged.
    replay_trace: Optional[str] = None  # workload mix, e.g. "chat:4,classify:2"
    trace: Optional[str] = None  # deprecated alias of replay_trace
    tenant_mix: int = 2  # tenants sharing per-tenant system prompts
    # ---- observability (repro.obs; docs/OBSERVABILITY.md) ----
    metrics_out: Optional[str] = None  # metrics dump path (.prom = text format)
    trace_out: Optional[str] = None  # Chrome trace_event JSON path (Perfetto)
    trace_buffer: int = 65536  # tracer ring-buffer capacity (events)

    def __post_init__(self):
        if self.replay_trace is None and self.trace is not None:
            self.replay_trace = self.trace
        elif self.trace is None and self.replay_trace is not None:
            self.trace = self.replay_trace
        elif (
            self.trace is not None
            and self.replay_trace is not None
            and self.trace != self.replay_trace
        ):
            raise ValueError(
                "--trace (deprecated) and --replay-trace disagree: "
                f"{self.trace!r} vs {self.replay_trace!r}"
            )

    @classmethod
    def from_namespace(cls, ns: argparse.Namespace) -> "ServeArgs":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in vars(ns).items() if k in names})

    def plan_overrides(self) -> dict:
        """Keyword arguments for :func:`derive_serve_plan`."""
        return {
            "max_seq_len": self.max_seq,
            "decode_batch": self.batch if self.fix_batch else None,
            "prefill_chunk": self.prefill_chunk,
            "mixed_slab_width": self.slab_width,
            "pages_per_tile": self.pages_per_tile,
            "fused_attention": not self.no_fused,
            "kv_dtype": self.kv_dtype,
            "draft": self.draft or "none",
            "spec_len": self.spec_len,
            "prefix_sharing": not self.no_prefix_sharing,
            "slo_ttft_ms": self.slo_ttft_ms,
            "rolled_steps": self.rolled_steps,
            "typical_prompt_len": self.prompt_len,
            "deadline_ms": self.deadline_ms,
            "retry_limit": self.retry_limit,
            "stall_limit": self.stall_limit,
        }

    def make_injector(self):
        """Build the chaos injector when any --chaos-* flag asks for one."""
        if self.chaos_seed is None:
            return None
        from repro.serve import FaultInjector

        return FaultInjector(
            self.chaos_seed,
            transient_rate=self.chaos_transient,
            transient_burst=self.chaos_burst,
            nan_rate=self.chaos_nan,
            pressure_rate=self.chaos_pressure,
            spike_rate=1.0 if self.chaos_spike_ms > 0 else 0.0,
            spike_ms=self.chaos_spike_ms,
            horizon=self.chaos_horizon,
        )

    def make_observability(self):
        """The engine's observability bundle: metrics + drift always on,
        lifecycle tracing only when ``--trace-out`` asks for the export."""
        from repro.obs import Observability

        return Observability(
            tracing=self.trace_out is not None, trace_buffer=self.trace_buffer
        )

    def request_stream(self, cfg) -> list:
        if self.replay_trace:
            return make_trace(
                cfg,
                parse_mix(self.replay_trace),
                tenants=self.tenant_mix,
                stagger=self.stagger,
                seed=1,
                max_tokens=self.max_seq,
            )
        return random_stream(
            cfg, self.requests, self.prompt_len, self.gen, self.stagger, seed=1
        )


def pick_from_family(a: ServeArgs, cfg, mesh, hw):
    """Resolve the ServePlan from the Pareto frontier (--from-family).

    The search is restricted to the launcher's actual model-axis degree so
    the picked plan is runnable on this mesh; the criterion selects the
    frontier's throughput-, cost-, or energy-optimal point."""
    import dataclasses as _dc

    from repro.core.search import default_space, search_family

    ma = dict(mesh.shape).get("model", 1)
    space = _dc.replace(
        default_space(hw, max_seq_len=a.max_seq), mesh_models=(ma,)
    )
    result = search_family(cfg, hw, space)
    if not result.frontier:
        raise SystemExit(f"empty family frontier for {cfg.name} on {hw.name}")
    key = {
        "throughput": lambda p: -p.tokens_per_s,
        "cost": lambda p: p.usd_per_mtok,
        "energy": lambda p: p.mj_per_tok,
    }[a.from_family]
    point = min(result.frontier, key=key)
    print(
        f"family pick ({a.from_family}-optimal of {len(result.frontier)} "
        f"frontier points on {hw.name}): {point.tokens_per_s:.0f} tok/s, "
        f"${point.usd_per_mtok:.3f}/Mtok, {point.mj_per_tok:.2f} mJ/tok, "
        f"tile {point.tile}"
    )
    return point.plan


def run_batched(a: ServeArgs, cfg, mesh) -> dict:
    hw = get_hardware(a.hardware)
    plan = derive_plan(
        cfg, dict(mesh.shape), hw,
        batch=a.batch, seq_len=a.prompt_len, training=False,
    )
    if a.from_family:
        serve = pick_from_family(a, cfg, mesh, hw)
    else:
        serve = derive_serve_plan(cfg, dict(mesh.shape), hw, **a.plan_overrides())
    print(plan.describe())
    print(serve.describe())
    sh = Shardings(mesh, plan, cfg)
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    params = jax.device_put(params, sh.param_shardings(params))
    draft = None
    draft_name = a.draft
    if a.from_family and not draft_name and serve.spec_len > 0:
        draft_name = serve.draft  # the frontier point decided to speculate
    if draft_name and serve.spec_len == 0:
        print("roofline slack leaves no free verification rows at this "
              "decode batch: speculation stays off (gamma = 0)")
    elif draft_name:
        draft = make_draft_source(draft_name, cfg, serve, hw=hw, seed=2)
    injector = a.make_injector()
    if injector is not None:
        print(f"chaos injection on: {injector.to_record()}")
    obs = a.make_observability()
    engine = ServingEngine(
        params, cfg, plan, serve, shardings=sh, draft=draft,
        injector=injector, obs=obs, hw=hw,
    )
    if engine.fused != serve.fused_attention:
        print("multi-device mesh: unified step falls back to the gather path "
              "(Pallas kernel is single-device for now)")
    out = engine.run(a.request_stream(cfg))
    summary = engine.summary()
    if injector is not None:
        print(f"engine health after chaos: {json.dumps(engine.health())}")
    first = next(iter(out))
    print(f"served {len(out)} requests; {first} -> {out[first]}")
    if a.replay_trace:
        summary["classes"] = per_class_report(engine.sched.finished)
    if a.trace_out:
        n = obs.tracer.write(a.trace_out)
        print(f"wrote {n} trace events to {a.trace_out} "
              f"(load in Perfetto: https://ui.perfetto.dev)")
    if a.metrics_out:
        if a.metrics_out.endswith((".prom", ".txt")):
            with open(a.metrics_out, "w") as f:
                f.write(obs.metrics.to_prometheus())
        else:
            with open(a.metrics_out, "w") as f:
                json.dump(obs.metrics.snapshot(), f, indent=1)
        print(f"wrote metrics to {a.metrics_out}")
    cal = summary["calibration"]
    if cal.get("overall_ratio"):
        print(f"planner calibration: {cal['note']}")
    print(json.dumps(summary, indent=1, default=str))
    return summary


def run_eager(a: ServeArgs, cfg, mesh) -> dict:
    plan = derive_plan(
        cfg, dict(mesh.shape), get_hardware(a.hardware),
        batch=a.batch, seq_len=a.prompt_len, training=False,
    )
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (a.batch, a.prompt_len), 0, cfg.vocab_size)
    }
    if cfg.frontend != "none":
        batch["prefix_embeds"] = jax.random.normal(
            key, (a.batch, cfg.n_prefix_embeds, cfg.d_model), jnp.float32
        )
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (a.batch, cfg.enc_seq, cfg.d_model), jnp.float32
        )
    t0 = time.time()
    out = greedy_generate(
        params, cfg, plan, batch, n_steps=a.gen,
        cache_len=a.prompt_len + a.gen,
    )
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({a.batch * a.gen / dt:.1f} tok/s)")
    print(out[0])
    return {"tok_per_s": a.batch * a.gen / dt}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--engine", default="batched", choices=["batched", "eager"])
    ap.add_argument("--batch", type=int, default=4,
                    help="eager batch / batched decode slots (with --fix-batch)")
    ap.add_argument("--fix-batch", action="store_true",
                    help="pin decode_batch to --batch instead of deriving it")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--stagger", type=int, default=2,
                    help="engine iterations between request arrivals")
    ap.add_argument("--max-seq", type=int, default=2048)
    ap.add_argument("--prefill-chunk", type=int, default=None)
    ap.add_argument("--slab-width", type=int, default=None,
                    help="mixed-slab query rows per slot (default: prefill chunk)")
    ap.add_argument("--pages-per-tile", type=int, default=None,
                    help="KV pages per VMEM tile of the fused kernel "
                         "(default: derived from the VMEM budget)")
    ap.add_argument("--no-fused", action="store_true",
                    help="use the dense gather path instead of the fused "
                         "Pallas paged-attention kernel")
    ap.add_argument("--kv-dtype", default=None,
                    choices=[None, "bf16", "int8", "fp32"])
    ap.add_argument("--draft", default=None,
                    help="speculative draft source: 'ngram' (prompt-lookup "
                         "self-drafting) or a config name (e.g. smollm-135m "
                         "drafting for a larger --arch)")
    ap.add_argument("--spec-len", type=int, default=None,
                    help="draft depth gamma per decode slot (default: derived "
                         "from the roofline's compute slack; 0 disables)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable copy-on-write prefix sharing (A/B baseline; "
                         "outputs are byte-identical either way)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="fleet TTFT target fed back into the plan "
                         "(slab width, draft depth)")
    ap.add_argument("--rolled-steps", type=int, default=None,
                    help="cap K of the rolled on-device decode loop (decode "
                         "iterations per dispatch; default: derived from the "
                         "dispatch-overhead roofline; 1 disables)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="fleet-default per-request deadline (wall-clock ms "
                         "from submit); expiry cancels the request and "
                         "releases its blocks")
    ap.add_argument("--retry-limit", type=int, default=3,
                    help="transient-dispatch retries per degradation-ladder "
                         "rung before stepping down rolled -> mixed -> gather")
    ap.add_argument("--stall-limit", type=int, default=256,
                    help="consecutive no-progress iterations before run() "
                         "raises StallError with an engine health dump")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="enable the deterministic fault injector with this "
                         "seed (pair with --chaos-* rates; docs/ROBUSTNESS.md)")
    ap.add_argument("--chaos-transient", type=float, default=0.0,
                    help="per-iteration probability of a transient dispatch "
                         "failure burst")
    ap.add_argument("--chaos-burst", type=int, default=1,
                    help="consecutive dispatch attempts each transient fault "
                         "kills (longer than --retry-limit forces ladder "
                         "escalation)")
    ap.add_argument("--chaos-nan", type=float, default=0.0,
                    help="per-slot per-iteration probability of non-finite "
                         "logits (quarantine + replay keeps outputs "
                         "byte-identical)")
    ap.add_argument("--chaos-pressure", type=float, default=0.0,
                    help="per-iteration probability of a temporary block-pool "
                         "squeeze")
    ap.add_argument("--chaos-spike-ms", type=float, default=0.0,
                    help="artificial per-dispatch latency spike (stresses the "
                         "SLO/EMA feedback); 0 disables")
    ap.add_argument("--chaos-horizon", type=int, default=None,
                    help="iteration after which no new fault fires (lets a "
                         "chaotic stream drain deterministically)")
    ap.add_argument("--hardware", default="tpu_v5e",
                    help="registered HardwareSpec name the plans derive from "
                         "(variants: repro.core.hardware.HARDWARE_VARIANTS)")
    ap.add_argument("--from-family", default=None,
                    choices=[None, "throughput", "cost", "energy"],
                    help="pick the serving plan off the design-space Pareto "
                         "frontier (core/search.py) instead of deriving one; "
                         "the criterion chooses the frontier point")
    ap.add_argument("--replay-trace", default=None, dest="replay_trace",
                    help="multi-tenant trace replay: workload mix spec like "
                         "'chat:4,summarize:2,classify:2' (replaces "
                         "--requests/--prompt-len/--gen)")
    ap.add_argument("--trace", default=None,
                    help="(deprecated alias for --replay-trace; --trace-out "
                         "is the lifecycle-trace export)")
    ap.add_argument("--tenant-mix", type=int, default=2,
                    help="tenants in the trace; each gets a shared system "
                         "prompt its requests all carry")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics registry after the run: *.prom/"
                         "*.txt -> Prometheus text exposition, anything "
                         "else -> JSON snapshot")
    ap.add_argument("--trace-out", default=None,
                    help="write the lifecycle + dispatch trace as Chrome "
                         "trace_event JSON (open in https://ui.perfetto.dev); "
                         "tracing is enabled only when this is set")
    ap.add_argument("--trace-buffer", type=int, default=65536,
                    help="trace ring-buffer capacity in events; older events "
                         "drop first (dropped count recorded in the export)")
    return ap


def main():
    a = ServeArgs.from_namespace(build_parser().parse_args())
    cfg = get_config(a.arch)
    mesh = make_host_mesh()
    if a.engine == "batched" and not serve_feasible(cfg)[0]:
        print(f"{a.arch}: {serve_feasible(cfg)[1]}; falling back to --engine eager")
        a.engine = "eager"
    if a.engine == "batched":
        run_batched(a, cfg, mesh)
    else:
        run_eager(a, cfg, mesh)


if __name__ == "__main__":
    main()
