"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh and record memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

    # accelerator-family mode: sweep the serving design space on one device
    # and print/write the Pareto frontier (tokens/s vs $/token vs J/token)
    PYTHONPATH=src python -m repro.launch.dryrun --family --hardware tpu_v5e

Results go to benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json
(incremental: existing cells are skipped unless --force); --family reports
go to benchmarks/results/family/<hardware>__<arch>.json.
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; this must
# run before ANY other import that touches jax.  --calibrate actually *runs*
# a serving stream, so it keeps the real device topology instead.
import os
import sys

if "--calibrate" not in sys.argv:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALL_SHAPES, ASSIGNED_ARCHS, applicable, get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan, serve_feasible
from repro.core.roofline import analyze, analytic_memory_floor, model_flops_for
from repro.dist.pipeline import bubble_fraction
from repro.dist.sharding import Shardings
from repro.launch.mesh import make_production_mesh, mesh_axes_dict
from repro.models.cache import init_cache
from repro.models.params import init_params
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def input_specs(cfg, shape, plan):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    specs = {}
    if shape.kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        return specs
    text = S
    if cfg.frontend != "none" and cfg.n_prefix_embeds:
        text = S - cfg.n_prefix_embeds
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_prefix_embeds, cfg.d_model), bf16
        )
    if cfg.enc_dec:
        specs["enc_embeds"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), bf16)
    if cfg.vocab_size > 1:
        specs["tokens"] = jax.ShapeDtypeStruct((B, text), i32)
    if shape.kind == "train":
        specs["targets"] = jax.ShapeDtypeStruct((B, text), i32)
    return specs


def build_cell(cfg, shape, mesh, *, plan_overrides=None):
    """Returns (jitted_fn, example_args_as_SDS) for one cell."""
    axes = mesh_axes_dict(mesh)
    overrides = plan_overrides or {}
    plan = derive_plan(
        cfg,
        axes,
        TPU_V5E,
        batch=shape.global_batch,
        seq_len=shape.seq_len,
        training=shape.kind == "train",
        **overrides,
    )
    sh = Shardings(mesh, plan, cfg)
    params_sds = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.bfloat16)
    )
    param_sh = sh.param_shardings(params_sds)
    batch_sds = input_specs(cfg, shape, plan)
    batch_sh = sh.batch_shardings(batch_sds)

    if shape.kind == "train":
        from repro.train.optimizer import TrainState

        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        state_sds = TrainState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            params=params_sds,
            m=jax.tree.map(f32, params_sds),
            v=jax.tree.map(f32, params_sds),
            residual=None,
        )
        state_sh = TrainState(
            step=sh._ns(jax.sharding.PartitionSpec()),
            params=param_sh,
            m=param_sh,
            v=param_sh,
            residual=None,
        )
        step = make_train_step(
            cfg, plan, OptimizerConfig(), shard=sh.constrain,
            grad_shardings=param_sh, mesh=mesh,
        )
        fn = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        args = (state_sds, batch_sds)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, plan, shard=sh.constrain)
        fn = jax.jit(step, in_shardings=(param_sh, batch_sh))
        args = (params_sds, batch_sds)
    else:  # decode
        cache_sds = jax.eval_shape(
            lambda: init_cache(cfg, plan, shape.global_batch, shape.seq_len)
        )
        cache_sh = sh.cache_shardings(cache_sds)
        step = make_decode_step(cfg, plan, shard=sh.constrain)
        fn = jax.jit(
            step,
            in_shardings=(param_sh, batch_sh["tokens"], cache_sh),
            donate_argnums=(2,),
        )
        args = (params_sds, batch_sds["tokens"], cache_sds)
    return fn, args, plan


def run_cell(arch, shape, *, multi_pod, force=False, out_dir=RESULTS,
             plan_overrides=None, tag=""):
    mesh_name = "multi" if multi_pod else "single"
    out = pathlib.Path(out_dir) / mesh_name
    out.mkdir(parents=True, exist_ok=True)
    fname = out / f"{arch}__{shape.name}{tag}.json"
    if fname.exists() and not force:
        return json.loads(fname.read_text())

    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape)
    record = {
        "arch": arch,
        "shape": shape.name,
        "mesh": mesh_name,
        "status": "skipped",
        "reason": reason,
    }
    if ok:
        t0 = time.time()
        try:
            mesh = make_production_mesh(multi_pod=multi_pod)
            n_chips = 512 if multi_pod else 256
            fn, args, plan = build_cell(cfg, shape, mesh, plan_overrides=plan_overrides)
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            ma = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # jax<=0.4.x returns [dict]
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
            rep = analyze(
                arch=arch,
                shape=shape.name,
                mesh_name=mesh_name,
                n_chips=n_chips,
                cost=cost,
                hlo_text=hlo,
                hw=TPU_V5E,
                model_flops=model_flops_for(cfg, shape, shape.kind == "train"),
                arg_bytes=float(ma.argument_size_in_bytes),
                temp_bytes=float(ma.temp_size_in_bytes),
                memory_floor_bytes=analytic_memory_floor(cfg, shape, plan, n_chips),
            )
            record = {
                "status": "ok",
                "lower_s": round(t1 - t0, 2),
                "compile_s": round(t2 - t1, 2),
                "plan": {
                    "mha_mode": plan.mha.mode,
                    "ffn_mode": plan.ffn.mode,
                    "mha_factor1": plan.mha.factor1,
                    "ffn_factor1": plan.ffn.factor1,
                    "fuse_qkv": plan.fuse_qkv,
                    "p_atb": plan.p_atb,
                    "head_shards": plan.head_shards,
                    "remat": plan.remat,
                    "microbatches": plan.microbatches,
                    "embed_shard": plan.embed_shard,
                    "moe_mode": plan.moe_mode,
                    "moe_dispatch": plan.moe_dispatch,
                    "seq_shard": plan.seq_shard,
                    "seq_parallel_acts": plan.seq_parallel_acts,
                    "grad_compression": plan.grad_compression,
                    # pod-axis accounting: role + GPipe bubble the schedule
                    # pays at this (stages, microbatches) point
                    "pod_role": plan.pod_role,
                    "pipeline_stages": (
                        plan.pod_axis if plan.pod_role == "pipeline" else 1
                    ),
                    "pipeline_bubble": (
                        bubble_fraction(plan.microbatches, plan.pod_axis)
                        if plan.pod_role == "pipeline"
                        else 0.0
                    ),
                    # serving cells also record the derived serve knobs
                    # (decode batch / block size / KV dtype / speculative
                    # draft depth) so the plan->serve mapping is
                    # inspectable per mesh; draft="ngram" (the model-free
                    # source every arch can run) makes the record show the
                    # roofline-slack gamma this cell would get
                    "serve": (
                        derive_serve_plan(
                            cfg,
                            mesh_axes_dict(mesh),
                            TPU_V5E,
                            max_seq_len=shape.seq_len,
                            draft="ngram",
                        ).to_record()
                        if shape.kind in ("decode", "prefill")
                        and serve_feasible(cfg)[0]
                        else None
                    ),
                },
                **rep.to_dict(),
            }
        except Exception as e:  # a failure here is a bug in the system
            record = {
                "arch": arch,
                "shape": shape.name,
                "mesh": mesh_name,
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-3000:],
            }
    fname.write_text(json.dumps(record, indent=1, default=str))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--autotune", action="store_true",
        help="search plan candidates for --arch/--shape and report the winner",
    )
    ap.add_argument(
        "--family", action="store_true",
        help="design-space search: emit the Pareto frontier of serving "
             "accelerator variants for --arch on --hardware "
             "(tokens/s vs $/token vs J/token; docs/PLANNER.md)",
    )
    ap.add_argument(
        "--hardware", default="tpu_v5e",
        help="registered device name for --family (see "
             "repro.core.hardware.registered_hardware)",
    )
    ap.add_argument(
        "--calibrate", action="store_true",
        help="run a short serving stream on the real backend and print the "
             "planner drift report: predicted (roofline) vs measured wall "
             "time per phase (docs/OBSERVABILITY.md §Drift)",
    )
    ap.add_argument(
        "--max-seq", type=int, default=2048,
        help="serving context bound for the --family sweep",
    )
    ap.add_argument(
        "--bench-out", default=None,
        help="write an aggregate JSON of all cells run (CI benchmark artifact)",
    )
    a = ap.parse_args()

    if a.calibrate:
        from repro.launch.mesh import make_host_mesh
        from repro.launch.serve import ServeArgs, run_batched

        arch = a.arch or "smollm-135m"
        cfg = get_config(arch)
        ok, reason = serve_feasible(cfg)
        if not ok:
            raise SystemExit(f"{arch}: {reason} (pick a serve-feasible --arch)")
        sargs = ServeArgs(
            arch=arch, requests=6, prompt_len=16, gen=12, stagger=2,
            max_seq=96, batch=3, fix_batch=True, prefill_chunk=16,
            hardware=a.hardware,
        )
        summary = run_batched(sargs, cfg, make_host_mesh())
        cal = summary["calibration"]
        print()
        print(f"planner drift calibration ({arch}, roofline priced as {a.hardware}):")
        for phase, rep in (cal.get("phases") or {}).items():
            if rep is None:
                continue
            print(
                f"  {phase:8s} n={rep['n']:4d}"
                f" predicted={rep['predicted_ms_mean']:8.3f}ms"
                f" measured={rep['measured_ms_mean']:8.3f}ms"
                f" ratio={rep['ratio']:8.2f}"
                f" p50={rep['ratio_p50']:8.2f} p90={rep['ratio_p90']:8.2f}"
            )
        print(f"  overall ratio: {cal.get('overall_ratio')}")
        print(f"  {cal.get('note')}")
        if a.bench_out:
            pathlib.Path(a.bench_out).write_text(
                json.dumps({"arch": arch, "hardware": a.hardware,
                            "calibration": cal}, indent=1, default=str)
            )
            print(f"wrote {a.bench_out}")
        return

    if a.family:
        from repro.core.search import family_report

        arch = a.arch or "qwen3-1.7b"
        out_dir = RESULTS.parent / "family"
        result, record = family_report(
            arch, a.hardware, max_seq_len=a.max_seq, out_dir=out_dir,
        )
        print(record["markdown"])
        print(f"wrote {out_dir / (result.hardware + '__' + arch + '.json')}")
        if a.bench_out:
            pathlib.Path(a.bench_out).write_text(
                json.dumps(record, indent=1, default=str)
            )
            print(f"wrote {a.bench_out}")
        if not result.frontier:
            raise SystemExit("empty frontier: no feasible design point")
        return

    if a.autotune:
        from repro.configs import ALL_SHAPES as _AS
        from repro.core.autotune import autotune

        shape = next(s for s in _AS if s.name == (a.shape or "train_4k"))
        best, scored = autotune(a.arch, shape, multi_pod=a.mesh == "multi")
        for c in scored:
            print(
                f"{c.name:18s} step={c.step_s if c.step_s is None else round(c.step_s, 3)}"
                f" fits={c.fits} err={c.error and c.error[:80]}"
            )
        print(f"winner: {best.name if best else 'none'}")
        return

    archs = [a.arch] if a.arch else list(ASSIGNED_ARCHS)
    shapes = [s for s in ALL_SHAPES if a.shape in (None, s.name)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[a.mesh]
    n_ok = n_err = n_skip = 0
    records = []
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, multi_pod=multi, force=a.force)
                records.append(r)
                status = r.get("status")
                n_ok += status == "ok"
                n_err += status == "error"
                n_skip += status == "skipped"
                extra = (
                    f" bottleneck={r.get('bottleneck')} "
                    f"compile={r.get('compile_s')}s"
                    if status == "ok"
                    else " " + str(r.get("reason") or r.get("error", ""))[:120]
                )
                print(
                    f"[{'multi' if multi else 'single'}] {arch:22s} "
                    f"{shape.name:12s} {status:8s}{extra}",
                    flush=True,
                )
    print(f"done: ok={n_ok} err={n_err} skipped={n_skip}")
    if a.bench_out:
        pathlib.Path(a.bench_out).write_text(
            json.dumps(
                {"ok": n_ok, "err": n_err, "skipped": n_skip, "cells": records},
                indent=1,
                default=str,
            )
        )
        print(f"wrote {a.bench_out} ({len(records)} cells)")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
