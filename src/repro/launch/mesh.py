"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis role is a
plan decision (extra DP by default; pipeline stages optionally — C9).

A function, not a module constant: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_axes_dict(mesh) -> dict:
    return dict(mesh.shape)


def make_host_mesh():
    """Whatever devices exist, as a 1x1xN debug mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh(
        (1, n), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
