"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis role is a
plan decision (extra DP by default; pipeline stages optionally — C9).

A function, not a module constant: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, n_pods: int = 2):
    shape = (n_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_axes_dict(mesh) -> dict:
    return dict(mesh.shape)


def make_host_mesh(*, pod: int = 1, data: int = 1):
    """Whatever devices exist, as a debug mesh (tests/examples).

    ``pod=1, data=1``: (data=1, model=N).  ``data>1``: (data, model=N/data).
    ``pod>1``: (pod, data, model=N/(pod*data)) — the multi-EDPU pipeline
    topology on fake host devices, optionally with data parallelism inside
    each stage."""
    n = len(jax.devices())
    if pod > 1:
        if n % (pod * data):
            raise ValueError(
                f"{n} host devices do not split into {pod} pods x {data} dp"
            )
        return jax.make_mesh(
            (pod, data, n // (pod * data)), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3,
        )
    if data > 1:
        if n % data:
            raise ValueError(f"{n} host devices do not split into data={data}")
        return jax.make_mesh(
            (data, n // data), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2,
        )
    return jax.make_mesh(
        (1, n), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def make_pipeline_mesh(n_stage: int = 0):
    """A 1-D ("pod",) mesh for pipeline_forward (n_stage=0: all devices)."""
    n = n_stage or len(jax.devices())
    return jax.make_mesh(
        (n,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,)
    )
