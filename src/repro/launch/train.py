"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m-reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume

    # pipeline the layer stack over 2 pod stages (+ DP inside each stage)
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m-reduced \
        --steps 20 --batch 8 --seq 64 --pipeline 2 --dp 2

    # Megatron-SP: seq-sharded residual, ring-overlap collectives
    PYTHONPATH=src python -m repro.launch.train --arch bert-base-reduced \
        --steps 20 --batch 8 --seq 64 --dp 2 --seq-parallel

The plan decides, this file executes (docs/ARCHITECTURE.md): pod_role=
"pipeline" routes the step through dist.pipeline (bubble accounting is
printed at startup), --compression rides the compressed_psum wire path
when the mesh is pure-DP and falls back to accumulation-dtype otherwise.

Fault tolerance: periodic async checkpoints (atomic manifests), --resume
picks the latest complete step and the deterministic data pipeline replays
from there; a per-step watchdog flags stragglers (wall-clock budget).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan
from repro.data.pipeline import DataConfig, DataIterator
from repro.dist.sharding import Shardings
from repro.launch.mesh import make_host_mesh
from repro.models.params import init_params
from repro.dist.pipeline import bubble_fraction
from repro.train.compression import CompressionConfig
from repro.train.optimizer import OptimizerConfig, init_state
from repro.train.train_step import make_train_step, wire_compression_axes


class StepWatchdog:
    """Flags steps that exceed a wall-clock budget (straggler detection)."""

    def __init__(self, budget_factor: float = 3.0, warmup: int = 3):
        self.budget_factor = budget_factor
        self.warmup = warmup
        self.times: list[float] = []
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) <= self.warmup:
            return False
        median = sorted(self.times[self.warmup :])[len(self.times[self.warmup :]) // 2]
        if dt > self.budget_factor * max(median, 1e-6):
            self.flagged.append(step)
            return True
        return False


def run(
    arch: str,
    *,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    resume: bool = False,
    compression: str = "none",
    pipeline: int = 0,
    dp: int = 1,
    seq_parallel: bool = False,
    force_mode: str | None = None,
    seed: int = 0,
    dtype=jnp.float32,
    log_every: int = 10,
):
    cfg = get_config(arch)
    if pipeline > 1 and dp == 1:
        # pipeline composes with DP, not TP: fold the spare devices into
        # the data axis instead of leaving a >1 model axis
        dp = max(1, len(jax.devices()) // pipeline)
    mesh = make_host_mesh(pod=pipeline if pipeline > 1 else 1, data=dp)
    plan = derive_plan(
        cfg, dict(mesh.shape), TPU_V5E, batch=batch, seq_len=seq, training=True,
        pod_role="pipeline" if pipeline > 1 else "data",
        seq_parallel=seq_parallel, grad_compression=compression,
        force_mode=force_mode,
    )
    if plan.pod_role == "pipeline" and plan.pod_axis > 1:
        print(
            f"pipeline: {plan.pod_axis} stages x {plan.microbatches} microbatches"
            f" (bubble {bubble_fraction(plan.microbatches, plan.pod_axis):.1%})"
        )
    if seq_parallel and not plan.seq_parallel_acts:
        print("seq-parallel requested but infeasible for this (arch, mesh); off")
    sh = Shardings(mesh, plan, cfg)
    params = init_params(jax.random.PRNGKey(seed), cfg, plan, dtype=dtype)
    param_sh = sh.param_shardings(params)
    params = jax.device_put(params, param_sh)
    # Error-feedback residual only serves the accumulation-dtype fallback;
    # the wire path (compressed_psum) quantizes on a shared grid instead.
    wire = wire_compression_axes(plan, mesh, batch) is not None
    state = init_state(params, with_residual=compression != "none" and not wire)

    opt = OptimizerConfig(peak_lr=lr, warmup_steps=max(steps // 20, 5), total_steps=steps)
    cc = CompressionConfig(mode=compression)
    step_fn = jax.jit(
        make_train_step(
            cfg, plan, opt, shard=sh.constrain, compression=cc,
            grad_shardings=param_sh, mesh=mesh,
        ),
        donate_argnums=(0,),
    )

    start = 0
    if resume and ckpt_dir:
        k = latest_step(ckpt_dir)
        if k is not None:
            # Elastic restore: re-place the big trees on the current mesh so
            # a resumed run keeps the sharded layout of a fresh one.
            state_sh = state._replace(
                step=sh._ns(jax.sharding.PartitionSpec()),
                params=param_sh, m=param_sh, v=param_sh,
                residual=None if state.residual is None else param_sh,
            )
            state = restore_checkpoint(ckpt_dir, k, state, shardings=state_sh)
            start = k
            print(f"resumed from step {k}")

    data = DataIterator(
        DataConfig(cfg.vocab_size, seq, batch, seed=seed), start_step=start
    )
    dog = StepWatchdog()
    losses = []
    pending = None
    batch_sh = None  # built from the first batch; shapes are loop-invariant
    for step in range(start, steps):
        b = next(data)
        if batch_sh is None:
            batch_sh = sh.batch_shardings(b)
        b = jax.device_put(b, batch_sh)
        t0 = time.time()
        state, metrics = step_fn(state, b)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        if dog.observe(step, dt):
            print(f"[watchdog] step {step} took {dt:.2f}s (straggler)")
        if log_every and step % log_every == 0:
            print(
                f"step {step:5d} loss {loss:.4f} gnorm "
                f"{float(metrics['grad_norm']):.3f} ({dt*1e3:.0f} ms)"
            )
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = save_checkpoint(
                ckpt_dir, step + 1, state, meta={"arch": arch}, async_save=True
            )
    if pending is not None:
        pending.join()
    return losses, state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compression", default="none", choices=["none", "bf16", "int8"])
    ap.add_argument(
        "--pipeline", type=int, default=0,
        help="pipeline the layer stack over this many pod stages (0/1: off)",
    )
    ap.add_argument(
        "--dp", type=int, default=1,
        help="data-parallel axis extent of the host mesh",
    )
    ap.add_argument(
        "--seq-parallel", action="store_true",
        help="Megatron-SP: seq-shard the residual over the model axis",
    )
    ap.add_argument("--force-mode", default=None, choices=["spatial", "temporal"])
    a = ap.parse_args()
    losses, _ = run(
        a.arch, steps=a.steps, batch=a.batch, seq=a.seq, lr=a.lr,
        ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every, resume=a.resume,
        compression=a.compression, pipeline=a.pipeline, dp=a.dp,
        seq_parallel=a.seq_parallel, force_mode=a.force_mode,
    )
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
