"""Planner drift meter: predicted step time vs measured wall time.

``core/plan.derive_serve_plan`` and ``core/search.predict_point`` price
every candidate with the same decode roofline — but until now nothing
checked those prices against what a dispatch actually costs, which is why
``BENCH_family.json`` can only report *that* ``ordering_holds`` failed on
a replay, never *why*.  This module closes the loop:

* :func:`step_time_model` freezes the per-dispatch constants of exactly
  the ``predict_point`` roofline (weight stream, KV bytes/token, FLOPs/row,
  ICI, dispatch overhead — see docs/PLANNER.md §Cost model) into a
  :class:`StepTimeModel` whose :meth:`~StepTimeModel.predict_s` is two
  multiplies and a max per dispatch, using the dispatch's *actual* row
  count and resident context instead of the planner's steady-state
  representative (``CTX_FRACTION``);
* :class:`DriftMeter` accumulates ``ratio = measured / predicted`` per
  phase (``prefill`` when any prompt rows ride the slab, else ``decode``;
  rolled spans are ``decode``) with an EWMA and percentile report —
  surfaced as ``engine.summary()["calibration"]``, ``dryrun --calibrate``
  and the family-search replay's per-point drift column.

A ratio of 1.0 means the roofline prices this device perfectly; on the CPU
test backend expect large ratios — that *is* the honest signal explaining
why modeled orderings need not survive replay there.  Compile iterations
are excluded by the engine (same guard as its step-time EMA), so drift
measures steady-state dispatches only.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core.hardware import HardwareSpec

_EPS_S = 1e-12


@dataclasses.dataclass(frozen=True)
class StepTimeModel:
    """Per-dispatch roofline with the plan/hardware constants pre-folded.

    ``predict_s(rows, ctx_tokens, k)`` prices one dispatch of ``k`` device
    iterations, each forwarding ``rows`` live slab rows against
    ``ctx_tokens`` total resident KV positions:

    * memory  — ``weight_bytes + ctx_tokens * kv_bytes_per_token`` (plus
      the dense gather tax when the fused kernel is off) over HBM bandwidth;
    * compute — ``2 * P_active * rows`` over peak FLOP/s;
    * ici     — the per-layer ring all-reduce bytes when model-sharded;
    * total   — ``k * max(memory, compute, ici) + dispatch_overhead`` (one
      host->device dispatch per *span*, which is precisely the rolled
      loop's amortization claim).
    """

    weight_bytes_chip: float
    kv_bytes_per_token_chip: float
    gather_tax_per_token_chip: float  # extra bytes/ctx-token, fused off
    flops_per_row_chip: float
    ici_bytes_per_row_chip: float
    hbm_bandwidth: float
    peak_flops: float
    ici_bandwidth: float
    dispatch_overhead_s: float

    def predict_s(self, rows: float, ctx_tokens: float, k: int = 1) -> float:
        mem_bytes = (
            self.weight_bytes_chip
            + ctx_tokens
            * (self.kv_bytes_per_token_chip + self.gather_tax_per_token_chip)
        )
        t_mem = (
            mem_bytes / self.hbm_bandwidth
            if self.hbm_bandwidth > 0
            else math.inf
        )
        t_comp = (
            self.flops_per_row_chip * rows / self.peak_flops
            if self.peak_flops > 0
            else math.inf
        )
        t_ici = (
            self.ici_bytes_per_row_chip * rows / self.ici_bandwidth
            if self.ici_bytes_per_row_chip and self.ici_bandwidth > 0
            else 0.0
        )
        return max(1, int(k)) * max(t_mem, t_comp, t_ici) + self.dispatch_overhead_s


def step_time_model(
    cfg, serve, hw: HardwareSpec, *, mesh_model: int = 1, fused: bool = True
) -> StepTimeModel:
    """Freeze the ``core/search.predict_point`` roofline terms for one
    (arch, serve plan, device, TP degree) — the engine builds this once at
    construction so per-dispatch prediction costs O(1)."""
    ma = max(1, int(mesh_model))
    p_active = cfg.param_count(active_only=True)
    ici_bytes_per_row = 0.0
    if ma > 1:
        # one ring all-reduce of the (rows, d_model) activations per layer
        ici_bytes_per_row = 2.0 * cfg.d_model * 2.0 * cfg.n_layers * (ma - 1) / ma
    return StepTimeModel(
        weight_bytes_chip=2.0 * p_active / ma,
        kv_bytes_per_token_chip=serve.kv_bytes_per_token / ma,
        gather_tax_per_token_chip=(
            0.0 if fused else 2.0 * serve.kv_bytes_per_token / ma
        ),
        flops_per_row_chip=2.0 * p_active / ma,
        ici_bytes_per_row_chip=ici_bytes_per_row,
        hbm_bandwidth=hw.hbm_bandwidth,
        peak_flops=hw.peak_flops_bf16,
        ici_bandwidth=hw.ici_bandwidth,
        dispatch_overhead_s=hw.dispatch_overhead_s,
    )


class DriftMeter:
    """Accumulates (predicted, measured) dispatch times per phase.

    Bounded memory: per phase, the ratio sample window keeps the most
    recent ``keep`` dispatches (percentiles are over that window) while
    the count / time totals and the EWMA cover the whole run."""

    def __init__(self, *, ewma_alpha: float = 0.1, keep: int = 2048):
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha: must be in (0, 1], got {ewma_alpha}")
        self.ewma_alpha = float(ewma_alpha)
        self.keep = int(keep)
        self._phases: dict = {}

    def record(self, phase: str, predicted_s: float, measured_s: float) -> None:
        s = self._phases.get(phase)
        if s is None:
            s = self._phases[phase] = {
                "n": 0,
                "predicted_s": 0.0,
                "measured_s": 0.0,
                "ratios": collections.deque(maxlen=self.keep),
                "ewma": None,
            }
        ratio = float(measured_s) / max(float(predicted_s), _EPS_S)
        s["n"] += 1
        s["predicted_s"] += float(predicted_s)
        s["measured_s"] += float(measured_s)
        s["ratios"].append(ratio)
        s["ewma"] = (
            ratio
            if s["ewma"] is None
            else (1.0 - self.ewma_alpha) * s["ewma"] + self.ewma_alpha * ratio
        )

    @property
    def empty(self) -> bool:
        return not self._phases

    def phase_report(self, phase: str) -> Optional[dict]:
        s = self._phases.get(phase)
        if s is None or s["n"] == 0:
            return None
        arr = np.asarray(s["ratios"], np.float64)
        return {
            "n": s["n"],
            "predicted_ms_mean": s["predicted_s"] / s["n"] * 1e3,
            "measured_ms_mean": s["measured_s"] / s["n"] * 1e3,
            # aggregate ratio over total time — robust to per-dispatch noise
            "ratio": s["measured_s"] / max(s["predicted_s"], _EPS_S),
            "ratio_ewma": s["ewma"],
            "ratio_p50": float(np.percentile(arr, 50)),
            "ratio_p90": float(np.percentile(arr, 90)),
            "ratio_p99": float(np.percentile(arr, 99)),
        }

    def report(self) -> dict:
        """The ``summary()["calibration"]`` payload: per-phase drift plus a
        one-line verdict a human (or the family-search replay) can quote."""
        phases = {
            ph: self.phase_report(ph) for ph in sorted(self._phases)
        }
        ratios = [p["ratio"] for p in phases.values() if p is not None]
        overall = (
            sum(s["measured_s"] for s in self._phases.values())
            / max(sum(s["predicted_s"] for s in self._phases.values()), _EPS_S)
            if self._phases
            else None
        )
        return {
            "phases": phases,
            "overall_ratio": overall,
            "note": _verdict(overall) if ratios else "no calibrated dispatches",
        }


def _verdict(ratio: Optional[float]) -> str:
    if ratio is None:
        return "no calibrated dispatches"
    if 0.5 <= ratio <= 2.0:
        return (
            f"roofline within 2x of measured (ratio {ratio:.2f}); "
            "modeled orderings should roughly hold here"
        )
    direction = "slower" if ratio > 1 else "faster"
    return (
        f"measured steps are {ratio:.3g}x the roofline prediction "
        f"({direction} than modeled); modeled orderings need not survive "
        "replay on this backend"
    )
