"""Lifecycle tracing: ring-buffered spans exported as Chrome ``trace_event``
JSON (load in Perfetto / ``chrome://tracing``).

Two kinds of tracks:

* the **engine** process (pid 1) carries one span per device dispatch
  (``step`` / ``rolled_step`` / ``fallback_step``), annotated with the slab
  composition, rolled-K and the degradation-ladder rung, plus an instant
  per injected fault (tid 1) tagged with the injector's (seed, salt,
  iteration) so a chaos run is visually replayable;
* the **requests** process (pid 2) gives each request its own thread: the
  ``queued`` span, per-dispatch ``prefill-chunk`` / ``decode`` spans (their
  window is the enclosing step span's window, so lifecycles nest under
  dispatches on the timeline), ``spec-verify`` / ``rollback``, and the
  terminal ``finished`` / ``shed`` / ``evict`` / ``quarantine`` instants.

The backend is a ``deque(maxlen=buffer)`` ring: always-on tracing is O(1)
memory and O(1) per event; overflow drops the *oldest* events and counts
them (``dropped``), never blocks.  A disabled tracer (``enabled=False``)
returns from every emit before touching the ring — the hot path costs one
attribute load + branch, performs no host->device work, and therefore
cannot change ``trace_counts`` or byte output (asserted by the parity
matrix with observability on vs off).

Timestamps are ``time.perf_counter()`` converted to µs relative to the
tracer's birth — the same clock every engine/scheduler ``t_*`` field uses,
so span boundaries line up exactly with the latency accounting.
"""

from __future__ import annotations

import collections
import json
import time
from typing import Optional

# Chrome trace_event pids: one fake "process" per subsystem.
PID_ENGINE = 1
PID_REQUESTS = 2
TID_DISPATCH = 0  # engine pid: device dispatches
TID_FAULTS = 1  # engine pid: chaos injections

# Request threads cycle through a bounded id space so the rid -> tid map
# stays O(1) memory on unbounded streams (collisions only recolor lanes in
# the viewer; events still carry the rid in args).
_MAX_REQUEST_TIDS = 4096


class Tracer:
    """Ring-buffered Chrome trace_event collector (or a no-op when
    ``enabled=False`` — same type, so call sites never branch)."""

    def __init__(self, buffer: int = 65536, enabled: bool = True):
        if buffer <= 0:
            raise ValueError(f"buffer: must be positive, got {buffer}")
        self.enabled = bool(enabled)
        self.buffer = int(buffer)
        self.dropped = 0
        self._events: collections.deque = collections.deque(maxlen=self.buffer)
        self._t0 = time.perf_counter()
        self._rid_tids: dict = {}
        self._next_tid = 0

    # ------------------------------------------------------------- plumbing
    def _ts(self, t: float) -> float:
        return (t - self._t0) * 1e6  # perf_counter seconds -> trace µs

    def _push(self, ev: dict) -> None:
        if len(self._events) == self.buffer:
            self.dropped += 1
        self._events.append(ev)

    def _request_tid(self, rid: str) -> int:
        tid = self._rid_tids.get(rid)
        if tid is None:
            if len(self._rid_tids) >= _MAX_REQUEST_TIDS:
                self._rid_tids.clear()
            tid = self._next_tid % _MAX_REQUEST_TIDS
            self._next_tid += 1
            self._rid_tids[rid] = tid
        return tid

    # ------------------------------------------------------------ emitters
    def complete(
        self,
        name: str,
        pid: int,
        tid: int,
        t0: float,
        t1: float,
        args: Optional[dict] = None,
    ) -> None:
        """One ``ph: X`` complete event over the [t0, t1] perf_counter
        window (clamped to zero duration if the clock went backwards)."""
        if not self.enabled:
            return
        self._push({
            "name": name, "ph": "X", "pid": pid, "tid": tid,
            "ts": self._ts(t0), "dur": max(0.0, (t1 - t0) * 1e6),
            "args": args or {},
        })

    def instant(
        self,
        name: str,
        pid: int,
        tid: int,
        t: Optional[float] = None,
        args: Optional[dict] = None,
    ) -> None:
        if not self.enabled:
            return
        self._push({
            "name": name, "ph": "i", "pid": pid, "tid": tid,
            "ts": self._ts(t if t is not None else time.perf_counter()),
            "s": "t", "args": args or {},
        })

    def request_span(
        self, name: str, rid: str, t0: float, t1: float,
        args: Optional[dict] = None,
    ) -> None:
        if not self.enabled:
            return
        self.complete(
            name, PID_REQUESTS, self._request_tid(rid), t0, t1,
            {"rid": rid, **(args or {})},
        )

    def request_instant(
        self, name: str, rid: str, t: Optional[float] = None,
        args: Optional[dict] = None,
    ) -> None:
        if not self.enabled:
            return
        self.instant(
            name, PID_REQUESTS, self._request_tid(rid), t,
            {"rid": rid, **(args or {})},
        )

    # -------------------------------------------------------------- export
    def chrome_trace(self) -> dict:
        """The Chrome trace_event JSON object (load in Perfetto).

        Events are sorted by timestamp (the ring preserves *completion*
        order; viewers and the golden test want monotone ``ts``), with
        process/thread naming metadata prepended."""
        meta = [
            _meta("process_name", PID_ENGINE, 0, "engine"),
            _meta("thread_name", PID_ENGINE, TID_DISPATCH, "dispatch"),
            _meta("thread_name", PID_ENGINE, TID_FAULTS, "faults"),
            _meta("process_name", PID_REQUESTS, 0, "requests"),
        ]
        for rid, tid in sorted(self._rid_tids.items()):
            meta.append(_meta("thread_name", PID_REQUESTS, tid, rid))
        events = sorted(self._events, key=lambda e: e["ts"])
        return {
            "traceEvents": meta + events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def write(self, path: str) -> int:
        """Dump the Chrome trace to ``path``; returns the event count
        (excluding naming metadata)."""
        doc = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return sum(1 for e in doc["traceEvents"] if e["ph"] != "M")


def _meta(name: str, pid: int, tid: int, label: str) -> dict:
    return {
        "name": name, "ph": "M", "pid": pid, "tid": tid, "ts": 0.0,
        "args": {"name": label},
    }


def validate_chrome_trace(doc: dict) -> list:
    """Assert ``doc`` is structurally valid Chrome ``trace_event`` JSON with
    monotone non-meta timestamps; returns the non-meta events.  Used by the
    golden-file test and the launcher after ``--trace-out``."""
    assert isinstance(doc, dict) and isinstance(doc.get("traceEvents"), list), (
        "trace must be the JSON-object form with a traceEvents list"
    )
    events = []
    last_ts = None
    for ev in doc["traceEvents"]:
        assert isinstance(ev, dict), ev
        assert isinstance(ev.get("name"), str) and ev["name"], ev
        assert ev.get("ph") in {"X", "i", "M"}, ev
        assert isinstance(ev.get("pid"), int), ev
        assert isinstance(ev.get("tid"), int), ev
        ts = ev.get("ts")
        assert isinstance(ts, (int, float)) and ts >= 0.0, ev
        if ev["ph"] == "M":
            continue
        if ev["ph"] == "X":
            dur = ev.get("dur")
            assert isinstance(dur, (int, float)) and dur >= 0.0, ev
        assert last_ts is None or ts >= last_ts, (
            f"timestamps not monotone: {ts} after {last_ts}"
        )
        last_ts = ts
        events.append(ev)
    return events
