"""``repro.obs`` — unified serving observability.

Three layers, one bundle:

* :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
  fixed-bucket histograms with declared labels; Prometheus text exposition
  + JSON snapshot (``launch/serve.py --metrics-out``);
* :class:`~repro.obs.trace.Tracer` — ring-buffered per-request lifecycle
  spans and per-dispatch step spans, exported as Chrome ``trace_event``
  JSON for Perfetto (``--trace-out`` / ``--trace-buffer``);
* :class:`~repro.obs.calibrate.DriftMeter` — predicted (roofline) vs
  measured wall time per dispatch, per phase — the planner's calibration
  signal (``engine.summary()["calibration"]``, ``dryrun --calibrate``).

:class:`Observability` is the bundle the engine, scheduler, draft sources
and fault injector all emit into.  The default construction
(``Observability()``) is what every engine gets when the caller passes
nothing: metrics + drift on (pure host dict arithmetic), tracing *off* —
the disabled tracer returns before touching its ring, so the engine hot
path is unchanged (no extra device dispatches; the parity matrix asserts
byte-identical output and identical ``trace_counts`` with tracing on).

Metric catalog, label schema and the span taxonomy: docs/OBSERVABILITY.md.
"""

from __future__ import annotations

from typing import Optional

from repro.obs.calibrate import DriftMeter, StepTimeModel, step_time_model
from repro.obs.metrics import (
    DEFAULT_MS_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    parse_prometheus_text,
    prometheus_roundtrip_ok,
)
from repro.obs.trace import (
    PID_ENGINE,
    PID_REQUESTS,
    TID_DISPATCH,
    TID_FAULTS,
    Tracer,
    validate_chrome_trace,
)


class Observability:
    """The per-engine observability bundle + its emission API.

    Every hook is host-side accounting only — no jax calls, no shapes, no
    device work — so enabling or disabling observability can never perturb
    the engine's byte output or its no-retrace contract.
    """

    def __init__(
        self,
        *,
        tracing: bool = False,
        trace_buffer: int = 65536,
        metrics: Optional[MetricsRegistry] = None,
        tracer: Optional[Tracer] = None,
        drift: Optional[DriftMeter] = None,
    ):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = (
            tracer
            if tracer is not None
            else Tracer(trace_buffer, enabled=tracing)
        )
        self.drift = drift if drift is not None else DriftMeter()
        m = self.metrics
        # ---- the serving metric catalog (docs/OBSERVABILITY.md) ----------
        self.m_submitted = m.counter(
            "serve_requests_submitted_total",
            "Requests entering the waiting queue", ("tenant", "wclass"),
        )
        self.m_finished = m.counter(
            "serve_requests_finished_total",
            "Requests retired, by disposition", ("tenant", "status"),
        )
        self.m_admissions = m.counter(
            "serve_admissions_total", "Slot admissions (incl. re-admissions)"
        )
        self.m_evictions = m.counter(
            "serve_evictions_total", "Recompute-style preemptions"
        )
        self.m_prefix_hits = m.counter(
            "serve_prefix_hits_total", "Admissions that reused a resident prefix"
        )
        self.m_prefix_saved = m.counter(
            "serve_prefix_tokens_saved_total", "Prompt tokens never re-prefilled"
        )
        self.m_forks = m.counter(
            "serve_forks_total", "Copy-on-write forks scheduled at admission"
        )
        self.m_steps = m.counter(
            "serve_steps_total",
            "Device dispatches by program kind", ("kind",),
        )
        self.m_tokens = m.counter(
            "serve_tokens_total",
            "Slab rows consumed, by kind (generated = emitted output tokens;"
            " prefill = prompt rows)", ("kind",),
        )
        self.m_draft_rows = m.counter(
            "serve_draft_rows_total", "Speculative rows submitted for verification"
        )
        self.m_draft_accepted = m.counter(
            "serve_draft_accepted_total", "Draft rows the target accepted"
        )
        self.m_draft_rounds = m.counter(
            "serve_draft_rounds_total",
            "Draft proposal rounds, by source", ("source",),
        )
        self.m_draft_proposed = m.counter(
            "serve_draft_proposed_total",
            "Draft tokens proposed, by source", ("source",),
        )
        self.m_draft_steps = m.counter(
            "serve_draft_device_steps_total",
            "Drafter device dispatches, by source", ("source",),
        )
        self.m_quarantines = m.counter(
            "serve_quarantines_total", "Non-finite slot-steps quarantined"
        )
        self.m_retries = m.counter(
            "serve_retries_total", "Transient-fault dispatch retries"
        )
        self.m_faults = m.counter(
            "serve_faults_injected_total",
            "Chaos injections fired, by kind", ("kind",),
        )
        self.m_rung_changes = m.counter(
            "serve_rung_changes_total",
            "Degradation-ladder moves", ("direction",),
        )
        self.m_rung = m.gauge(
            "serve_rung", "Current ladder rung (0 rolled, 1 mixed, 2 gather)"
        )
        self.m_blocks_in_use = m.gauge(
            "serve_blocks_in_use", "KV pool blocks currently referenced"
        )
        self.m_blocks_available = m.gauge(
            "serve_blocks_available", "KV pool blocks free"
        )
        self.m_slots_active = m.gauge(
            "serve_slots_active", "Decode slots holding a request"
        )
        self.m_queue_depth = m.gauge(
            "serve_queue_depth", "Requests waiting (arrived or future)"
        )
        self.m_step_ms = m.histogram(
            "serve_step_ms",
            "Measured device dispatch wall time (whole span for rolled)",
            ("phase",),
        )
        self.m_ttft_ms = m.histogram(
            "serve_ttft_ms", "Admit -> first token", ("tenant",)
        )
        self.m_latency_ms = m.histogram(
            "serve_latency_ms", "Admit -> done (finished only)", ("tenant",)
        )

    # ------------------------------------------------------ request events
    def on_submit(self, req) -> None:
        self.m_submitted.inc(tenant=req.tenant, wclass=req.tag or "")

    def on_admit(
        self, req, now: float, *, prefix_tokens: int = 0, forked: bool = False
    ) -> None:
        self.m_admissions.inc()
        if prefix_tokens > 0:
            self.m_prefix_hits.inc()
            self.m_prefix_saved.inc(prefix_tokens)
        if forked:
            self.m_forks.inc()
        if req.t_submit is not None:
            self.tracer.request_span(
                "queued", req.rid, req.t_submit, now,
                {"tenant": req.tenant, "wclass": req.tag or "",
                 "prefix_tokens": prefix_tokens},
            )
        self.tracer.request_instant(
            "admitted", req.rid, now, {"slot": req.slot}
        )

    def on_finish(self, req, now: float) -> None:
        self.m_finished.inc(tenant=req.tenant, status="ok")
        if req.t_admit is not None:
            if req.t_first is not None:
                self.m_ttft_ms.observe(
                    (req.t_first - req.t_admit) * 1e3, tenant=req.tenant
                )
            self.m_latency_ms.observe(
                (now - req.t_admit) * 1e3, tenant=req.tenant
            )
        t0 = req.t_submit if req.t_submit is not None else now
        self.tracer.request_span(
            "request", req.rid, t0, now,
            {"tenant": req.tenant, "wclass": req.tag or "",
             "tokens": len(req.out), "status": "ok"},
        )
        self.tracer.request_instant("finished", req.rid, now)

    def on_cancel(self, req, status: str, now: float) -> None:
        """A request retired without completing: shed / expired / cancelled
        / poisoned."""
        self.m_finished.inc(tenant=req.tenant, status=status)
        self.tracer.request_instant(
            status, req.rid, now, {"tenant": req.tenant}
        )

    def on_evict(self, req, now: float) -> None:
        self.m_evictions.inc()
        self.tracer.request_instant("evict", req.rid, now)

    def on_quarantine(self, req, now: float) -> None:
        self.m_quarantines.inc()
        self.tracer.request_instant(
            "quarantine", req.rid, now, {"streak": req.quarantine_streak}
        )

    # ----------------------------------------------------- dispatch events
    def on_dispatch(
        self,
        kind: str,
        phase: str,
        t0: float,
        t1: float,
        *,
        rows: int,
        composition: Optional[dict] = None,
        rung: str = "",
        k: int = 1,
        predicted_s: Optional[float] = None,
        calibrated: bool = True,
    ) -> None:
        """One device dispatch (a step, a rolled span, or the gather
        fallback).  ``calibrated=False`` (a compile iteration) records the
        step metric but keeps the drift meter clean."""
        measured_s = t1 - t0
        self.m_steps.inc(kind=kind)
        self.m_step_ms.observe(measured_s * 1e3, phase=phase)
        if calibrated and predicted_s is not None:
            self.drift.record(phase, predicted_s, measured_s)
        args = {
            "phase": phase, "rows": rows, "rung": rung, "k": k,
            "measured_ms": measured_s * 1e3,
        }
        if predicted_s is not None:
            args["predicted_ms"] = predicted_s * 1e3
            args["calibrated"] = bool(calibrated)
        if composition:
            args["kinds"] = dict(composition)
        self.tracer.complete(kind, PID_ENGINE, TID_DISPATCH, t0, t1, args)

    def on_step_counts(self, c: dict) -> None:
        """Fold one dispatch's accounting dict (``_slab_done`` /
        ``_rolled_done`` return value) into the token counters."""
        if c.get("generated"):
            self.m_tokens.inc(c["generated"], kind="generated")
        if c.get("prefill"):
            self.m_tokens.inc(c["prefill"], kind="prefill")
        if c.get("draft_rows"):
            self.m_draft_rows.inc(c["draft_rows"])
        if c.get("accepted_drafts"):
            self.m_draft_accepted.inc(c["accepted_drafts"])

    def on_draft_round(
        self, source: str, n_asks: int, n_proposed: int, device_steps: int = 0
    ) -> None:
        """One draft-source proposal round (speculative decoding)."""
        self.m_draft_rounds.inc(source=source)
        if n_proposed:
            self.m_draft_proposed.inc(n_proposed, source=source)
        if device_steps:
            self.m_draft_steps.inc(device_steps, source=source)

    def set_pool(
        self, *, available: int, in_use: int, active: int, queued: int
    ) -> None:
        self.m_blocks_available.set(available)
        self.m_blocks_in_use.set(in_use)
        self.m_slots_active.set(active)
        self.m_queue_depth.set(queued)

    # ------------------------------------------------- faults + the ladder
    def on_fault(
        self,
        kind: str,
        *,
        seed: int,
        salt: int,
        iteration: int,
        t: Optional[float] = None,
        **extra,
    ) -> None:
        """One chaos injection, tagged with the injector's determinism key
        (seed, salt, iteration) so a trace visually replays the schedule."""
        self.m_faults.inc(kind=kind)
        self.tracer.instant(
            f"fault:{kind}", PID_ENGINE, TID_FAULTS, t,
            {"seed": seed, "salt": salt, "iteration": iteration, **extra},
        )

    def on_retry(self) -> None:
        self.m_retries.inc()

    def on_rung(self, direction: str, rung: int, rung_name: str) -> None:
        self.m_rung_changes.inc(direction=direction)
        self.m_rung.set(rung)
        self.tracer.instant(
            f"rung:{direction}", PID_ENGINE, TID_DISPATCH, None,
            {"rung": rung, "rung_name": rung_name},
        )


__all__ = [
    "Observability",
    # metrics layer
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "DEFAULT_MS_BUCKETS",
    "parse_prometheus_text",
    "prometheus_roundtrip_ok",
    # tracing layer
    "Tracer",
    "validate_chrome_trace",
    "PID_ENGINE",
    "PID_REQUESTS",
    "TID_DISPATCH",
    "TID_FAULTS",
    # calibration layer
    "DriftMeter",
    "StepTimeModel",
    "step_time_model",
]
