"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

One :class:`MetricsRegistry` per serving engine (engine, scheduler, prefix
index, draft sources and the fault injector all emit into it) — the single
structured home for the numbers ``engine.summary()`` used to scatter across
bespoke dict sections.  The ``summary()`` sections remain as back-compat
aliases; this registry is the machine-readable source the launcher exports
(``--metrics-out``).

Design constraints, in order:

* **hot-path cheap** — an ``inc``/``observe`` is one tuple build and one
  dict update on the host; no locks (the engine is single-threaded by
  construction), no string formatting until exposition time;
* **fixed label sets** — every metric declares its label names up front
  and every sample must bind exactly those names, so cardinality is a
  review-time decision, never a runtime surprise;
* **fixed buckets** — histograms never rebucket; the defaults cover the
  step-time and request-latency ranges the serving stack produces
  (sub-ms CPU steps through multi-second chaos runs);
* **two wire formats** — a JSON-able :meth:`MetricsRegistry.snapshot` and
  a Prometheus text exposition (:meth:`MetricsRegistry.to_prometheus`)
  that round-trips through :func:`parse_prometheus_text` (asserted by the
  CI serving-smoke lane).

The full metric catalog with label schemas lives in docs/OBSERVABILITY.md.
"""

from __future__ import annotations

import math
import re
from typing import Optional, Union

Number = Union[int, float]

# Prometheus metric / label name grammar (we enforce at registration so a
# bad name fails at construction, not at scrape time).
_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Milliseconds: spans sub-ms fake-device steps through chaos-spiked multi-
# second tails.  Shared by step-time and request-latency histograms so
# cross-metric comparison needs no bucket translation.
DEFAULT_MS_BUCKETS = (
    0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0,
    200.0, 500.0, 1000.0, 2000.0, 5000.0,
)


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(f"invalid metric name {name!r}")
    return name


def _fmt(v: float) -> str:
    """Prometheus float formatting: integers without the trailing .0 (so
    counters read naturally), everything else via repr (round-trip exact)."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class Metric:
    """Base: a named family of samples keyed by label-value tuples."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labels: tuple = ()):
        self.name = _check_name(name)
        self.help = help
        for ln in labels:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        self.labels = tuple(labels)
        self._samples: dict = {}

    def _key(self, kv: dict) -> tuple:
        if tuple(sorted(kv)) != tuple(sorted(self.labels)):
            raise ValueError(
                f"{self.name}: expected labels {self.labels}, got {tuple(kv)}"
            )
        return tuple(str(kv[ln]) for ln in self.labels)

    def _label_dict(self, key: tuple) -> dict:
        return dict(zip(self.labels, key))


class Counter(Metric):
    kind = "counter"

    def inc(self, amount: Number = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up, got {amount}")
        k = self._key(labels)
        self._samples[k] = self._samples.get(k, 0) + amount

    def value(self, **labels) -> Number:
        return self._samples.get(self._key(labels), 0)


class Gauge(Metric):
    kind = "gauge"

    def set(self, value: Number, **labels) -> None:
        self._samples[self._key(labels)] = value

    def value(self, **labels) -> Number:
        return self._samples.get(self._key(labels), 0)


class Histogram(Metric):
    """Fixed cumulative buckets + sum + count, one set per label binding."""

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str = "",
        labels: tuple = (),
        buckets: tuple = DEFAULT_MS_BUCKETS,
    ):
        super().__init__(name, help, labels)
        bs = tuple(float(b) for b in buckets)
        if not bs or list(bs) != sorted(bs):
            raise ValueError(f"{name}: buckets must be non-empty and sorted")
        self.buckets = bs

    def observe(self, value: Number, **labels) -> None:
        k = self._key(labels)
        s = self._samples.get(k)
        if s is None:
            s = self._samples[k] = {
                "buckets": [0] * len(self.buckets), "sum": 0.0, "count": 0,
            }
        v = float(value)
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                s["buckets"][i] += 1
                break
        s["sum"] += v
        s["count"] += 1


class MetricsRegistry:
    """Get-or-create factory + the two exposition formats."""

    def __init__(self):
        self._metrics: dict[str, Metric] = {}

    def _get(self, cls, name: str, help: str, labels: tuple, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if type(m) is not cls or m.labels != tuple(labels):
                raise ValueError(
                    f"metric {name!r} re-registered as {cls.kind}"
                    f"{tuple(labels)} (was {m.kind}{m.labels})"
                )
            return m
        m = cls(name, help, tuple(labels), **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "", labels: tuple = ()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels: tuple = ()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        labels: tuple = (),
        buckets: tuple = DEFAULT_MS_BUCKETS,
    ) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    # ------------------------------------------------------- JSON snapshot
    def snapshot(self) -> dict:
        """JSON-able view: {name: {type, help, labels, samples}}."""
        out: dict = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            samples = []
            for key in sorted(m._samples):
                rec: dict = {"labels": m._label_dict(key)}
                s = m._samples[key]
                if isinstance(s, dict):  # histogram
                    rec["buckets"] = {
                        _fmt(edge): int(c)
                        for edge, c in zip(m.buckets, s["buckets"])
                    }
                    rec["sum"] = s["sum"]
                    rec["count"] = s["count"]
                else:
                    rec["value"] = s
                samples.append(rec)
            out[name] = {
                "type": m.kind,
                "help": m.help,
                "labels": list(m.labels),
                "samples": samples,
            }
        return out

    # ------------------------------------------- Prometheus text exposition
    def flat_samples(self) -> dict:
        """Every exposed series as {(name, ((label, value), ...)): float} —
        histogram buckets expand to ``_bucket``/``_sum``/``_count`` series
        exactly as the text format does.  This is the round-trip oracle:
        ``parse_prometheus_text(to_prometheus())`` must equal it."""
        flat: dict = {}
        for m in self._metrics.values():
            for key, s in m._samples.items():
                base = tuple(sorted(m._label_dict(key).items()))
                if isinstance(s, dict):  # histogram
                    cum = 0
                    for edge, c in zip(m.buckets, s["buckets"]):
                        cum += c
                        flat[
                            m.name + "_bucket",
                            tuple(sorted(base + (("le", _fmt(edge)),))),
                        ] = float(cum)
                    flat[
                        m.name + "_bucket",
                        tuple(sorted(base + (("le", "+Inf"),))),
                    ] = float(s["count"])
                    flat[m.name + "_sum", base] = float(s["sum"])
                    flat[m.name + "_count", base] = float(s["count"])
                else:
                    flat[m.name, base] = float(s)
        return flat

    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: list = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {m.name} {_escape(m.help)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for key in sorted(m._samples):
                s = m._samples[key]
                base = m._label_dict(key)
                if isinstance(s, dict):  # histogram
                    cum = 0
                    for edge, c in zip(m.buckets, s["buckets"]):
                        cum += c
                        lines.append(
                            _series(m.name + "_bucket",
                                    {**base, "le": _fmt(edge)}, cum)
                        )
                    lines.append(
                        _series(m.name + "_bucket",
                                {**base, "le": "+Inf"}, s["count"])
                    )
                    lines.append(_series(m.name + "_sum", base, s["sum"]))
                    lines.append(_series(m.name + "_count", base, s["count"]))
                else:
                    lines.append(_series(m.name, base, s))
        return "\n".join(lines) + "\n"


def _series(name: str, labels: dict, value: Number) -> str:
    if labels:
        body = ",".join(
            f'{k}="{_escape(str(v))}"' for k, v in sorted(labels.items())
        )
        return f"{name}{{{body}}} {_fmt(value)}"
    return f"{name} {_fmt(value)}"


_SERIES_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)$"
)
_LABEL_PAIR_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus_text(text: str) -> dict:
    """Parse an exposition back to {(name, sorted label tuple): float}.

    The inverse of :meth:`MetricsRegistry.to_prometheus` over everything
    the registry emits — the CI serving-smoke lane asserts
    ``parse(to_prometheus()) == flat_samples()`` so the export is known
    machine-readable, not merely printable."""
    out: dict = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = _SERIES_RE.match(line)
        if not m:
            raise ValueError(f"unparseable exposition line: {raw!r}")
        labels = []
        if m.group("labels"):
            for lm in _LABEL_PAIR_RE.finditer(m.group("labels")):
                val = (
                    lm.group(2)
                    .replace("\\n", "\n")
                    .replace('\\"', '"')
                    .replace("\\\\", "\\")
                )
                labels.append((lm.group(1), val))
        v = m.group("value")
        value = math.inf if v == "+Inf" else (
            -math.inf if v == "-Inf" else float(v)
        )
        out[m.group("name"), tuple(sorted(labels))] = value
    return out


def prometheus_roundtrip_ok(reg: MetricsRegistry) -> bool:
    """True iff the text exposition parses back to exactly the registry's
    flat sample map (names, labels and values)."""
    return parse_prometheus_text(reg.to_prometheus()) == reg.flat_samples()
