"""Compat shims for the pinned jax toolchain.

The container bakes jax 0.4.37; parts of the codebase (and the test
contracts) use two newer-jax surfaces:

  * ``jax.sharding.AxisType`` (Auto/Explicit/Manual mesh axis kinds)
  * ``jax.make_mesh(..., axis_types=...)``

On 0.4.x every mesh axis already behaves as ``Auto``, so the shim supplies
the enum and teaches ``jax.make_mesh`` to accept (and ignore) the kwarg.
Applied idempotently from ``repro/__init__`` — no-op on newer jax.
"""
from __future__ import annotations

import enum
import functools
import inspect
import os


def _default_platform() -> None:
    """Pin JAX_PLATFORMS=cpu when no accelerator runtime is visible.

    Backend auto-probing can hang for minutes in stripped environments
    (subprocess tests, CI) while it looks for TPU/GPU runtimes that are not
    there.  Runs before backend init (first device access), never overrides
    an explicit setting, and stays out of the way on real accelerators.
    """
    if "JAX_PLATFORMS" in os.environ:
        return
    has_gpu = os.path.exists("/dev/nvidia0")
    # Hardware, not packages: an installed libtpu without a TPU attached
    # burns ~30 metadata-server retries per variable before giving up.
    has_tpu = os.path.exists("/dev/accel0") or "TPU_NAME" in os.environ
    if not (has_gpu or has_tpu):
        os.environ["JAX_PLATFORMS"] = "cpu"


def ensure_jax_compat() -> None:
    _default_platform()
    import jax
    import jax.sharding as jsh

    # jax snapshots JAX_PLATFORMS at import; if jax was imported before us
    # (the usual order in scripts) the env var alone is too late.
    platforms = os.environ.get("JAX_PLATFORMS")
    if platforms and getattr(jax.config, "jax_platforms", None) != platforms:
        jax.config.update("jax_platforms", platforms)

    if not hasattr(jsh, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jsh.AxisType = AxisType

    if not getattr(jax.make_mesh, "_repro_axis_types_shim", False):
        params = inspect.signature(jax.make_mesh).parameters
        if "axis_types" not in params:
            orig = jax.make_mesh

            @functools.wraps(orig)
            def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kw):
                del axis_types  # 0.4.x meshes are implicitly Auto
                return orig(axis_shapes, axis_names, *args, **kw)

            make_mesh._repro_axis_types_shim = True
            jax.make_mesh = make_mesh
