"""CAT reproduction: customized transformer accelerator framework in JAX.

Importing ``repro`` applies the pinned-toolchain jax compat shims so every
entry point (launchers, tests, subprocess snippets) sees the same jax
surface regardless of the installed 0.4.x/0.5.x version.
"""
from repro._jax_compat import ensure_jax_compat

ensure_jax_compat()
