"""Decode caches.

Per layer kind:
  attn/swa/local : {"k","v": (B, Sc, KV, Dh), "t": ()}   Sc = window for
                   windowed layers (ring buffer; softmax is permutation-
                   invariant over kv so ring order is free), else cache_len.
  rglru          : {"h": (B, W), "conv": (B, cw-1, W)}
  rwkv6          : {"rwkv": {"S": (B,H,D,D), "shift": (B,d)}, "cmix": (B,d)}
Enc-dec adds {"memory": (B, Se, d)} and per-decoder-layer {"cross_kv"}.

The cache tree mirrors params ({"stack": ..., "tail": ...}) so the layer scan
threads it.  ``init_cache`` builds zero caches (or ShapeDtypeStructs under
``jax.eval_shape`` for the dry-run); ``cache_from_prefill`` turns a
collect_cache=True forward pass into a decode-ready cache.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan

PyTree = Any


def _layer_cache(cfg: ArchConfig, kind: str, B: int, cache_len: int, dtype):
    KV, Dh, d = cfg.n_kv_heads, cfg.d_head, cfg.d_model
    entry: dict = {}
    if kind in ("attn", "swa", "local"):
        window = (
            cfg.sliding_window
            if kind == "swa"
            else cfg.local_window if kind == "local" else 0
        )
        Sc = min(window, cache_len) if window else cache_len
        entry["attn"] = {
            "k": jnp.zeros((B, Sc, KV, Dh), dtype),
            "v": jnp.zeros((B, Sc, KV, Dh), dtype),
            "t": jnp.zeros((), jnp.int32),
        }
    elif kind == "rglru":
        W = cfg.lru_width or cfg.d_model
        entry["rglru"] = {
            "h": jnp.zeros((B, W), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_width - 1, W), jnp.float32),
        }
    elif kind == "rwkv6":
        H = cfg.rnn_heads
        entry["rwkv"] = {
            "S": jnp.zeros((B, H, Dh, Dh), jnp.float32),
            "shift": jnp.zeros((B, d), jnp.float32),
        }
        entry["cmix"] = jnp.zeros((B, d), jnp.float32)
    if cfg.enc_dec:
        entry["cross_kv"] = (
            jnp.zeros((B, cfg.enc_seq, KV, Dh), dtype),
            jnp.zeros((B, cfg.enc_seq, KV, Dh), dtype),
        )
    return entry


def init_cache(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    batch_size: int,
    cache_len: int,
    dtype=jnp.bfloat16,
) -> PyTree:
    pattern = cfg.layer_pattern
    n_full, rem = divmod(cfg.n_layers, len(pattern))

    def group(_):
        return tuple(
            _layer_cache(cfg, kind, batch_size, cache_len, dtype) for kind in pattern
        )

    groups = [group(i) for i in range(n_full)]
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *groups) if n_full else None
    tail = tuple(
        _layer_cache(cfg, pattern[i], batch_size, cache_len, dtype)
        for i in range(rem)
    )
    cache: dict = {
        "layers": {"stack": stack, "tail": tail},
        "t": jnp.zeros((), jnp.int32),
    }
    if cfg.enc_dec:
        cache["memory"] = jnp.zeros((batch_size, cfg.enc_seq, cfg.d_model), dtype)
    return cache


def _kv_to_ring(k: jax.Array, v: jax.Array, Sc: int, dtype):
    """Place a prefill's (B,S,KV,D) kv into an Sc-slot cache at slot = pos % Sc."""
    B, S, KV, Dh = k.shape
    if S <= Sc:
        pad = [(0, 0), (0, Sc - S), (0, 0), (0, 0)]
        return jnp.pad(k, pad).astype(dtype), jnp.pad(v, pad).astype(dtype)
    keep = jnp.arange(S - Sc, S)
    slots = keep % Sc
    kk = jnp.zeros((B, Sc, KV, Dh), dtype).at[:, slots].set(
        k[:, keep].astype(dtype)
    )
    vv = jnp.zeros((B, Sc, KV, Dh), dtype).at[:, slots].set(
        v[:, keep].astype(dtype)
    )
    return kk, vv


def cache_from_prefill(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    prefill_cache: PyTree,
    cache_len: int,
    dtype=jnp.bfloat16,
) -> PyTree:
    """Convert the collect_cache=True output of ``forward`` into a decode cache.

    Call outside jit (the prefill length is read as a python int)."""
    S = int(prefill_cache["t"])
    pattern = cfg.layer_pattern
    layers = prefill_cache["layers"]

    def convert_entry(entry, kind):
        e = dict(entry)
        if "kv_out" in e:
            k, v = e.pop("kv_out")
            window = (
                cfg.sliding_window
                if kind == "swa"
                else cfg.local_window if kind == "local" else 0
            )
            Sc = min(window, cache_len) if window else cache_len
            kk, vv = _kv_to_ring(k, v, Sc, dtype)
            e["attn"] = {"k": kk, "v": vv, "t": jnp.asarray(S, jnp.int32)}
        return e

    new_stack = None
    if layers["stack"] is not None:
        new_stack = _convert_stacked(layers["stack"], pattern, cfg, cache_len, S, dtype)
    new_tail = tuple(
        convert_entry(layers["tail"][i], pattern[i % len(pattern)])
        for i in range(len(layers["tail"]))
    )
    cache = {
        "layers": {"stack": new_stack, "tail": new_tail},
        "t": jnp.asarray(S, jnp.int32),
    }
    if "memory" in prefill_cache:
        cache["memory"] = prefill_cache["memory"]
    return cache


def _convert_stacked(stack, pattern, cfg, cache_len, S, dtype):
    out = []
    for i, kind in enumerate(pattern):
        entry = dict(stack[i])
        if "kv_out" in entry:
            k, v = entry.pop("kv_out")  # (n_groups, B, S, KV, Dh)
            window = (
                cfg.sliding_window
                if kind == "swa"
                else cfg.local_window if kind == "local" else 0
            )
            Sc = min(window, cache_len) if window else cache_len
            kk, vv = jax.vmap(lambda a, b: _kv_to_ring(a, b, Sc, dtype))(k, v)
            n = k.shape[0]
            entry["attn"] = {
                "k": kk,
                "v": vv,
                "t": jnp.full((n,), S, jnp.int32),
            }
        out.append(entry)
    return tuple(out)
