"""Decode caches.

Per layer kind:
  attn/swa/local : {"k","v": (B, Sc, KV, Dh), "t": ()}   Sc = window for
                   windowed layers (ring buffer; softmax is permutation-
                   invariant over kv so ring order is free), else cache_len.
  rglru          : {"h": (B, W), "conv": (B, cw-1, W)}
  rwkv6          : {"rwkv": {"S": (B,H,D,D), "shift": (B,d)}, "cmix": (B,d)}
Enc-dec adds {"memory": (B, Se, d)} and per-decoder-layer {"cross_kv"}.

The cache tree mirrors params ({"stack": ..., "tail": ...}) so the layer scan
threads it.  ``init_cache`` builds zero caches (or ShapeDtypeStructs under
``jax.eval_shape`` for the dry-run); ``cache_from_prefill`` turns a
collect_cache=True forward pass into a decode-ready cache.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan

PyTree = Any


def _layer_cache(cfg: ArchConfig, kind: str, B: int, cache_len: int, dtype):
    KV, Dh, d = cfg.n_kv_heads, cfg.d_head, cfg.d_model
    entry: dict = {}
    if kind in ("attn", "swa", "local"):
        window = (
            cfg.sliding_window
            if kind == "swa"
            else cfg.local_window if kind == "local" else 0
        )
        Sc = min(window, cache_len) if window else cache_len
        entry["attn"] = {
            "k": jnp.zeros((B, Sc, KV, Dh), dtype),
            "v": jnp.zeros((B, Sc, KV, Dh), dtype),
            "t": jnp.zeros((), jnp.int32),
        }
    elif kind == "rglru":
        W = cfg.lru_width or cfg.d_model
        entry["rglru"] = {
            "h": jnp.zeros((B, W), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_width - 1, W), jnp.float32),
        }
    elif kind == "rwkv6":
        H = cfg.rnn_heads
        entry["rwkv"] = {
            "S": jnp.zeros((B, H, Dh, Dh), jnp.float32),
            "shift": jnp.zeros((B, d), jnp.float32),
        }
        entry["cmix"] = jnp.zeros((B, d), jnp.float32)
    if cfg.enc_dec:
        entry["cross_kv"] = (
            jnp.zeros((B, cfg.enc_seq, KV, Dh), dtype),
            jnp.zeros((B, cfg.enc_seq, KV, Dh), dtype),
        )
    return entry


def init_cache(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    batch_size: int,
    cache_len: int,
    dtype=jnp.bfloat16,
) -> PyTree:
    pattern = cfg.layer_pattern
    n_full, rem = divmod(cfg.n_layers, len(pattern))

    def group(_):
        return tuple(
            _layer_cache(cfg, kind, batch_size, cache_len, dtype) for kind in pattern
        )

    groups = [group(i) for i in range(n_full)]
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *groups) if n_full else None
    tail = tuple(
        _layer_cache(cfg, pattern[i], batch_size, cache_len, dtype)
        for i in range(rem)
    )
    cache: dict = {
        "layers": {"stack": stack, "tail": tail},
        "t": jnp.zeros((), jnp.int32),
    }
    if cfg.enc_dec:
        cache["memory"] = jnp.zeros((batch_size, cfg.enc_seq, cfg.d_model), dtype)
    return cache


# ---------------------------------------------------------------------------
# Paged KV cache (continuous-batching serving; docs/ARCHITECTURE.md §Serving).
#
# One shared pool of fixed-size blocks per attention layer; a request owns a
# set of blocks through its block-table row (position p of slot b lives at
# flat pool slot ``table[b, p // bs] * bs + p % bs``).  Block 0 is the trash
# block — idle decode slots point their whole table at it, so the jitted step
# keeps static shapes with no per-request branching.  The int8 page option
# reuses ``train/compression.quantize`` on a per-(token, kv-head) grid (the
# paper's Int8 deployment precision applied to the cache).
#
# Rollback invariant (speculative decoding rides on this): pages past a
# slot's per-slot length hold arbitrary stale KV — rejected draft rows,
# leftovers from a block's previous owner — and both attention paths mask
# by the length vector, never by page contents.  Rolling a slot back past
# rejected positions is therefore just shrinking its length: the block
# table keeps the blocks, and the next ``paged_update`` at those positions
# overwrites the stale rows in place.
# ---------------------------------------------------------------------------
def _kv_vec_scale(x: jax.Array) -> jax.Array:
    """Int8 grid per (token, kv-head) vector: max |x| over d_head / 127."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return jnp.maximum(m, 1e-12) / 127.0


def _paged_layer_entry(cfg: ArchConfig, serve) -> dict:
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    N, bs = serve.n_blocks, serve.block_size
    if serve.kv_dtype == "int8":
        return {
            "paged": {
                "k": jnp.zeros((N, bs, KV, Dh), jnp.int8),
                "v": jnp.zeros((N, bs, KV, Dh), jnp.int8),
                "k_scale": jnp.zeros((N, bs, KV, 1), jnp.float32),
                "v_scale": jnp.zeros((N, bs, KV, 1), jnp.float32),
            }
        }
    dt = {"bf16": jnp.bfloat16, "fp32": jnp.float32}[serve.kv_dtype]
    return {
        "paged": {
            "k": jnp.zeros((N, bs, KV, Dh), dt),
            "v": jnp.zeros((N, bs, KV, Dh), dt),
        }
    }


def init_paged_cache(cfg: ArchConfig, plan: ExecutionPlan, serve) -> PyTree:
    """Zero block pools mirroring the layer tree ({"stack": ..., "tail": ...}).

    ``serve`` is a :class:`repro.core.plan.ServePlan`.  Only attention-kind
    layers are supported (``serve_feasible`` gates the rest)."""
    pattern = cfg.layer_pattern
    n_full, rem = divmod(cfg.n_layers, len(pattern))
    groups = [
        tuple(_paged_layer_entry(cfg, serve) for _ in pattern) for _ in range(n_full)
    ]
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *groups) if n_full else None
    tail = tuple(_paged_layer_entry(cfg, serve) for _ in range(rem))
    return {"layers": {"stack": stack, "tail": tail}}


def paged_copy_block(pools: PyTree, src, dst) -> PyTree:
    """Copy one block's pages ``src -> dst`` across every layer pool.

    The copy-on-write fork of prefix sharing (docs/ARCHITECTURE.md §"Prefix
    sharing"): when a new request diverges *inside* a resident shared block,
    the scheduler allocates it a fresh block and the engine duplicates the
    matched pages there before its next step — the resident block is never
    written by a sharer.  Copies every leaf (k/v and, for int8 pools, the
    quantization scales), so the fork is byte-identical by construction.

    ``src``/``dst`` may be traced scalars: callers jit this once and reuse
    it for every fork (block ids are data, not shapes)."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    layers = pools["layers"]
    stack = (
        jax.tree.map(lambda x: x.at[:, dst].set(x[:, src]), layers["stack"])
        if layers["stack"] is not None
        else None
    )
    tail = jax.tree.map(lambda x: x.at[dst].set(x[src]), layers["tail"])
    return {"layers": {"stack": stack, "tail": tail}}


def paged_flat_slots(
    table: jax.Array,
    positions: jax.Array,
    block_size: int,
    valid: Optional[jax.Array] = None,
):
    """Flat pool slots for ``positions`` (B, S) under block table (B, MB).

    ``valid`` (B, S) bool routes masked positions to the trash block (block
    0), which is how the unified mixed step keeps static shapes: a decode
    slot's unused slab rows and an idle slot's whole row write there.
    Positions are clamped into the table extent first (a dead row's
    position may run past ``max_seq_len``)."""
    B, MB = table.shape
    pos = jnp.clip(positions, 0, MB * block_size - 1)
    blk = table[jnp.arange(B)[:, None], pos // block_size]
    if valid is not None:
        blk = jnp.where(valid, blk, 0)
    return blk * block_size + pos % block_size


def paged_update(
    entry: dict, k: jax.Array, v: jax.Array, positions: jax.Array,
    table: jax.Array, block_size: int, valid: Optional[jax.Array] = None,
) -> dict:
    """Write new (B, S, KV, Dh) keys/values at their slots; returns the entry.

    Slot collisions only happen on the trash block (idle slots and, with
    ``valid`` given, the dead rows of a mixed slab), where any winner is
    fine — live requests own disjoint blocks by construction."""
    from repro.train.compression import quantize

    B, S = k.shape[:2]
    flat = paged_flat_slots(table, positions, block_size, valid).reshape(-1)

    def put(pool, val):
        fp = pool.reshape((-1,) + pool.shape[2:])
        fp = fp.at[flat].set(val.reshape((B * S,) + val.shape[2:]).astype(fp.dtype))
        return fp.reshape(pool.shape)

    out = dict(entry)
    if "k_scale" in entry:
        qk, sk = quantize(k.astype(jnp.float32), "int8", _kv_vec_scale(k))
        qv, sv = quantize(v.astype(jnp.float32), "int8", _kv_vec_scale(v))
        out["k"] = put(entry["k"], qk)
        out["v"] = put(entry["v"], qv)
        out["k_scale"] = put(entry["k_scale"], sk)
        out["v_scale"] = put(entry["v_scale"], sv)
    else:
        out["k"] = put(entry["k"], k)
        out["v"] = put(entry["v"], v)
    return out


def paged_gather(
    entry: dict,
    table: jax.Array,
    block_size: int,
    max_blocks: Optional[int] = None,
):
    """Materialize each slot's pages in position order: (B, L*bs, KV, Dh).

    Key j of the gathered view sits at sequence position j, so the attention
    mask is just ``j <= q_position`` — the block indirection vanishes here.
    This is the fallback/oracle path; the production serve step runs
    ``kernels/paged_attention`` which consumes the table directly and never
    materializes this buffer.

    ``L`` is clamped to the live blocks' high-water mark instead of always
    the full table width: block tables are prefix-dense (a slot's blocks
    occupy its leading columns), so every live position sits below the last
    non-trash column and the tail of the table gathers nothing but trash.
    The clamp is automatic when ``table`` is concrete (eager tests, the
    interpreter path); under a jit trace the width is static, so callers
    pass ``max_blocks`` themselves or get the full extent."""
    from repro.train.compression import dequantize

    MB = table.shape[1]
    if max_blocks is None and not isinstance(table, jax.core.Tracer):
        live = np.nonzero(np.asarray(table).any(axis=0))[0]
        max_blocks = int(live[-1]) + 1 if live.size else 1
    L = min(MB, max_blocks) if max_blocks else MB
    pos = jnp.arange(L * block_size)
    blk = table[:, pos // block_size]
    flat = blk * block_size + pos % block_size  # (B, MB*bs)

    def take(pool):
        return pool.reshape((-1,) + pool.shape[2:])[flat]

    k, v = take(entry["k"]), take(entry["v"])
    if "k_scale" in entry:
        k = dequantize(k, take(entry["k_scale"]), "int8")
        v = dequantize(v, take(entry["v_scale"]), "int8")
    return k, v


def _kv_to_ring(k: jax.Array, v: jax.Array, Sc: int, dtype):
    """Place a prefill's (B,S,KV,D) kv into an Sc-slot cache at slot = pos % Sc."""
    B, S, KV, Dh = k.shape
    if S <= Sc:
        pad = [(0, 0), (0, Sc - S), (0, 0), (0, 0)]
        return jnp.pad(k, pad).astype(dtype), jnp.pad(v, pad).astype(dtype)
    keep = jnp.arange(S - Sc, S)
    slots = keep % Sc
    kk = jnp.zeros((B, Sc, KV, Dh), dtype).at[:, slots].set(
        k[:, keep].astype(dtype)
    )
    vv = jnp.zeros((B, Sc, KV, Dh), dtype).at[:, slots].set(
        v[:, keep].astype(dtype)
    )
    return kk, vv


def cache_from_prefill(
    cfg: ArchConfig,
    plan: ExecutionPlan,
    prefill_cache: PyTree,
    cache_len: int,
    dtype=jnp.bfloat16,
) -> PyTree:
    """Convert the collect_cache=True output of ``forward`` into a decode cache.

    Call outside jit (the prefill length is read as a python int)."""
    S = int(prefill_cache["t"])
    pattern = cfg.layer_pattern
    layers = prefill_cache["layers"]

    def convert_entry(entry, kind):
        e = dict(entry)
        if "kv_out" in e:
            k, v = e.pop("kv_out")
            window = (
                cfg.sliding_window
                if kind == "swa"
                else cfg.local_window if kind == "local" else 0
            )
            Sc = min(window, cache_len) if window else cache_len
            kk, vv = _kv_to_ring(k, v, Sc, dtype)
            e["attn"] = {"k": kk, "v": vv, "t": jnp.asarray(S, jnp.int32)}
        return e

    new_stack = None
    if layers["stack"] is not None:
        new_stack = _convert_stacked(layers["stack"], pattern, cfg, cache_len, S, dtype)
    new_tail = tuple(
        convert_entry(layers["tail"][i], pattern[i % len(pattern)])
        for i in range(len(layers["tail"]))
    )
    cache = {
        "layers": {"stack": new_stack, "tail": new_tail},
        "t": jnp.asarray(S, jnp.int32),
    }
    if "memory" in prefill_cache:
        cache["memory"] = prefill_cache["memory"]
    return cache


def _convert_stacked(stack, pattern, cfg, cache_len, S, dtype):
    out = []
    for i, kind in enumerate(pattern):
        entry = dict(stack[i])
        if "kv_out" in entry:
            k, v = entry.pop("kv_out")  # (n_groups, B, S, KV, Dh)
            window = (
                cfg.sliding_window
                if kind == "swa"
                else cfg.local_window if kind == "local" else 0
            )
            Sc = min(window, cache_len) if window else cache_len
            kk, vv = jax.vmap(lambda a, b: _kv_to_ring(a, b, Sc, dtype))(k, v)
            n = k.shape[0]
            entry["attn"] = {
                "k": kk,
                "v": vv,
                "t": jnp.full((n,), S, jnp.int32),
            }
        out.append(entry)
    return tuple(out)
