"""Parameter initialization.

The param tree mirrors the EDPU structure: ``blocks.stack`` holds n_full
pattern-groups stacked on a leading axis (scanned), ``blocks.tail`` the
remainder layers.  Whether QKV is one fused matrix (C5 Independent-Linear)
is a *plan* decision, so init takes the plan.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan

PyTree = Any


def _norm_params(cfg: ArchConfig, d: int) -> dict:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


class _Init:
    """Deterministic per-leaf initializer (fold_in counter keys)."""

    def __init__(self, key: jax.Array, dtype):
        self.key = key
        self.count = 0
        self.dtype = dtype

    def normal(self, shape, scale=0.02):
        self.count += 1
        k = jax.random.fold_in(self.key, self.count)
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(self.dtype)

    def zeros(self, shape):
        return jnp.zeros(shape, self.dtype)


def _attn_params(init: _Init, cfg: ArchConfig, plan: ExecutionPlan, cross: bool = False) -> dict:
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p: dict = {"ln": _norm_params(cfg, d)}
    if plan.fuse_qkv and not cross:
        p["wqkv"] = init.normal((d, (H + 2 * KV) * Dh))
    else:
        p["wq"] = init.normal((d, H * Dh))
        p["wk"] = init.normal((d, KV * Dh))
        p["wv"] = init.normal((d, KV * Dh))
    p["wo"] = init.normal((H * Dh, d), scale=0.02 / max(1, cfg.n_layers) ** 0.5)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((Dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((Dh,), jnp.float32)
    return p


def _ffn_params(init: _Init, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    p: dict = {"ln": _norm_params(cfg, d)}
    if cfg.is_moe:
        E, F = cfg.n_experts, cfg.moe_d_ff
        p["router"] = init.normal((d, E))
        p["w1"] = init.normal((E, d, F))
        if cfg.activation in ("swiglu", "geglu"):
            p["w3"] = init.normal((E, d, F))
        p["w2"] = init.normal((E, F, d))
    elif cfg.activation == "rwkv":
        F = cfg.d_ff
        p["mix_k"] = init.zeros((d,))
        p["mix_r"] = init.zeros((d,))
        p["w1"] = init.normal((d, F))
        p["w_r"] = init.normal((d, d))
        p["w2"] = init.normal((F, d))
    else:
        F = cfg.d_ff
        p["w1"] = init.normal((d, F))
        if cfg.activation in ("swiglu", "geglu"):
            p["w3"] = init.normal((d, F))
        p["w2"] = init.normal((F, d))
    return p


def _rglru_params(init: _Init, cfg: ArchConfig) -> dict:
    d, W, Hn = cfg.d_model, cfg.lru_width or cfg.d_model, max(cfg.rnn_heads, 1)
    bh = W // Hn
    return {
        "ln": _norm_params(cfg, d),
        "w_x": init.normal((d, W)),
        "w_g": init.normal((d, W)),
        "conv_w": init.normal((cfg.conv_width, W), scale=0.1),
        "w_gate_a": init.normal((Hn, bh, bh)),
        "b_gate_a": init.zeros((W,)),
        "w_gate_x": init.normal((Hn, bh, bh)),
        "b_gate_x": init.zeros((W,)),
        # softplus(lam) ~ U[...] so a = exp(-8 softplus(lam)) spans (0.7, 0.999)
        "lam": jnp.linspace(-2.0, 1.0, W, dtype=jnp.float32),
        "w_out": init.normal((W, d)),
    }


def _rwkv6_params(init: _Init, cfg: ArchConfig) -> dict:
    d, H, Dh = cfg.d_model, cfg.rnn_heads, cfg.d_head
    hd = H * Dh
    lora = max(32, d // 32)
    p = {
        "ln": _norm_params(cfg, d),
        "w_r": init.normal((d, hd)),
        "w_k": init.normal((d, hd)),
        "w_v": init.normal((d, hd)),
        "w_g": init.normal((d, hd)),
        "w_o": init.normal((hd, d), scale=0.02 / max(1, cfg.n_layers) ** 0.5),
        "lora_a": init.normal((d, lora)).astype(jnp.float32),
        "lora_b": init.normal((lora, hd)).astype(jnp.float32),
        "w0": jnp.full((hd,), -0.6, jnp.float32),  # decay ~ exp(-exp(-0.6)) ~ .58
        "u": init.normal((H, Dh)).astype(jnp.float32),
        "gn_scale": jnp.ones((hd,), jnp.float32),
        "gn_bias": jnp.zeros((hd,), jnp.float32),
    }
    for name in ("mix_r", "mix_k", "mix_v", "mix_g", "mix_w"):
        p[name] = init.zeros((d,))
    return p


def layer_params(init: _Init, cfg: ArchConfig, plan: ExecutionPlan, kind: str,
                 with_cross: bool = False) -> dict:
    if kind in ("attn", "swa", "local"):
        core = {"attn": _attn_params(init, cfg, plan)}
    elif kind == "rglru":
        core = {"attn": _rglru_params(init, cfg)}
    elif kind == "rwkv6":
        core = {"attn": _rwkv6_params(init, cfg)}
    else:
        raise ValueError(kind)
    if with_cross:
        core["cross"] = _attn_params(init, cfg, plan, cross=True)
    core["ffn"] = _ffn_params(init, cfg)
    return core


def init_params(
    key: jax.Array,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    dtype=jnp.bfloat16,
) -> PyTree:
    init = _Init(key, dtype)
    pattern = cfg.layer_pattern
    n_full, rem = divmod(cfg.n_layers, len(pattern))
    with_cross = cfg.enc_dec

    def one_group(_):
        return tuple(
            layer_params(init, cfg, plan, kind, with_cross) for kind in pattern
        )

    groups = [one_group(i) for i in range(n_full)]
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *groups) if n_full else None
    tail = tuple(
        layer_params(init, cfg, plan, pattern[i], with_cross) for i in range(rem)
    )

    params: dict = {"blocks": {"stack": stack, "tail": tail}}
    if cfg.vocab_size > 1:
        params["embed"] = init.normal((cfg.vocab_size, cfg.d_model))
    if cfg.pos_embedding == "learned":
        params["pos"] = init.normal((cfg.max_seq_len, cfg.d_model))
    params["final_norm"] = _norm_params(cfg, cfg.d_model)
    if not cfg.tie_embeddings and cfg.vocab_size > 1:
        params["lm_head"] = init.normal((cfg.d_model, cfg.vocab_size))
    if cfg.n_classes:
        params["cls_head"] = init.normal((cfg.d_model, cfg.n_classes))

    if cfg.enc_dec:
        enc_groups = [
            (layer_params(init, cfg, plan, "attn"),) for _ in range(cfg.n_enc_layers)
        ]
        params["encoder"] = {
            "stack": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_groups),
            "tail": (),
            "final_norm": _norm_params(cfg, cfg.d_model),
        }
    return params


def param_count_tree(params: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
