"""Model building blocks, pure JAX.

The attention path is the jnp analogue of the paper's ATB: blocked
online-softmax (FlashAttention-style) so scores never materialize in HBM —
the paper's "nonlinear operators inserted into the MM dataflow" (C6) at the
reference level.  The Pallas kernel in ``repro.kernels.flash_attention``
implements the same block schedule for real TPUs; this file is its oracle
and the path the multi-pod dry-run lowers.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(params: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, d, 2, dtype=jnp.float32) / d
    )  # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (jnp "flash"): the ATB reference path.
# ---------------------------------------------------------------------------
def _chunk_scores(qi, kj, softmax_scale):
    # qi: (B, qc, KH, G, D); kj: (B, kc, KH, D) -> (B, KH, G, qc, kc)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32), kj.astype(jnp.float32))
    return s * softmax_scale


def _chunk_mask(q_off, k_off, qc, kc, causal: bool, window: int, prefix_len: int = 0):
    iq = q_off + jnp.arange(qc)[:, None]
    ik = k_off + jnp.arange(kc)[None, :]
    m = jnp.ones((qc, kc), dtype=bool)
    if causal:
        c = iq >= ik
        if prefix_len > 0:  # prefix-LM (PaliGemma): prefix attends bidirectionally
            c |= ik < prefix_len
        m &= c
    if window > 0:
        m &= (iq - ik) < window
    return m


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    q_chunk: int = 512,
    k_chunk: int = 512,
    softmax_scale: Optional[float] = None,
    prefix_len: int = 0,
) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D); GQA via H = KH * G.

    Online softmax over k-chunks inside a scan over q-chunks: peak temp is
    O(qc * kc) per head instead of O(Sq * Sk).
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    assert H % KH == 0, (H, KH)
    G = H // KH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    # Pick chunk sizes that divide (shapes in this repo are powers of two or
    # get padded by the caller).
    while Sq % qc:
        qc //= 2
    while Sk % kc:
        kc //= 2
    nq, nk = Sq // qc, Sk // kc

    qr = jnp.moveaxis(q.reshape(B, nq, qc, KH, G, D), 1, 0)  # (nq, B, ...)
    kr = jnp.moveaxis(k.reshape(B, nk, kc, KH, D), 1, 0)  # (nk, B, ...)
    vr = jnp.moveaxis(v.reshape(B, nk, kc, KH, D), 1, 0)

    def q_step(_, q_in):
        qi, q_idx = q_in
        q_off = q_idx * qc

        def k_step(carry, k_in):
            m_i, l_i, o_i = carry
            kj, vj, k_idx = k_in
            k_off = k_idx * kc
            s = _chunk_scores(qi, kj, scale)  # (B, KH, G, qc, kc)
            mask = _chunk_mask(q_off, k_off, qc, kc, causal, window, prefix_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_i, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_i - m_new)
            l_new = l_i * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
            o_new = o_i * corr[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, KH, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, qc), jnp.float32)
        o0 = jnp.zeros((B, KH, G, qc, D), jnp.float32)
        (m, l, o), _ = lax.scan(
            k_step, (m0, l0, o0), (kr, vr, jnp.arange(nk))
        )
        o = o / jnp.maximum(l[..., None], 1e-30)
        # (B, KH, G, qc, D) -> (B, qc, KH, G, D)
        return None, jnp.transpose(o, (0, 3, 1, 2, 4))

    _, out = lax.scan(q_step, None, (qr, jnp.arange(nq)))
    # out: (nq, B, qc, KH, G, D)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array,
    *,
    window: int = 0,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """One-token attention against a cache.

    q: (B, 1, H, D); caches: (B, S, KH, D); cur_len: () current filled length
    (the new token sits at position cur_len - 1).
    """
    B, _, H, D = q.shape
    _, S, KH, _ = k_cache.shape
    G = H // KH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    qr = q.reshape(B, KH, G, D)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qr.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    idx = jnp.arange(S)
    valid = idx < cur_len
    if window > 0:
        valid &= idx >= (cur_len - window)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    # Cross-shard-safe softmax: max/sum reduce over the (possibly sharded)
    # cache axis; GSPMD inserts the small all-reduces (flash-decoding split-K).
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhgk,bkhd->bhgd", p / l, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


def paged_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_positions: jax.Array,
    *,
    window: int = 0,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """Attention against gathered KV pages with per-slot positions.

    q: (B, S, H, D) — S is 1 for a decode row, up to the mixed-slab width
    for a prefill chunk.  k/v: (B, Skv, KH, D) page gather where key j sits
    at sequence position j (``models/cache.paged_gather`` guarantees this).
    q_positions: (B, S) absolute positions, so every slot in a continuous
    batch masks by its own length — the mask is ``j <= pos`` (+ window),
    never a shared scalar.

    This is the gather *fallback* of the unified serve step (model-sharded
    meshes, where GSPMD cannot partition the Pallas call) and, composed
    with ``paged_gather``, the oracle the fused block-table kernel
    (``repro.kernels.paged_attention``) is tested against — the production
    path never materializes the (B, Skv, ...) gather this function reads.
    """
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    qr = q.reshape(B, S, KH, G, D)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qr.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale  # (B, KH, G, S, Skv)
    j = jnp.arange(k.shape[1])
    valid = j[None, None, :] <= q_positions[:, :, None]  # (B, S, Skv)
    if window > 0:
        valid &= (q_positions[:, :, None] - j[None, None, :]) < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum(
        "bhgqk,bkhd->bhgqd", p / p.sum(axis=-1, keepdims=True),
        v.astype(jnp.float32),
    )
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, S, H, D).astype(q.dtype)


def plain_cross_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_chunk: int = 512,
) -> jax.Array:
    """Bidirectional cross-attention (decoder -> short encoder memory)."""
    return blocked_attention(
        q, k, v, causal=False, window=0, q_chunk=q_chunk, k_chunk=k.shape[1]
    )


# ---------------------------------------------------------------------------
# Megatron-SP primitives (manual mode: call INSIDE an enclosing shard_map).
#
# The sequence-parallel residual stream lives seq-sharded over the `model`
# axis; the pair below is the per-stage collective envelope (one gather on
# the way up, one reduce-scatter on the way down) with the gather executed
# as the ring-overlap schedule from dist.collectives, so the HLO of the SP
# layer stack contains collective-permutes but no all-gather.
# Paper-to-code map: docs/ARCHITECTURE.md §"Megatron-SP".
# ---------------------------------------------------------------------------
def sp_gather_matmul(
    x_local: jax.Array, w_shard: jax.Array, axis: str, n_shards: int
) -> jax.Array:
    """Seq-sharded ``x_local`` (B, S/n, D) times column shard ``w_shard``
    (D, N/n) -> full-sequence (B, S, N/n), gathering S over the ring."""
    from repro.dist.collectives import ring_gather_matmul

    return ring_gather_matmul(x_local, w_shard, axis, n_shards, gather_dim=1)


def sp_scatter_matmul(x_full: jax.Array, w_shard: jax.Array, axis: str) -> jax.Array:
    """Row-parallel tail: full-sequence partials ``x_full`` (B, S, K/n) times
    ``w_shard`` (K/n, D), summed over ``axis`` and handed back to each device
    as its sequence chunk (B, S/n, D) in one reduce-scatter."""
    from repro.dist.collectives import seq_scatter

    return seq_scatter(x_full @ w_shard, axis, scatter_dim=1)


def sp_mlp(
    params: dict, x_local: jax.Array, activation: str, axis: str, n_shards: int
) -> jax.Array:
    """The FFN stage under Megatron-SP: one ring gather feeds the (fused
    w1|w3) column shards, one reduce-scatter returns the row-parallel w2
    product to the seq-sharded residual.  Numerically identical to ``mlp``
    up to the fp32 reduction order."""
    if activation in ("swiglu", "geglu"):
        w13 = jnp.concatenate([params["w1"], params["w3"]], axis=-1)
        hg = sp_gather_matmul(x_local, w13, axis, n_shards)
        h, g = jnp.split(hg, [params["w1"].shape[-1]], axis=-1)
        act = jax.nn.silu if activation == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True)
        )
        h = act(h) * g
    elif activation == "gelu":
        h = jax.nn.gelu(
            sp_gather_matmul(x_local, params["w1"], axis, n_shards),
            approximate=True,
        )
    else:
        raise ValueError(f"sp_mlp does not handle activation={activation!r}")
    return sp_scatter_matmul(h, params["w2"], axis)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp(params: dict, x: jax.Array, activation: str) -> jax.Array:
    if activation in ("swiglu", "geglu"):
        h = x @ params["w1"]
        g = x @ params["w3"]
        act = jax.nn.silu if activation == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True)
        )
        h = act(h) * g
    elif activation == "gelu":
        h = jax.nn.gelu(x @ params["w1"], approximate=True)
    else:
        raise ValueError(f"mlp does not handle activation={activation!r}")
    return h @ params["w2"]
