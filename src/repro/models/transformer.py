"""EDPU — the Encoder/Decoder Processing Unit (paper §III.B) in JAX.

One ``edpu_layer`` call = one Transformer layer = MHA Stage then FFN Stage,
serially, sharing the same chips (the paper's two-stage resource-sharing
design).  Layers are stacked as scanned pattern-groups so heterogeneous
patterns (e.g. RecurrentGemma's rglru/rglru/local) stay scannable.

Everything is a pure function of (params, batch) with the ExecutionPlan as
static configuration — the plan is where the CAT customization (fused QKV,
chunk sizes, remat, MoE dispatch) enters.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as G
from repro.models import rwkv6 as R

PyTree = Any
Identity = lambda x, name=None: x


# ---------------------------------------------------------------------------
# Attention stage (the ATB + LBs)
# ---------------------------------------------------------------------------
def _project_qkv(ap: dict, h: jax.Array, cfg: ArchConfig, plan: ExecutionPlan):
    B, S, _ = h.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if plan.fuse_qkv and "wqkv" in ap:
        qkv = h @ ap["wqkv"]  # C5: one large MM instead of 3 narrow ones
        q, k, v = jnp.split(qkv, [H * Dh, (H + KV) * Dh], axis=-1)
    else:
        q, k, v = h @ ap["wq"], h @ ap["wk"], h @ ap["wv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KV, Dh)
    v = v.reshape(B, S, KV, Dh)
    if cfg.qk_norm:
        q = L.rmsnorm(q, ap["q_norm"])
        k = L.rmsnorm(k, ap["k_norm"])
    return q, k, v


def attention_stage(
    ap: dict,
    h: jax.Array,
    *,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    kind: str,
    positions: jax.Array,
    cache: Optional[dict],
    prefix_len: int,
    shard: Callable = Identity,
):
    B, S, _ = h.shape
    H, Dh = cfg.n_heads, cfg.d_head
    window = (
        cfg.sliding_window
        if kind == "swa"
        else cfg.local_window if kind == "local" else 0
    )
    q, k, v = _project_qkv(ap, h, cfg, plan)
    if cfg.pos_embedding == "rope":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    q, k, v = shard(q, "act_heads"), shard(k, "act_kv"), shard(v, "act_kv")

    new_cache = None
    if cache is None:
        o = L.blocked_attention(
            q, k, v,
            causal=cfg.causal,
            window=window,
            q_chunk=plan.mha.pu.block_m,
            k_chunk=plan.mha.pu.block_n,
            prefix_len=prefix_len,
        )
        kv_out = (k, v)
    else:
        Sc = cache["k"].shape[1]
        t = cache["t"]  # filled length before this token
        idx = t % Sc if window else jnp.minimum(t, Sc - 1)
        k_cache = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        v_cache = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        eff_len = jnp.minimum(t + 1, Sc)
        o = L.decode_attention(q, k_cache, v_cache, eff_len, window=0)
        new_cache = {"k": k_cache, "v": v_cache, "t": t + 1}
        kv_out = None
    out = shard(o.reshape(B, S, H * Dh), "act_heads_flat") @ ap["wo"]
    return out, new_cache, kv_out


def cross_attention_stage(cp: dict, h: jax.Array, memory_kv, cfg: ArchConfig):
    """Decoder -> encoder-memory attention (whisper). memory_kv: (k, v)."""
    B, S, _ = h.shape
    q = (h @ cp["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    mk, mv = memory_kv
    o = L.plain_cross_attention(q, mk, mv)
    return o.reshape(B, S, cfg.n_heads * cfg.d_head) @ cp["wo"]


def cross_kv(cp: dict, memory: jax.Array, cfg: ArchConfig):
    B, Se, _ = memory.shape
    mk = (memory @ cp["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.d_head)
    mv = (memory @ cp["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.d_head)
    return mk, mv


# ---------------------------------------------------------------------------
# The EDPU layer
# ---------------------------------------------------------------------------
def edpu_layer(
    lp: dict,
    x: jax.Array,
    *,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    kind: str,
    positions: jax.Array,
    cache: Optional[dict] = None,
    memory: Optional[jax.Array] = None,
    prefix_len: int = 0,
    causal_override: Optional[bool] = None,
    collect: bool = False,
    shard: Callable = Identity,
):
    """One Encoder/Decoder layer: MHA Stage -> (cross) -> FFN Stage.

    ``collect=True`` (prefill) harvests decode-cache state from the parallel
    pass; the train path keeps it False so no KV leaves the layer scan.
    Returns (x, new_cache, aux_loss)."""
    run_cfg = cfg
    if causal_override is not None:
        import dataclasses

        run_cfg = dataclasses.replace(cfg, causal=causal_override)
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    # ---- MHA Stage ---------------------------------------------------------
    h = L.apply_norm(lp["attn"]["ln"], x, cfg.norm)
    if kind in ("attn", "swa", "local"):
        a, nc, kv_out = attention_stage(
            lp["attn"], h,
            cfg=run_cfg, plan=plan, kind=kind, positions=positions,
            cache=None if cache is None else cache.get("attn"),
            prefix_len=prefix_len, shard=shard,
        )
        if nc is not None:
            new_cache["attn"] = nc
        if cache is None and collect and kv_out is not None:
            new_cache["kv_out"] = kv_out  # harvested by prefill
    elif kind == "rglru":
        a, nc = G.rglru_block(
            lp["attn"], h,
            n_heads=max(cfg.rnn_heads, 1),
            cache=None if cache is None else cache.get("rglru"),
            collect=collect,
        )
        if nc is not None:
            new_cache["rglru"] = nc
    elif kind == "rwkv6":
        a, nc = R.rwkv6_time_mix(
            lp["attn"], h,
            n_heads=cfg.rnn_heads, d_head=cfg.d_head,
            cache=None if cache is None else cache.get("rwkv"),
            collect=collect,
        )
        if nc is not None:
            new_cache["rwkv"] = nc
    else:
        raise ValueError(kind)
    x = shard(x + a, "act_hidden")

    # ---- Cross-attention sub-stage (enc-dec decoder only) -------------------
    if "cross" in lp:
        hc = L.apply_norm(lp["cross"]["ln"], x, cfg.norm)
        if cache is not None and "cross_kv" in cache:
            mkv = cache["cross_kv"]
        else:
            mkv = cross_kv(lp["cross"], memory, cfg)
        x = x + cross_attention_stage(lp["cross"], hc, mkv, cfg)
        if cache is not None or collect:
            new_cache["cross_kv"] = mkv

    # ---- FFN Stage ----------------------------------------------------------
    h2 = L.apply_norm(lp["ffn"]["ln"], x, cfg.norm)
    if cfg.is_moe:
        st = M.MoESettings(
            n_experts=cfg.n_experts,
            top_k=cfg.top_k,
            capacity_factor=cfg.moe_capacity_factor,
            dispatch=plan.moe_dispatch,
        )
        f, aux = M.moe_ffn(lp["ffn"], h2, st, cfg.activation)
    elif kind == "rwkv6":
        f, nc = R.rwkv6_channel_mix(
            lp["ffn"], h2,
            cache=None if cache is None else cache.get("cmix"),
            collect=collect,
        )
        if nc is not None:
            new_cache["cmix"] = nc
    else:
        f = L.mlp(lp["ffn"], h2, cfg.activation)
    x = shard(x + f, "act_hidden")
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------
def _run_stack(
    blocks: dict,
    x: jax.Array,
    layer_fn: Callable,
    pattern: tuple[str, ...],
    caches: Optional[dict] = None,
    remat: bool = False,
):
    """Scan the stacked pattern-groups, then the tail layers.

    layer_fn(lp, x, kind, cache) -> (x, new_cache, aux).
    caches mirrors blocks: {"stack": ..., "tail": ...} or None.
    Returns (x, new_caches, total_aux)."""

    def group_body(x, inp):
        gp, gcache = inp
        no_cache = gcache is None or hasattr(gcache, "ndim")  # scan dummy
        new_caches = []
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            c = None if no_cache else gcache[i]
            x, nc, a = layer_fn(gp[i], x, kind, c)
            new_caches.append(nc)
            aux += a
        return x, (tuple(new_caches), aux)

    body = jax.checkpoint(group_body) if remat else group_body
    new_stack = None
    total_aux = jnp.zeros((), jnp.float32)
    if blocks["stack"] is not None:
        stack_caches = None if caches is None else caches["stack"]
        if stack_caches is None:
            n = jax.tree.leaves(blocks["stack"])[0].shape[0]
            stack_caches = None
            xs = (blocks["stack"], _nones_like_scan(blocks["stack"]))
        else:
            xs = (blocks["stack"], stack_caches)
        x, (new_stack, auxes) = lax.scan(body, x, xs)
        total_aux += auxes.sum()
    new_tail = []
    for i, lp in enumerate(blocks["tail"]):
        kind = pattern[i % len(pattern)]
        c = None if caches is None else caches["tail"][i]
        x, nc, a = layer_fn(lp, x, kind, c)
        new_tail.append(nc)
        total_aux += a
    return x, {"stack": new_stack, "tail": tuple(new_tail)}, total_aux


def _nones_like_scan(tree):
    """A scan-compatible 'no cache' placeholder: broadcast None via a dummy."""
    n = jax.tree.leaves(tree)[0].shape[0]
    return jnp.zeros((n, 0))  # zero-width array; treated as falsy cache


def _weight_dtype(params: PyTree):
    """Compute dtype = dtype of the (>=2-D) weight leaves (norms stay fp32)."""
    for leaf in jax.tree.leaves(params):
        if getattr(leaf, "ndim", 0) >= 2:
            return leaf.dtype
    return jnp.bfloat16


def forward(
    params: PyTree,
    batch: dict,
    *,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    cache: Optional[PyTree] = None,
    collect_cache: bool = False,
    shard: Callable = Identity,
):
    """Full model forward.

    batch keys (by arch): "tokens" (B,S) int32; optional "prefix_embeds"
    (B,P,d); enc-dec: "enc_embeds" (B,Se,d).  With ``cache`` set, runs one
    decode step (S == 1).  Returns (hidden (B,S,d), new_cache, aux).
    """
    dtype = _weight_dtype(params)
    x_parts = []
    prefix_len = 0
    if "prefix_embeds" in batch:
        x_parts.append(batch["prefix_embeds"].astype(dtype))
        prefix_len = batch["prefix_embeds"].shape[1]
    if "tokens" in batch and "embed" in params:
        emb = params["embed"].astype(dtype)[batch["tokens"]]
        if cfg.activation == "geglu":  # gemma family scales embeddings
            emb = emb * jnp.asarray(cfg.d_model**0.5, dtype)
        x_parts.append(emb)
    x = x_parts[0] if len(x_parts) == 1 else jnp.concatenate(x_parts, axis=1)
    B, S, _ = x.shape

    t0 = 0 if cache is None else cache["t"]
    positions = t0 + jnp.arange(S)[None, :]
    if cfg.pos_embedding == "learned":
        x = x + params["pos"].astype(dtype)[None, :S] if cache is None else (
            x + lax.dynamic_slice_in_dim(params["pos"].astype(dtype), t0, 1)[None]
        )
    elif cfg.pos_embedding == "sinusoidal":
        pos = L.sinusoidal_positions(S, cfg.d_model).astype(dtype)
        if cache is None:
            x = x + pos[None]
        else:
            x = x + lax.dynamic_slice_in_dim(
                L.sinusoidal_positions(cfg.max_seq_len, cfg.d_model).astype(dtype),
                t0, 1)[None]
    x = shard(x, "act_hidden")

    # ---- encoder (enc-dec archs) -------------------------------------------
    memory = None
    if cfg.enc_dec:
        if cache is not None and "memory" in cache:
            memory = cache["memory"]
        else:
            enc = batch["enc_embeds"].astype(dtype)
            enc = enc + L.sinusoidal_positions(enc.shape[1], cfg.d_model).astype(dtype)[None]
            enc_positions = jnp.arange(enc.shape[1])[None, :]

            def enc_layer_fn(lp, xx, kind, c):
                return edpu_layer(
                    lp, xx, cfg=cfg, plan=plan, kind=kind,
                    positions=enc_positions, cache=None, prefix_len=0,
                    causal_override=False, shard=shard,
                )

            enc, _, _ = _run_stack(
                params["encoder"], enc, enc_layer_fn, ("attn",), None, plan.remat
            )
            memory = L.apply_norm(params["encoder"]["final_norm"], enc, cfg.norm)

    # ---- decoder / main stack ------------------------------------------------
    def layer_fn(lp, xx, kind, c):
        c = None if (c is None or (hasattr(c, "ndim"))) else c  # scan dummy
        return edpu_layer(
            lp, xx, cfg=cfg, plan=plan, kind=kind, positions=positions,
            cache=c, memory=memory, prefix_len=prefix_len,
            causal_override=False if cfg.encoder_only else None,
            collect=collect_cache, shard=shard,
        )

    layer_caches = None if cache is None else cache["layers"]
    x, new_layer_caches, aux = _run_stack(
        params["blocks"], x, layer_fn, cfg.layer_pattern, layer_caches, plan.remat
    )
    x = L.apply_norm(params["final_norm"], x, cfg.norm)

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["layers"] = new_layer_caches
        new_cache["t"] = cache["t"] + S
    elif collect_cache:
        new_cache = {"layers": new_layer_caches, "t": S}
        if memory is not None:
            new_cache["memory"] = memory
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Heads + losses
# ---------------------------------------------------------------------------
def logits_fn(params: PyTree, x: jax.Array, cfg: ArchConfig):
    if cfg.n_classes:
        return x.mean(axis=1) @ params["cls_head"]
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    return x @ w


def chunked_softmax_xent(
    x: jax.Array,
    w: jax.Array,
    targets: jax.Array,
    loss_mask: Optional[jax.Array] = None,
    chunk: int = 512,
):
    """Cross-entropy without materializing full (B,S,V) logits.

    x: (B,S,d); w: (d,V); targets: (B,S) int32. Returns (sum_loss, n_tokens)."""
    B, S, d = x.shape
    c = min(chunk, S)
    while S % c:
        c //= 2
    N = S // c
    xr = x.reshape(B, N, c, d).swapaxes(0, 1)
    tr = targets.reshape(B, N, c).swapaxes(0, 1)
    if loss_mask is None:
        mr = jnp.ones((N, B, c), jnp.float32)
    else:
        mr = loss_mask.reshape(B, N, c).swapaxes(0, 1).astype(jnp.float32)

    # checkpoint: without it the scan saves every chunk's (B, c, V) logits
    # for the backward pass — 40 GB/chip at a 152k vocab.  Recompute instead.
    @jax.checkpoint
    def step(acc, inp):
        xc, tc, mc = inp
        logits = (xc @ w).astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        loss = (lse - tl) * mc
        return (acc[0] + loss.sum(), acc[1] + mc.sum()), None

    (total, n), _ = lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (xr, tr, mr))
    return total, jnp.maximum(n, 1.0)


def lm_loss(params: PyTree, batch: dict, *, cfg: ArchConfig, plan: ExecutionPlan,
            shard: Callable = Identity):
    x, _, aux = forward(params, batch, cfg=cfg, plan=plan, shard=shard)
    if cfg.n_classes:  # classifier head (ViT)
        logits = logits_fn(params, x, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, batch["label"][:, None], axis=-1)[:, 0]
        return (lse - tl).mean() + 0.01 * aux
    w = params.get("lm_head", None)
    if w is None:
        w = params["embed"].T.astype(x.dtype)
    targets = batch["targets"]
    prefix = batch.get("prefix_embeds")
    if prefix is not None:
        # loss only over the text positions (prefix carries no targets)
        P = prefix.shape[1]
        x = x[:, P:]
    total, n = chunked_softmax_xent(x, w, targets, batch.get("loss_mask"))
    return total / n + 0.01 * aux
