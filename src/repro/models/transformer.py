"""EDPU — the Encoder/Decoder Processing Unit (paper §III.B) in JAX.

One ``edpu_layer`` call = one Transformer layer = MHA Stage then FFN Stage,
serially, sharing the same chips (the paper's two-stage resource-sharing
design).  Layers are stacked as scanned pattern-groups so heterogeneous
patterns (e.g. RecurrentGemma's rglru/rglru/local) stay scannable.

Everything is a pure function of (params, batch) with the ExecutionPlan as
static configuration — the plan is where the CAT customization (fused QKV,
chunk sizes, remat, MoE dispatch) enters.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.configs.base import ArchConfig
from repro.core.plan import ExecutionPlan
from repro.models import cache as C
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as G
from repro.models import rwkv6 as R

PyTree = Any
Identity = lambda x, name=None: x


# ---------------------------------------------------------------------------
# Attention stage (the ATB + LBs)
# ---------------------------------------------------------------------------
def _project_qkv(ap: dict, h: jax.Array, cfg: ArchConfig, plan: ExecutionPlan):
    B, S, _ = h.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if plan.fuse_qkv and "wqkv" in ap:
        qkv = h @ ap["wqkv"]  # C5: one large MM instead of 3 narrow ones
        q, k, v = jnp.split(qkv, [H * Dh, (H + KV) * Dh], axis=-1)
    else:
        q, k, v = h @ ap["wq"], h @ ap["wk"], h @ ap["wv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KV, Dh)
    v = v.reshape(B, S, KV, Dh)
    if cfg.qk_norm:
        q = L.rmsnorm(q, ap["q_norm"])
        k = L.rmsnorm(k, ap["k_norm"])
    return q, k, v


def attention_stage(
    ap: dict,
    h: jax.Array,
    *,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    kind: str,
    positions: jax.Array,
    cache: Optional[dict],
    prefix_len: int,
    shard: Callable = Identity,
    page_state: Optional[dict] = None,
):
    B, S, _ = h.shape
    H, Dh = cfg.n_heads, cfg.d_head
    window = (
        cfg.sliding_window
        if kind == "swa"
        else cfg.local_window if kind == "local" else 0
    )
    q, k, v = _project_qkv(ap, h, cfg, plan)
    if cfg.pos_embedding == "rope":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    q, k, v = shard(q, "act_heads"), shard(k, "act_kv"), shard(v, "act_kv")

    new_cache = None
    if cache is not None and "paged" in cache:
        # Continuous-batching serve path: write this step's KV into the
        # block pool at each slot's own positions, then attend straight off
        # the block table (prefill-chunk rows and decode rows are the same
        # code — ``q_lens`` says how many slab rows are live per slot).
        bs = page_state["block_size"]
        table = page_state["table"]
        q_lens = page_state.get("q_lens")
        pos2d = jnp.broadcast_to(positions, (B, S)).astype(jnp.int32)
        valid = None
        if q_lens is not None:  # dead slab rows write to the trash block
            valid = jnp.arange(S)[None, :] < q_lens[:, None]
        entry = C.paged_update(cache["paged"], k, v, pos2d, table, bs, valid)
        if page_state.get("fused"):
            # Fused Pallas kernel: walks the table, streams pages into VMEM
            # tiles, dequantizes int8 in-kernel — no dense gather in HBM.
            from repro.kernels.paged_attention.ops import paged_attention

            ql = q_lens if q_lens is not None else jnp.full((B,), S, jnp.int32)
            o = paged_attention(
                q, entry, table, pos2d[:, 0], ql,
                block_size=bs, window=window,
                pages_per_tile=page_state.get("pages_per_tile", 0),
            )
        else:
            # jnp fallback (model-sharded meshes, oracle tests): gather the
            # pages — clamped to the live high-water mark when concrete —
            # then attend with per-slot masks.
            kf, vf = C.paged_gather(entry, table, bs)
            o = L.paged_attention(q, kf, vf, pos2d, window=window)
        out = shard(o.reshape(B, S, H * Dh), "act_heads_flat") @ ap["wo"]
        return out, {"paged": entry}, None
    if cache is None:
        o = L.blocked_attention(
            q, k, v,
            causal=cfg.causal,
            window=window,
            q_chunk=plan.mha.pu.block_m,
            k_chunk=plan.mha.pu.block_n,
            prefix_len=prefix_len,
        )
        kv_out = (k, v)
    else:
        Sc = cache["k"].shape[1]
        t = cache["t"]  # filled length before this token
        idx = t % Sc if window else jnp.minimum(t, Sc - 1)
        k_cache = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        v_cache = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        eff_len = jnp.minimum(t + 1, Sc)
        o = L.decode_attention(q, k_cache, v_cache, eff_len, window=0)
        new_cache = {"k": k_cache, "v": v_cache, "t": t + 1}
        kv_out = None
    out = shard(o.reshape(B, S, H * Dh), "act_heads_flat") @ ap["wo"]
    return out, new_cache, kv_out


def cross_attention_stage(cp: dict, h: jax.Array, memory_kv, cfg: ArchConfig):
    """Decoder -> encoder-memory attention (whisper). memory_kv: (k, v)."""
    B, S, _ = h.shape
    q = (h @ cp["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    mk, mv = memory_kv
    o = L.plain_cross_attention(q, mk, mv)
    return o.reshape(B, S, cfg.n_heads * cfg.d_head) @ cp["wo"]


def cross_kv(cp: dict, memory: jax.Array, cfg: ArchConfig):
    B, Se, _ = memory.shape
    mk = (memory @ cp["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.d_head)
    mv = (memory @ cp["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.d_head)
    return mk, mv


# ---------------------------------------------------------------------------
# The EDPU layer
# ---------------------------------------------------------------------------
def edpu_layer(
    lp: dict,
    x: jax.Array,
    *,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    kind: str,
    positions: jax.Array,
    cache: Optional[dict] = None,
    memory: Optional[jax.Array] = None,
    prefix_len: int = 0,
    causal_override: Optional[bool] = None,
    collect: bool = False,
    shard: Callable = Identity,
    page_state: Optional[dict] = None,
):
    """One Encoder/Decoder layer: MHA Stage -> (cross) -> FFN Stage.

    ``collect=True`` (prefill) harvests decode-cache state from the parallel
    pass; the train path keeps it False so no KV leaves the layer scan.
    Returns (x, new_cache, aux_loss)."""
    run_cfg = cfg
    if causal_override is not None:
        import dataclasses

        run_cfg = dataclasses.replace(cfg, causal=causal_override)
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    # ---- MHA Stage ---------------------------------------------------------
    h = L.apply_norm(lp["attn"]["ln"], x, cfg.norm)
    if kind in ("attn", "swa", "local"):
        ac = None
        if cache is not None:
            ac = cache if "paged" in cache else cache.get("attn")
        a, nc, kv_out = attention_stage(
            lp["attn"], h,
            cfg=run_cfg, plan=plan, kind=kind, positions=positions,
            cache=ac, prefix_len=prefix_len, shard=shard,
            page_state=page_state,
        )
        if nc is not None:
            if "paged" in nc:
                new_cache["paged"] = nc["paged"]  # keep the pool tree shape
            else:
                new_cache["attn"] = nc
        if cache is None and collect and kv_out is not None:
            new_cache["kv_out"] = kv_out  # harvested by prefill
    elif kind == "rglru":
        a, nc = G.rglru_block(
            lp["attn"], h,
            n_heads=max(cfg.rnn_heads, 1),
            cache=None if cache is None else cache.get("rglru"),
            collect=collect,
        )
        if nc is not None:
            new_cache["rglru"] = nc
    elif kind == "rwkv6":
        a, nc = R.rwkv6_time_mix(
            lp["attn"], h,
            n_heads=cfg.rnn_heads, d_head=cfg.d_head,
            cache=None if cache is None else cache.get("rwkv"),
            collect=collect,
        )
        if nc is not None:
            new_cache["rwkv"] = nc
    else:
        raise ValueError(kind)
    x = shard(x + a, "act_hidden")

    # ---- Cross-attention sub-stage (enc-dec decoder only) -------------------
    if "cross" in lp:
        hc = L.apply_norm(lp["cross"]["ln"], x, cfg.norm)
        if cache is not None and "cross_kv" in cache:
            mkv = cache["cross_kv"]
        else:
            mkv = cross_kv(lp["cross"], memory, cfg)
        x = x + cross_attention_stage(lp["cross"], hc, mkv, cfg)
        if cache is not None or collect:
            new_cache["cross_kv"] = mkv

    # ---- FFN Stage ----------------------------------------------------------
    h2 = L.apply_norm(lp["ffn"]["ln"], x, cfg.norm)
    if cfg.is_moe:
        st = M.MoESettings(
            n_experts=cfg.n_experts,
            top_k=cfg.top_k,
            capacity_factor=cfg.moe_capacity_factor,
            dispatch=plan.moe_dispatch,
        )
        f, aux = M.moe_ffn(lp["ffn"], h2, st, cfg.activation)
    elif kind == "rwkv6":
        f, nc = R.rwkv6_channel_mix(
            lp["ffn"], h2,
            cache=None if cache is None else cache.get("cmix"),
            collect=collect,
        )
        if nc is not None:
            new_cache["cmix"] = nc
    else:
        f = L.mlp(lp["ffn"], h2, cfg.activation)
    x = shard(x + f, "act_hidden")
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Megatron-SP layer stack (manual collectives; docs/ARCHITECTURE.md
# §"Megatron-SP").  The residual stream is seq-sharded over `model`; each
# stage is one ring gather-matmul up and one reduce-scatter down, so the
# layernorm path lowers with zero all-gather ops.
# ---------------------------------------------------------------------------
def sp_edpu_layer(
    lp: dict,
    x: jax.Array,
    *,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    kind: str,
    positions: jax.Array,
    axis: str = "model",
    n_shards: int = 1,
):
    """One EDPU layer on the sequence-parallel residual.

    ``x`` is this device's (B_local, S/n, d) sequence chunk; weights are the
    local Megatron column/row shards (SP plans force unfused QKV so each
    projection splits on clean head boundaries).  Norms are token-local so
    they run directly on the chunk — the paper's "nonlinear operators
    inserted into the MM dataflow" (C6) costs no communication here.
    """
    ap = lp["attn"]
    Dh = cfg.d_head
    window = (
        cfg.sliding_window
        if kind == "swa"
        else cfg.local_window if kind == "local" else 0
    )

    # ---- MHA Stage: gather(seq) -> local heads -> scatter(seq) ------------
    h = L.apply_norm(ap["ln"], x, cfg.norm)
    wq, wk, wv = ap["wq"], ap["wk"], ap["wv"]
    qkv = L.sp_gather_matmul(
        h, jnp.concatenate([wq, wk, wv], axis=-1), axis, n_shards
    )
    q, k, v = jnp.split(
        qkv, [wq.shape[-1], wq.shape[-1] + wk.shape[-1]], axis=-1
    )
    B, S = q.shape[0], q.shape[1]
    q = q.reshape(B, S, wq.shape[-1] // Dh, Dh)  # local heads H/n
    k = k.reshape(B, S, wk.shape[-1] // Dh, Dh)  # local KV heads KV/n
    v = v.reshape(B, S, wv.shape[-1] // Dh, Dh)
    if cfg.qk_norm:
        q = L.rmsnorm(q, ap["q_norm"])
        k = L.rmsnorm(k, ap["k_norm"])
    if cfg.pos_embedding == "rope":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    o = L.blocked_attention(
        q, k, v,
        causal=False if cfg.encoder_only else cfg.causal,
        window=window,
        q_chunk=plan.mha.pu.block_m,
        k_chunk=plan.mha.pu.block_n,
    )
    o = o.reshape(B, S, o.shape[-2] * Dh)
    x = x + L.sp_scatter_matmul(o, ap["wo"], axis)

    # ---- FFN Stage --------------------------------------------------------
    h2 = L.apply_norm(lp["ffn"]["ln"], x, cfg.norm)
    return x + L.sp_mlp(lp["ffn"], h2, cfg.activation, axis, n_shards)


def sp_stack_forward(
    stack: PyTree,
    x: jax.Array,
    *,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    mesh,
    positions: jax.Array,
    axis: str = "model",
):
    """Run the stacked pattern-groups under shard_map with the residual
    seq-sharded over ``axis`` (Megatron-SP).  In/out spec for ``x`` comes
    from the same ``Shardings`` rules the GSPMD path uses, so entering and
    leaving the manual region needs no resharding."""
    from repro.dist.sharding import Shardings

    n_shards = dict(mesh.shape)[axis]
    pattern = cfg.layer_pattern
    sh = Shardings(mesh, plan, cfg)
    x_spec = sh.act_spec("act_hidden", x.shape)
    stack_specs = sh.stack_specs(stack)

    def body(wl, xl, pos):
        def group(xx, gp):
            for i, kind in enumerate(pattern):
                xx = sp_edpu_layer(
                    gp[i], xx, cfg=cfg, plan=plan, kind=kind,
                    positions=pos, axis=axis, n_shards=n_shards,
                )
            return xx, None

        gb = jax.checkpoint(group) if plan.remat else group
        xl, _ = lax.scan(gb, xl, wl)
        return xl

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(stack_specs, x_spec, PartitionSpec(None, None)),
        out_specs=x_spec,
        check_rep=False,
    )(stack, x, positions)


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------
def _run_stack(
    blocks: dict,
    x: jax.Array,
    layer_fn: Callable,
    pattern: tuple[str, ...],
    caches: Optional[dict] = None,
    remat: bool = False,
):
    """Scan the stacked pattern-groups, then the tail layers.

    layer_fn(lp, x, kind, cache) -> (x, new_cache, aux).
    caches mirrors blocks: {"stack": ..., "tail": ...} or None.
    Returns (x, new_caches, total_aux)."""

    def group_body(x, inp):
        gp, gcache = inp
        no_cache = gcache is None or hasattr(gcache, "ndim")  # scan dummy
        new_caches = []
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            c = None if no_cache else gcache[i]
            x, nc, a = layer_fn(gp[i], x, kind, c)
            new_caches.append(nc)
            aux += a
        return x, (tuple(new_caches), aux)

    body = jax.checkpoint(group_body) if remat else group_body
    new_stack = None
    total_aux = jnp.zeros((), jnp.float32)
    if blocks["stack"] is not None:
        stack_caches = None if caches is None else caches["stack"]
        if stack_caches is None:
            n = jax.tree.leaves(blocks["stack"])[0].shape[0]
            stack_caches = None
            xs = (blocks["stack"], _nones_like_scan(blocks["stack"]))
        else:
            xs = (blocks["stack"], stack_caches)
        x, (new_stack, auxes) = lax.scan(body, x, xs)
        total_aux += auxes.sum()
    new_tail = []
    for i, lp in enumerate(blocks["tail"]):
        kind = pattern[i % len(pattern)]
        c = None if caches is None else caches["tail"][i]
        x, nc, a = layer_fn(lp, x, kind, c)
        new_tail.append(nc)
        total_aux += a
    return x, {"stack": new_stack, "tail": tuple(new_tail)}, total_aux


def _nones_like_scan(tree):
    """A scan-compatible 'no cache' placeholder: broadcast None via a dummy."""
    n = jax.tree.leaves(tree)[0].shape[0]
    return jnp.zeros((n, 0))  # zero-width array; treated as falsy cache


def _weight_dtype(params: PyTree):
    """Compute dtype = dtype of the (>=2-D) weight leaves (norms stay fp32)."""
    for leaf in jax.tree.leaves(params):
        if getattr(leaf, "ndim", 0) >= 2:
            return leaf.dtype
    return jnp.bfloat16


def _embed_inputs(params: PyTree, batch: dict, cfg: ArchConfig, cache, dtype):
    """Token/prefix embedding + position injection (shared by the plain,
    sequence-parallel, and pipelined forwards).  Returns (x, positions,
    prefix_len)."""
    x_parts = []
    prefix_len = 0
    if "prefix_embeds" in batch:
        x_parts.append(batch["prefix_embeds"].astype(dtype))
        prefix_len = batch["prefix_embeds"].shape[1]
    if "tokens" in batch and "embed" in params:
        emb = params["embed"].astype(dtype)[batch["tokens"]]
        if cfg.activation == "geglu":  # gemma family scales embeddings
            emb = emb * jnp.asarray(cfg.d_model**0.5, dtype)
        x_parts.append(emb)
    x = x_parts[0] if len(x_parts) == 1 else jnp.concatenate(x_parts, axis=1)
    S = x.shape[1]

    t0 = 0 if cache is None else cache["t"]
    # Per-slot offsets (continuous batching hands a (B,) length vector).
    off = t0[:, None] if getattr(t0, "ndim", 0) == 1 else t0
    positions = off + jnp.arange(S)[None, :]
    if cfg.pos_embedding == "learned":
        x = x + params["pos"].astype(dtype)[None, :S] if cache is None else (
            x + lax.dynamic_slice_in_dim(params["pos"].astype(dtype), t0, 1)[None]
        )
    elif cfg.pos_embedding == "sinusoidal":
        pos = L.sinusoidal_positions(S, cfg.d_model).astype(dtype)
        if cache is None:
            x = x + pos[None]
        else:
            x = x + lax.dynamic_slice_in_dim(
                L.sinusoidal_positions(cfg.max_seq_len, cfg.d_model).astype(dtype),
                t0, 1)[None]
    return x, positions, prefix_len


def forward(
    params: PyTree,
    batch: dict,
    *,
    cfg: ArchConfig,
    plan: ExecutionPlan,
    cache: Optional[PyTree] = None,
    collect_cache: bool = False,
    shard: Callable = Identity,
    mesh=None,
    page_state: Optional[dict] = None,
):
    """Full model forward.

    batch keys (by arch): "tokens" (B,S) int32; optional "prefix_embeds"
    (B,P,d); enc-dec: "enc_embeds" (B,Se,d).  With ``cache`` set, runs one
    decode step (S == 1).  Returns (hidden (B,S,d), new_cache, aux).

    With ``plan.seq_parallel_acts`` and a real ``mesh``, the stacked
    layer-groups run through the Megatron-SP manual-collective path
    (:func:`sp_stack_forward`); everything else stays on the GSPMD path.

    With a *paged* ``cache`` (``models/cache.init_paged_cache``) and
    ``page_state={"table": (B, MB) int32, "block_size": int}``, the pass is a
    continuous-batching serve step: ``cache["t"]`` is a per-slot (B,) length
    vector and S may be a prefill chunk width (>= 1).
    """
    dtype = _weight_dtype(params)
    x, positions, prefix_len = _embed_inputs(params, batch, cfg, cache, dtype)
    B, S, _ = x.shape
    x = shard(x, "act_hidden")

    # ---- encoder (enc-dec archs) -------------------------------------------
    memory = None
    if cfg.enc_dec:
        if cache is not None and "memory" in cache:
            memory = cache["memory"]
        else:
            enc = batch["enc_embeds"].astype(dtype)
            enc = enc + L.sinusoidal_positions(enc.shape[1], cfg.d_model).astype(dtype)[None]
            enc_positions = jnp.arange(enc.shape[1])[None, :]

            def enc_layer_fn(lp, xx, kind, c):
                return edpu_layer(
                    lp, xx, cfg=cfg, plan=plan, kind=kind,
                    positions=enc_positions, cache=None, prefix_len=0,
                    causal_override=False, shard=shard,
                )

            enc, _, _ = _run_stack(
                params["encoder"], enc, enc_layer_fn, ("attn",), None, plan.remat
            )
            memory = L.apply_norm(params["encoder"]["final_norm"], enc, cfg.norm)

    # ---- decoder / main stack ------------------------------------------------
    def layer_fn(lp, xx, kind, c):
        c = None if (c is None or (hasattr(c, "ndim"))) else c  # scan dummy
        return edpu_layer(
            lp, xx, cfg=cfg, plan=plan, kind=kind, positions=positions,
            cache=c, memory=memory, prefix_len=prefix_len,
            causal_override=False if cfg.encoder_only else None,
            collect=collect_cache, shard=shard, page_state=page_state,
        )

    layer_caches = None if cache is None else cache["layers"]
    use_sp = (
        plan.seq_parallel_acts
        and mesh is not None
        and cache is None
        and not collect_cache
        and prefix_len == 0
        and params["blocks"]["stack"] is not None
    )
    if use_sp:
        x = sp_stack_forward(
            params["blocks"]["stack"], x, cfg=cfg, plan=plan, mesh=mesh,
            positions=positions,
        )
        # tail layers (if any) stay on the GSPMD path
        x, new_layer_caches, aux = _run_stack(
            {"stack": None, "tail": params["blocks"]["tail"]}, x, layer_fn,
            cfg.layer_pattern, None, plan.remat,
        )
        new_layer_caches = None
    else:
        x, new_layer_caches, aux = _run_stack(
            params["blocks"], x, layer_fn, cfg.layer_pattern, layer_caches,
            plan.remat,
        )
    x = L.apply_norm(params["final_norm"], x, cfg.norm)

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["layers"] = new_layer_caches
        new_cache["t"] = cache["t"] + S
    elif collect_cache:
        new_cache = {"layers": new_layer_caches, "t": S}
        if memory is not None:
            new_cache["memory"] = memory
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Heads + losses
# ---------------------------------------------------------------------------
def logits_fn(params: PyTree, x: jax.Array, cfg: ArchConfig):
    if cfg.n_classes:
        return x.mean(axis=1) @ params["cls_head"]
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    return x @ w


def chunked_softmax_xent(
    x: jax.Array,
    w: jax.Array,
    targets: jax.Array,
    loss_mask: Optional[jax.Array] = None,
    chunk: int = 512,
):
    """Cross-entropy without materializing full (B,S,V) logits.

    x: (B,S,d); w: (d,V); targets: (B,S) int32. Returns (sum_loss, n_tokens)."""
    B, S, d = x.shape
    c = min(chunk, S)
    while S % c:
        c //= 2
    N = S // c
    xr = x.reshape(B, N, c, d).swapaxes(0, 1)
    tr = targets.reshape(B, N, c).swapaxes(0, 1)
    if loss_mask is None:
        mr = jnp.ones((N, B, c), jnp.float32)
    else:
        mr = loss_mask.reshape(B, N, c).swapaxes(0, 1).astype(jnp.float32)

    # checkpoint: without it the scan saves every chunk's (B, c, V) logits
    # for the backward pass — 40 GB/chip at a 152k vocab.  Recompute instead.
    @jax.checkpoint
    def step(acc, inp):
        xc, tc, mc = inp
        logits = (xc @ w).astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        loss = (lse - tl) * mc
        return (acc[0] + loss.sum(), acc[1] + mc.sum()), None

    (total, n), _ = lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (xr, tr, mr))
    return total, jnp.maximum(n, 1.0)


def _head_loss(params: PyTree, x: jax.Array, batch: dict, cfg: ArchConfig,
               aux: jax.Array):
    """Loss from final hidden states (shared by every forward variant)."""
    if cfg.n_classes:  # classifier head (ViT)
        logits = logits_fn(params, x, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, batch["label"][:, None], axis=-1)[:, 0]
        return (lse - tl).mean() + 0.01 * aux
    w = params.get("lm_head", None)
    if w is None:
        w = params["embed"].T.astype(x.dtype)
    targets = batch["targets"]
    prefix = batch.get("prefix_embeds")
    if prefix is not None:
        # loss only over the text positions (prefix carries no targets)
        P = prefix.shape[1]
        x = x[:, P:]
    total, n = chunked_softmax_xent(x, w, targets, batch.get("loss_mask"))
    return total / n + 0.01 * aux


def lm_loss(params: PyTree, batch: dict, *, cfg: ArchConfig, plan: ExecutionPlan,
            shard: Callable = Identity, mesh=None):
    x, _, aux = forward(params, batch, cfg=cfg, plan=plan, shard=shard, mesh=mesh)
    return _head_loss(params, x, batch, cfg, aux)


def check_pipeline_supported(cfg: ArchConfig, plan: ExecutionPlan, batch: int):
    """Raise with the first reason a pod_role="pipeline" plan cannot route
    through pipeline_lm_loss; return (n_stage, n_micro) when it can."""
    n_stage = plan.pod_axis
    n_micro = plan.microbatches
    reasons = []
    if n_stage <= 1:
        reasons.append("pod axis has a single stage")
    if plan.model_axis > 1:
        # pipeline_forward's weight in_specs are P("pod", ...) only: a >1
        # model axis would gather the TP weight shards every step and
        # duplicate the stage compute across it
        reasons.append(
            f"model axis {plan.model_axis} > 1 (pipeline composes with DP, "
            "not TP; put the spare devices on 'data')"
        )
    if cfg.is_moe:
        reasons.append("MoE aux losses do not cross stage boundaries yet")
    if cfg.enc_dec or cfg.frontend != "none":
        reasons.append("enc-dec/frontends keep non-stack state")
    if batch % max(n_micro, 1):
        reasons.append(f"batch {batch} not divisible by microbatches {n_micro}")
    elif (batch // max(n_micro, 1)) % max(plan.data_axis, 1):
        # replication across DP replicas (measured 21x FLOPs waste) must
        # fail loudly, never run silently
        reasons.append(
            f"microbatch {batch // max(n_micro, 1)} does not fold over "
            f"data axis {plan.data_axis}"
        )
    if n_micro < n_stage:
        reasons.append(f"microbatches {n_micro} < stages {n_stage}")
    if reasons:
        raise ValueError(
            "pod_role='pipeline' plan cannot execute: " + "; ".join(reasons)
        )
    return n_stage, n_micro


def pipeline_lm_loss(params: PyTree, batch: dict, *, cfg: ArchConfig,
                     plan: ExecutionPlan, mesh, shard: Callable = Identity):
    """LM loss with the stacked layer-groups run as pipeline stages over the
    ``pod`` axis (dist.pipeline.pipeline_forward; docs/ARCHITECTURE.md
    §"Pod axis").

    Embedding, tail layers, final norm, and the loss head run on the GSPMD
    path (replicated over ``pod``); the stack weights are sliced per stage
    (``Shardings.param_spec`` puts ``pod`` on the stacked leading dim) and
    microbatches flow stage-to-stage via collective-permute.  Numerically
    identical to the data-parallel baseline: the same layers run on the
    same tokens, only the schedule changes.
    """
    from repro.dist.pipeline import pipeline_forward
    from repro.dist.sharding import Shardings

    dtype = _weight_dtype(params)
    x, positions, prefix_len = _embed_inputs(params, batch, cfg, None, dtype)
    B, S, D = x.shape
    n_stage, n_micro = check_pipeline_supported(cfg, plan, B)
    stack = params["blocks"]["stack"]
    n_groups = jax.tree.leaves(stack)[0].shape[0]
    if n_groups % n_stage:
        raise ValueError(
            f"{n_groups} stacked layer-groups do not split into "
            f"{n_stage} pipeline stages"
        )
    x = shard(x, "act_hidden")
    micro = x.reshape(n_micro, B // n_micro, S, D)

    sh = Shardings(mesh, plan, cfg)
    batch_axes = sh.batch_axes_for(B // n_micro) or ()
    pattern = cfg.layer_pattern

    def stage_fn(wl, xm):
        # positions recomputed from the microbatch shape: shard_map (inside
        # pipeline_forward) must not close over traced arrays.
        pos = jnp.arange(xm.shape[1])[None, :]

        def group(xx, gp):
            for i, kind in enumerate(pattern):
                xx, _, _ = edpu_layer(
                    gp[i], xx, cfg=cfg, plan=plan, kind=kind,
                    positions=pos, prefix_len=prefix_len,
                    causal_override=False if cfg.encoder_only else None,
                )
            return xx, None

        gb = jax.checkpoint(group) if plan.remat else group
        xm, _ = lax.scan(gb, xm, wl)
        return xm

    pp = pipeline_forward(stage_fn, mesh, axis="pod", batch_axes=tuple(batch_axes))
    x = pp(stack, micro).reshape(B, S, D)

    # tail layers reuse the shared stack runner (same as the SP branch)
    def layer_fn(lp, xx, kind, c):
        return edpu_layer(
            lp, xx, cfg=cfg, plan=plan, kind=kind, positions=positions,
            prefix_len=prefix_len,
            causal_override=False if cfg.encoder_only else None, shard=shard,
        )

    x, _, aux = _run_stack(
        {"stack": None, "tail": params["blocks"]["tail"]}, x, layer_fn,
        pattern, None, plan.remat,
    )
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    return _head_loss(params, x, batch, cfg, aux)
