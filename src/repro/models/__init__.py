from repro.models.transformer import edpu_layer, forward, lm_loss, logits_fn
from repro.models.params import init_params
from repro.models.cache import cache_from_prefill, init_cache

__all__ = [
    "edpu_layer",
    "forward",
    "lm_loss",
    "logits_fn",
    "init_params",
    "init_cache",
    "cache_from_prefill",
]
