"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Structure per block (the "ATB" of the recurrent layers — DESIGN.md §5):
    branch a: x -> W_x -> causal depthwise conv1d(width 4) -> RG-LRU
    branch b: x -> W_g -> GeLU
    out     : (a * b) @ W_out

RG-LRU recurrence (per channel, block-diagonal input/recurrence gates):
    r_t = sigmoid(gate_r(u_t));  i_t = sigmoid(gate_i(u_t))
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training uses an associative scan over (a_t, b_t) pairs — O(S log S) depth;
decode is the single-step update.  ``rglru_scan_ref`` (plain lax.scan) is the
oracle used by the property tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

_C = 8.0


def _block_gate(u: jax.Array, w: jax.Array, b: jax.Array, n_heads: int) -> jax.Array:
    """Block-diagonal linear gate: u (..., W) with (heads, W/h, W/h) weights."""
    shape = u.shape
    uh = u.reshape(*shape[:-1], n_heads, shape[-1] // n_heads)
    y = jnp.einsum("...hi,hij->...hj", uh, w) + b.reshape(n_heads, -1)
    return y.reshape(shape)


def _gates(params: dict, u: jax.Array, n_heads: int):
    r = jax.nn.sigmoid(_block_gate(u, params["w_gate_a"], params["b_gate_a"], n_heads))
    i = jax.nn.sigmoid(_block_gate(u, params["w_gate_x"], params["b_gate_x"], n_heads))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r  # (..., W), <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)
    return a, b


def rglru(params: dict, u: jax.Array, n_heads: int, h0=None) -> tuple[jax.Array, jax.Array]:
    """u: (B, S, W) fp32-upcast inside; returns (y (B,S,W), h_last (B,W))."""
    dt = u.dtype
    a, b = _gates(params, u.astype(jnp.float32), n_heads)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2
    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(dt), h[:, -1].astype(jnp.float32)


def rglru_scan_ref(params: dict, u: jax.Array, n_heads: int, h0=None):
    """Sequential oracle for the associative-scan implementation."""
    a, b = _gates(params, u.astype(jnp.float32), n_heads)
    h0 = jnp.zeros_like(u[:, 0], dtype=jnp.float32) if h0 is None else h0
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    _, hs = lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(u.dtype), hs[-1]


def rglru_decode_step(params: dict, u1: jax.Array, h: jax.Array, n_heads: int):
    """u1: (B, W) one step; h: (B, W) carried state."""
    a, b = _gates(params, u1.astype(jnp.float32), n_heads)
    h_new = a * h + b
    return h_new.astype(u1.dtype), h_new


def causal_conv1d(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv. x: (B, S, W); w: (cw, W); state: (B, cw-1, W)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(cw)
    )
    new_state = xp[:, -(cw - 1) :] if cw > 1 else None
    return out, new_state


def rglru_block(params: dict, x: jax.Array, *, n_heads: int, cache=None,
                collect: bool = False):
    """The full recurrent block. x: (B, S, d). cache: {"h", "conv"} or None.

    ``collect=True`` harvests the final recurrent + conv state from a parallel
    (prefill) pass.  Returns (y (B,S,d), new_cache)."""
    u = x @ params["w_x"]
    g = jax.nn.gelu(x @ params["w_g"], approximate=True)
    conv_state = None if cache is None else cache["conv"]
    u, new_conv = causal_conv1d(u, params["conv_w"], conv_state)
    if cache is None:
        h, h_last = rglru(params, u, n_heads)
    else:
        # decode: S == 1
        h1, h_last = rglru_decode_step(params, u[:, 0], cache["h"], n_heads)
        h = h1[:, None]
    y = (h * g) @ params["w_out"]
    new_cache = None
    if cache is not None or collect:
        new_cache = {"h": h_last, "conv": new_conv}
    return y, new_cache
