"""RWKV-6 "Finch" time-mix (arXiv:2404.05892) — data-dependent decay WKV.

Per head (d_k = d_v = d_head), with decay w_t in (0,1) per channel and bonus u:

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Three implementations:
  * ``wkv_scan_ref``  — sequential lax.scan oracle (tests).
  * ``wkv_chunked``   — chunked parallel form (intra-chunk masked matmuls in
    log-decay space + inter-chunk state carry).  This is the jnp reference of
    the Pallas kernel in ``repro.kernels.rwkv6`` and the path the model uses.
  * decode step       — single-token state update.

The projections (r, k, v, g, decay-lora) use token-shift mixing; the
channel-mix half of RWKV lives in ``transformer.py`` (relu^2 MLP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# WKV core
# ---------------------------------------------------------------------------
def wkv_scan_ref(r, k, v, w, u):
    """Sequential oracle.
    r/k/v: (B, S, H, D); w: (B, S, H, D) decay in (0,1); u: (H, D) bonus.
    Returns (B, S, H, D)."""
    B, S, H, D = r.shape
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(S_state, xs):
        rt, kt, vt, wt = xs  # (B, H, D)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, D, D)
        out = jnp.einsum(
            "bhk,bhkd->bhd", rt, S_state + u[None, :, :, None] * kv
        )
        S_new = wt[..., :, None] * S_state + kv
        return S_new, out

    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, w))
    _, outs = lax.scan(step, S0, xs)
    return outs.swapaxes(0, 1)


def wkv_chunked(r, k, v, w, u, chunk: int = 32, state=None, return_state=False):
    """Chunked parallel WKV.  Same signature/semantics as the oracle.

    Within a chunk (length c), with L_t = sum_{m<=t} log w_m (per channel):
      intra: o_t += sum_{j<t} (r_t * exp(L_{t-1} - L_j))^T ... realized as a
             masked (c x c) matmul over D with decay-ratio weights
      bonus: o_t += (r_t * u)^T k_t v_t            (the j = t term)
      cross: o_t += (r_t * exp(L_{t-1} - L_0-)) @ S_prev
      carry: S = diag(exp(L_c)) S_prev + sum_j (k_j exp(L_c - L_j)) v_j^T
    """
    B, S, H, D = r.shape
    c = min(chunk, S)
    while S % c:
        c //= 2
    N = S // c
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)
    logw = jnp.log(jnp.maximum(w, 1e-12)).reshape(B, N, c, H, D)
    rr = r.reshape(B, N, c, H, D)
    kk = k.reshape(B, N, c, H, D)
    vv = v.reshape(B, N, c, H, D)

    L = jnp.cumsum(logw, axis=2)  # inclusive cumulative log decay
    Lc = L[:, :, -1]  # (B, N, H, D) total chunk decay
    # decay from position j (exclusive) to chunk end / to position t-1:
    # exp(L_{t-1} - L_j) for j < t  ==  exp((L_t - logw_t) - L_j)
    Lq = L - logw  # L_{t-1}: decay accumulated before t

    def chunk_step(S_state, xs):
        Li, Lqi, Lci, ri, ki, vi, lwi = xs
        # ri etc: (B, c, H, D); S_state: (B, H, D, D)
        # Intra-chunk decay ratio exp(L_{t-1} - L_j), j < t: the exponent is
        # <= 0 wherever the mask is true, so this form never overflows
        # (the factored exp(L)*exp(-L) form does for strong decays).
        delta = Lqi[:, :, None] - Li[:, None]  # (B, t, s, H, D)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        delta = jnp.where(mask[None, :, :, None, None], delta, -jnp.inf)
        att = jnp.einsum("bthd,bshd,btshd->bhts", ri, ki, jnp.exp(delta))
        o = jnp.einsum("bhts,bshd->bthd", att, vi)
        rdec = ri * jnp.exp(Lqi)  # r_t * exp(L_{t-1}), exponent <= 0
        # bonus (diagonal) term
        o += jnp.einsum("bthd,bthd,bthe->bthe", ri * u[None, None], ki, vi)
        # cross-chunk: state contribution
        o += jnp.einsum("bthk,bhkd->bthd", rdec, S_state)
        # state update
        kfut = ki * jnp.exp(Lci[:, None] - Li)  # decay from j to chunk end
        S_new = jnp.exp(Lci)[..., None] * S_state + jnp.einsum(
            "bshk,bshd->bhkd", kfut, vi
        )
        return S_new, o

    S0 = (
        jnp.zeros((B, H, D, D), jnp.float32)
        if state is None
        else state.astype(jnp.float32)
    )
    xs = tuple(
        t.swapaxes(0, 1)
        for t in (L, Lq, Lc, rr, kk, vv, logw.reshape(B, N, c, H, D))
    )
    S_last, outs = lax.scan(chunk_step, S0, xs)
    out = outs.swapaxes(0, 1).reshape(B, S, H, D)
    if return_state:
        return out, S_last
    return out


def wkv_decode_step(r1, k1, v1, w1, u, S_state):
    """One token. r1/k1/v1/w1: (B, H, D); S_state: (B, H, D, D)."""
    r1, k1, v1, w1 = (t.astype(jnp.float32) for t in (r1, k1, v1, w1))
    kv = k1[..., :, None] * v1[..., None, :]
    out = jnp.einsum("bhk,bhkd->bhd", r1, S_state + u[None, :, :, None].astype(jnp.float32) * kv)
    S_new = w1[..., :, None] * S_state + kv
    return out, S_new


# ---------------------------------------------------------------------------
# Full time-mix block
# ---------------------------------------------------------------------------
def _token_shift(x, mix, x_prev=None):
    """lerp(x, shift(x), mix). x: (B, S, d); x_prev: (B, d) decode state."""
    if x_prev is None:
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        shifted = jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    return x + (shifted - x) * mix.astype(x.dtype)


def rwkv6_time_mix(params: dict, x: jax.Array, *, n_heads: int, d_head: int,
                   cache=None, chunk: int = 32, collect: bool = False):
    """x: (B, S, d). cache: {"S": (B,H,D,D), "shift": (B,d)} or None.
    Returns (y (B,S,d), new_cache)."""
    B, S, d = x.shape
    H, D = n_heads, d_head
    shift_state = None if cache is None else cache["shift"]

    xr = _token_shift(x, params["mix_r"], shift_state)
    xk = _token_shift(x, params["mix_k"], shift_state)
    xv = _token_shift(x, params["mix_v"], shift_state)
    xg = _token_shift(x, params["mix_g"], shift_state)
    xw = _token_shift(x, params["mix_w"], shift_state)

    r = (xr @ params["w_r"]).reshape(B, S, H, D)
    k = (xk @ params["w_k"]).reshape(B, S, H, D)
    v = (xv @ params["w_v"]).reshape(B, S, H, D)
    g = jax.nn.silu(xg @ params["w_g"])
    # data-dependent decay via low-rank adapter (Finch):
    dw = jnp.tanh(xw.astype(jnp.float32) @ params["lora_a"]) @ params["lora_b"]
    logit = params["w0"].astype(jnp.float32) + dw  # (B, S, H*D)
    w = jnp.exp(-jnp.exp(logit)).reshape(B, S, H, D)  # in (0, 1)

    if cache is None:
        if collect:
            o, S_last = wkv_chunked(r, k, v, w, params["u"], chunk=chunk,
                                    return_state=True)
            new_cache = {"S": S_last, "shift": x[:, -1].astype(jnp.float32)}
        else:
            o = wkv_chunked(r, k, v, w, params["u"], chunk=chunk)
            new_cache = None
    else:
        o1, S_new = wkv_decode_step(
            r[:, 0], k[:, 0], v[:, 0], w[:, 0], params["u"], cache["S"]
        )
        o = o1[:, None]
        new_cache = {"S": S_new, "shift": x[:, -1].astype(jnp.float32)}

    # per-head groupnorm on the wkv output (RWKV6 uses GN over heads)
    o = o.reshape(B, S, H, D)
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1)[..., None]
    o = (o - mu) * lax.rsqrt(var + 1e-5)
    o = o * params["gn_scale"].reshape(H, D) + params["gn_bias"].reshape(H, D)
    o = o.reshape(B, S, H * D).astype(x.dtype) * g.astype(x.dtype)
    return o @ params["w_o"], new_cache


def rwkv6_channel_mix(params: dict, x: jax.Array, cache=None, collect: bool = False):
    """RWKV channel-mix: relu(xk @ Wk)^2 @ Wv gated by sigmoid(xr @ Wr)."""
    shift_state = None if cache is None else cache
    xk = _token_shift(x, params["mix_k"], shift_state)
    xr = _token_shift(x, params["mix_r"], shift_state)
    h = jnp.square(jax.nn.relu(xk @ params["w1"]))
    y = jax.nn.sigmoid(xr @ params["w_r"]) * (h @ params["w2"])
    new_cache = (
        x[:, -1].astype(jnp.float32) if (cache is not None or collect) else None
    )
    return y, new_cache
