"""Mixture-of-Experts FFN stage.

Two dispatch implementations, selected by the plan (and contrasted in
EXPERIMENTS.md §Perf):

* ``gshard``  — grouped one-hot einsum dispatch (GShard/Switch style).  SPMD-
  clean under pjit: with experts sharded on the ``model`` axis the dispatch
  einsums lower to all-to-alls.  Cost: the dispatch einsums burn real MXU
  FLOPs (O(tokens * E * capacity_per_group * d) per layer).
* ``sort``    — argsort-based token permutation into (E, C, d) buffers
  (MegaBlocks-style dropping).  Gather/scatter moves bytes, not FLOPs, so the
  useful-FLOPs ratio is much better; sharding is constrained explicitly.

Expert placement follows the plan: ``ep``   experts sharded over ``model``
(e.g. qwen3-moe 128e / 16 = 8 per chip); ``tp``   every expert's d_ff sharded
over ``model`` (e.g. mixtral 8e < 16 chips).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 1024  # gshard dispatch group (tokens)
    dispatch: str = "gshard"  # gshard | sort


def router_topk(x: jax.Array, w_router: jax.Array, top_k: int):
    """x: (T, d) -> (gates (T,k) fp32, idx (T,k) int32, aux load-balance loss)."""
    logits = (x.astype(jnp.float32)) @ w_router.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss.
    E = w_router.shape[-1]
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def _expert_ffn(params: dict, xe: jax.Array, activation: str) -> jax.Array:
    """xe: (E, C, d) -> (E, C, d), batched over experts."""
    h = jnp.einsum("ecd,edf->ecf", xe, params["w1"])
    if activation in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", xe, params["w3"])
        act = jax.nn.silu if activation == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True)
        )
        h = act(h) * g
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, params["w2"])


# ---------------------------------------------------------------------------
# GShard grouped-einsum dispatch
# ---------------------------------------------------------------------------
def moe_gshard(params: dict, x: jax.Array, st: MoESettings, activation: str):
    """x: (T, d). Groups of g tokens dispatch independently (bounds the
    one-hot size and the einsum FLOPs)."""
    T, d = x.shape
    E, K = st.n_experts, st.top_k
    g = min(st.group_size, T)
    while T % g:
        g //= 2
    G = T // g
    cap = max(1, int(g * K * st.capacity_factor / E))

    gates, idx, aux = router_topk(x, params["router"], K)
    xg = x.reshape(G, g, d)
    idxg = idx.reshape(G, g, K)
    gatesg = gates.reshape(G, g, K)

    # Position of each (token, k) within its expert queue, per group.
    onehot_e = jax.nn.one_hot(idxg, E, dtype=jnp.float32)  # (G, g, K, E)
    flat = onehot_e.reshape(G, g * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, g, K, E)
    pos_k = jnp.take_along_axis(pos, idxg[..., None].astype(jnp.int32), axis=-1)
    pos_k = pos_k.squeeze(-1)  # (G, g, K): queue rank of each (token, k)
    in_cap = pos_k < cap
    onehot_c = jax.nn.one_hot(pos_k.astype(jnp.int32), cap, dtype=jnp.float32)
    onehot_c = onehot_c * in_cap[..., None]
    # combine[g,s,e,c] = gate of token s if it landed in (expert e, slot c).
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot_e, onehot_c, gatesg)
    dispatch = (combine > 0).astype(x.dtype)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)  # (G, E, C, d)
    xe = xe.transpose(1, 0, 2, 3).reshape(E, G * cap, d)
    ye = _expert_ffn(params, xe, activation)
    ye = ye.reshape(E, G, cap, d).transpose(1, 0, 2, 3)  # (G, E, C, d)
    out = jnp.einsum(
        "gsec,gecd->gsd", combine, ye.astype(jnp.float32)
    )
    return out.reshape(T, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Sort-based dispatch (optimized path)
# ---------------------------------------------------------------------------
def moe_sort(params: dict, x: jax.Array, st: MoESettings, activation: str):
    T, d = x.shape
    E, K = st.n_experts, st.top_k
    N = T * K
    C = max(1, int(T * K * st.capacity_factor / E))

    gates, idx, aux = router_topk(x, params["router"], K)
    flat_e = idx.reshape(N)
    flat_gate = gates.reshape(N)
    order = jnp.argsort(flat_e, stable=True)  # (N,)
    sorted_e = flat_e[order]
    # rank within expert = position - first index of that expert value
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(N) - first
    valid = rank < C
    slot = jnp.where(valid, sorted_e * C + rank, E * C)  # E*C = drop bin
    token_of = order // K

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(x[token_of], mode="drop")
    ye = _expert_ffn(params, buf[:-1].reshape(E, C, d), activation)
    y_flat = jnp.concatenate([ye.reshape(E * C, d), jnp.zeros((1, d), ye.dtype)])
    contrib = y_flat[slot].astype(jnp.float32) * (
        flat_gate[order] * valid
    )[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[token_of].add(contrib)
    return out.astype(x.dtype), aux


def moe_ffn(
    params: dict,
    x: jax.Array,
    st: MoESettings,
    activation: str,
):
    """x: (..., d) -> (..., d), plus the aux loss (fp32 scalar)."""
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    fn = moe_sort if st.dispatch == "sort" else moe_gshard
    y, aux = fn(params, xf, st, activation)
    return y.reshape(shape), aux
