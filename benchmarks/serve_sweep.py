"""Serving throughput: continuous-batching smoke + batch-occupancy sweep.

Two entry points:

* ``serving_smoke(arch, out)`` — drive the continuous-batching engine over a
  short mixed prefill/decode stream (staggered arrivals) and write
  ``BENCH_serve.json`` (tokens/s, steps, mean batch occupancy, serve plan).
  CI runs this on smollm-135m and uploads the artifact next to
  BENCH_smoke/BENCH_dist, so serving throughput is measurable across PRs.
* ``run()`` — the benchmarks/run.py hook: sweep the decode-slot count on the
  reduced config and emit ``serve_sweep/batchN`` CSV rows; occupancy in the
  derived column shows where slot count stops buying throughput.

    PYTHONPATH=src:. python -m benchmarks.serve_sweep --smoke \
        --arch smollm-135m --out BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.models.params import init_params
from repro.serve.engine import ServingEngine
from repro.serve.scheduler import random_stream


def _drive(cfg, decode_batch, *, n_requests=8, prompt_len=32, gen=16, stagger=2,
           seed=0):
    mesh = {"data": 1, "model": 1}
    plan = derive_plan(
        cfg, mesh, TPU_V5E, batch=decode_batch, seq_len=prompt_len, training=False
    )
    serve = derive_serve_plan(
        cfg, mesh, TPU_V5E,
        max_seq_len=max(64, prompt_len + gen),
        decode_batch=decode_batch,
        prefill_chunk=prompt_len,
        # narrow slab for the CPU smoke: prompt-width rows would make every
        # decode-phase step pay (W-1) dead rows per slot (the explicit
        # slab-width trade; docs/ARCHITECTURE.md §Serving)
        mixed_slab_width=min(prompt_len, 8),
    )
    params = init_params(jax.random.PRNGKey(seed), cfg, plan, dtype=jnp.float32)
    engine = ServingEngine(params, cfg, plan, serve)
    # warm the unified jitted step on a throwaway request so the measured
    # stream times serving, not XLA compilation
    engine.run(random_stream(cfg, 1, prompt_len, 2, seed=99, rid_prefix="warm"))
    engine.reset_stats()
    t0 = time.perf_counter()
    engine.run(random_stream(cfg, n_requests, prompt_len, gen, stagger, seed=7))
    wall = time.perf_counter() - t0
    s = engine.summary()
    s["wall_s"] = wall
    return s


def serving_smoke(arch: str = "smollm-135m", out: str = "BENCH_serve.json") -> dict:
    cfg = get_config(arch)
    s = _drive(cfg, decode_batch=4, n_requests=6, prompt_len=32, gen=12, stagger=2)
    record = {
        "arch": arch,
        # output tokens only — prompt rows ride in prefill_tokens, so the
        # headline tokens/s can no longer be inflated by prefill traffic
        "tokens_per_s": s["tok_per_s"],
        "generated_tokens": s["generated_tokens"],
        "prefill_tokens": s["prefill_tokens"],
        "steps": s["steps"],
        "fused_attention": s["fused_attention"],
        "mean_occupancy": s["mean_occupancy"],
        "evictions": s["evictions"],
        "traces": s["traces"],
        "latency_s": s["latency_s"],
        "ttft_s": s["ttft_s"],
        "wall_s": s["wall_s"],
        "serve_plan": s["serve_plan"],
        "spec_smoke": _spec_smoke(cfg),
    }
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {out}: {record['tokens_per_s']:.1f} tok/s "
          f"occupancy={record['mean_occupancy']:.2f} "
          f"spec_traces={record['spec_smoke']['traces']}")
    return record


def _spec_smoke(cfg) -> dict:
    """Serving-smoke invariant: speculation must not retrace the unified
    step (gamma varies per slot per iteration, but only ``kinds`` *values*
    change — any retrace here is a static-shape regression)."""
    from repro.serve.scheduler import random_stream
    from repro.serve.speculative import NGramDraft

    mesh = {"data": 1, "model": 1}
    plan = derive_plan(cfg, mesh, TPU_V5E, batch=4, seq_len=32, training=False)
    serve = derive_serve_plan(
        cfg, mesh, TPU_V5E, max_seq_len=64, decode_batch=4, prefill_chunk=16,
        mixed_slab_width=8, draft="ngram", spec_len=2,
    )
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    engine = ServingEngine(params, cfg, plan, serve, draft=NGramDraft())
    engine.run(random_stream(cfg, 4, 16, 8, stagger=1, seed=3))
    s = engine.summary()
    assert engine.trace_counts == {"step": 1}, (
        f"speculation retraced the unified step: {engine.trace_counts}"
    )
    return {
        "traces": dict(engine.trace_counts),
        "spec_len": serve.spec_len,
        "draft": serve.draft,
        "acceptance_rate": s["spec"]["acceptance_rate"],
        "tokens_per_spec_step": s["spec"]["tokens_per_spec_step"],
    }


def run() -> list[str]:
    """Batch-occupancy sweep on the reduced config (benchmarks/run.py hook)."""
    cfg = get_config("smollm-135m").reduced()
    out = []
    for b in (1, 2, 4, 8):
        s = _drive(cfg, decode_batch=b, n_requests=8, prompt_len=16, gen=8,
                   stagger=1)
        out.append(
            emit(
                f"serve_sweep/batch{b}",
                s["wall_s"] * 1e6,
                f"tok_s={s['tok_per_s']:.1f};occ={s['mean_occupancy']:.2f};"
                f"kv={s['serve_plan']['kv_dtype']}",
            )
        )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--out", default="BENCH_serve.json")
    a = ap.parse_args()
    if a.smoke:
        serving_smoke(a.arch, a.out)
    else:
        run()
