"""Serving throughput: continuous-batching smoke + batch-occupancy sweep.

Two entry points:

* ``serving_smoke(arch, out)`` — drive the continuous-batching engine over a
  short mixed prefill/decode stream (staggered arrivals) and write
  ``BENCH_serve.json`` (tokens/s, steps, mean batch occupancy, serve plan).
  CI runs this on smollm-135m and uploads the artifact next to
  BENCH_smoke/BENCH_dist, so serving throughput is measurable across PRs.
* ``rolled_sweep(arch, out)`` — decode tok/s vs the rolled-loop cap K at
  decode batch in {1, 4, 16} on a decode-heavy stream, written to
  ``BENCH_rolled.json``.  K=1 is the per-dispatch baseline; larger K
  amortizes the host dispatch overhead across K on-device decode
  iterations, which matters most at batch=1 where one dispatch moves one
  token.  The record keeps every point (including regressions — on a CPU
  backend XLA's while_loop overhead can eat the dispatch saving; the json
  is the honest measurement either way).
* ``run()`` — the benchmarks/run.py hook: sweep the decode-slot count on the
  reduced config and emit ``serve_sweep/batchN`` CSV rows (occupancy in the
  derived column shows where slot count stops buying throughput), then
  ``serve_rolled/b1kK`` rows for the rolled-loop A/B at batch=1.

    PYTHONPATH=src:. python -m benchmarks.serve_sweep --smoke \
        --arch smollm-135m --out BENCH_serve.json
    PYTHONPATH=src:. python -m benchmarks.serve_sweep --rolled \
        --arch smollm-135m --out BENCH_rolled.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_record, emit
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.models.params import init_params
from repro.serve.engine import ServingEngine
from repro.serve.scheduler import random_stream


def _drive(cfg, decode_batch, *, n_requests=8, prompt_len=32, gen=16, stagger=2,
           seed=0):
    mesh = {"data": 1, "model": 1}
    plan = derive_plan(
        cfg, mesh, TPU_V5E, batch=decode_batch, seq_len=prompt_len, training=False
    )
    serve = derive_serve_plan(
        cfg, mesh, TPU_V5E,
        max_seq_len=max(64, prompt_len + gen),
        decode_batch=decode_batch,
        prefill_chunk=prompt_len,
        # narrow slab for the CPU smoke: prompt-width rows would make every
        # decode-phase step pay (W-1) dead rows per slot (the explicit
        # slab-width trade; docs/ARCHITECTURE.md §Serving)
        mixed_slab_width=min(prompt_len, 8),
    )
    params = init_params(jax.random.PRNGKey(seed), cfg, plan, dtype=jnp.float32)
    engine = ServingEngine(params, cfg, plan, serve)
    # warm the unified jitted step on a throwaway request so the measured
    # stream times serving, not XLA compilation
    engine.run(random_stream(cfg, 1, prompt_len, 2, seed=99, rid_prefix="warm"))
    engine.reset_stats()
    t0 = time.perf_counter()
    engine.run(random_stream(cfg, n_requests, prompt_len, gen, stagger, seed=7))
    wall = time.perf_counter() - t0
    s = engine.summary()
    s["wall_s"] = wall
    return s


def serving_smoke(arch: str = "smollm-135m", out: str = "BENCH_serve.json") -> dict:
    cfg = get_config(arch)
    t0 = time.perf_counter()
    s = _drive(cfg, decode_batch=4, n_requests=6, prompt_len=32, gen=12, stagger=2)
    record = bench_record("serve_sweep", {
        "arch": arch,
        # output tokens only — prompt rows ride in prefill_tokens, so the
        # headline tokens/s can no longer be inflated by prefill traffic
        "tokens_per_s": s["tok_per_s"],
        "generated_tokens": s["generated_tokens"],
        "prefill_tokens": s["prefill_tokens"],
        "steps": s["steps"],
        "fused_attention": s["fused_attention"],
        "mean_occupancy": s["mean_occupancy"],
        "evictions": s["evictions"],
        "traces": s["traces"],
        "latency_s": s["latency_s"],
        "ttft_s": s["ttft_s"],
        "wall_s": s["wall_s"],
        "serve_plan": s["serve_plan"],
        "spec_smoke": _spec_smoke(cfg),
        "prometheus_roundtrip": _prometheus_smoke(cfg),
    }, config={"arch": arch}, seed=7, elapsed_s=time.perf_counter() - t0)
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {out}: {record['tokens_per_s']:.1f} tok/s "
          f"occupancy={record['mean_occupancy']:.2f} "
          f"spec_traces={record['spec_smoke']['traces']}")
    return record


def _spec_smoke(cfg) -> dict:
    """Serving-smoke invariant: speculation must not retrace the unified
    step (gamma varies per slot per iteration, but only ``kinds`` *values*
    change — any retrace here is a static-shape regression)."""
    from repro.serve.scheduler import random_stream
    from repro.serve.speculative import NGramDraft

    mesh = {"data": 1, "model": 1}
    plan = derive_plan(cfg, mesh, TPU_V5E, batch=4, seq_len=32, training=False)
    serve = derive_serve_plan(
        cfg, mesh, TPU_V5E, max_seq_len=64, decode_batch=4, prefill_chunk=16,
        mixed_slab_width=8, draft="ngram", spec_len=2,
    )
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    engine = ServingEngine(params, cfg, plan, serve, draft=NGramDraft())
    engine.run(random_stream(cfg, 4, 16, 8, stagger=1, seed=3))
    s = engine.summary()
    assert engine.trace_counts == {"step": 1}, (
        f"speculation retraced the unified step: {engine.trace_counts}"
    )
    return {
        "traces": dict(engine.trace_counts),
        "spec_len": serve.spec_len,
        "draft": serve.draft,
        "acceptance_rate": s["spec"]["acceptance_rate"],
        "tokens_per_spec_step": s["spec"]["tokens_per_spec_step"],
    }


def _prometheus_smoke(cfg) -> dict:
    """Serving-smoke invariant: the metrics a real engine run populates
    must survive a Prometheus text-exposition round trip exactly (parse of
    the rendered text == the registry's own flat samples)."""
    from repro.obs import Observability, prometheus_roundtrip_ok
    from repro.serve.scheduler import random_stream

    mesh = {"data": 1, "model": 1}
    plan = derive_plan(cfg, mesh, TPU_V5E, batch=2, seq_len=16, training=False)
    serve = derive_serve_plan(
        cfg, mesh, TPU_V5E, max_seq_len=64, decode_batch=2, prefill_chunk=8,
        mixed_slab_width=8,
    )
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    obs = Observability()
    engine = ServingEngine(params, cfg, plan, serve, obs=obs)
    engine.run(random_stream(cfg, 3, 8, 6, stagger=1, seed=5))
    assert prometheus_roundtrip_ok(obs.metrics), (
        "Prometheus text exposition did not round-trip the live registry"
    )
    text = obs.metrics.to_prometheus()
    return {
        "roundtrip_ok": True,
        "series": len([ln for ln in text.splitlines() if ln and ln[0] != "#"]),
        "exposition_bytes": len(text),
    }


def _drive_rolled(cfg, decode_batch, rolled, *, prompt_len=8, gen=24, seed=0):
    """Decode-heavy measurement for the rolled A/B: every request arrives at
    t=0 with a short prompt and a long generation, so the stream is almost
    entirely decode iterations — the regime the rolled loop targets."""
    mesh = {"data": 1, "model": 1}
    plan = derive_plan(
        cfg, mesh, TPU_V5E, batch=decode_batch, seq_len=prompt_len,
        training=False,
    )
    serve = derive_serve_plan(
        cfg, mesh, TPU_V5E,
        max_seq_len=max(64, prompt_len + gen),
        decode_batch=decode_batch,
        prefill_chunk=prompt_len,
        mixed_slab_width=min(prompt_len, 8),
        rolled_steps=rolled,
    )
    params = init_params(jax.random.PRNGKey(seed), cfg, plan, dtype=jnp.float32)
    engine = ServingEngine(params, cfg, plan, serve)
    # warm BOTH programs (gen > 2*rolled guarantees a rolled span compiles
    # when rolling is on) so the measured stream times serving, not XLA
    engine.run(random_stream(cfg, 1, prompt_len, max(4, 2 * rolled), seed=99,
                             rid_prefix="warm"))
    engine.reset_stats()
    t0 = time.perf_counter()
    engine.run(random_stream(cfg, decode_batch, prompt_len, gen, 0, seed=7))
    wall = time.perf_counter() - t0
    s = engine.summary()
    tr = engine.trace_counts
    assert set(tr) <= {"step", "rolled_step"} and tr["step"] == 1 and (
        tr.get("rolled_step", 0) <= 1
    ), f"rolled sweep retraced a serving step: {tr}"
    return {
        "batch": decode_batch,
        "rolled_cap": rolled,
        "tok_per_s": s["generated_tokens"] / wall,
        "generated_tokens": s["generated_tokens"],
        "wall_s": wall,
        "steps": s["steps"],
        "rolled": s["rolled"],
        "traces": dict(tr),
    }


def rolled_sweep(arch: str = "smollm-135m",
                 out: str = "BENCH_rolled.json") -> dict:
    """Decode tok/s vs rolled-loop cap K at batch in {1, 4, 16} (the ISSUE's
    acceptance sweep).  ``monotone_batch1`` records whether batch=1
    throughput improves monotonically-or-flat with K (5% measurement
    slack); a CPU backend may legitimately report False — the json carries
    the honest curve either way."""
    cfg = get_config(arch).reduced()
    points = []
    for b in (1, 4, 16):
        for k in (1, 2, 4, 8):
            points.append(_drive_rolled(cfg, b, k))
            p = points[-1]
            print(f"rolled b={b} K={k}: {p['tok_per_s']:.1f} tok/s "
                  f"spans={p['rolled']['dispatches']} "
                  f"mean_span={p['rolled']['mean_span']}")
    b1 = [p["tok_per_s"] for p in points if p["batch"] == 1]
    record = bench_record("rolled_sweep", {
        "arch": cfg.name,
        "points": points,
        "monotone_batch1": all(
            later >= 0.95 * prev for prev, later in zip(b1, b1[1:])
        ),
    }, config={"arch": arch, "batches": [1, 4, 16], "ks": [1, 2, 4, 8]},
        seed=7)
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {out}: batch=1 curve {[round(x, 1) for x in b1]} "
          f"monotone={record['monotone_batch1']}")
    return record


def run() -> list[str]:
    """Batch-occupancy sweep on the reduced config (benchmarks/run.py hook)."""
    cfg = get_config("smollm-135m").reduced()
    out = []
    for b in (1, 2, 4, 8):
        s = _drive(cfg, decode_batch=b, n_requests=8, prompt_len=16, gen=8,
                   stagger=1)
        out.append(
            emit(
                f"serve_sweep/batch{b}",
                s["wall_s"] * 1e6,
                f"tok_s={s['tok_per_s']:.1f};occ={s['mean_occupancy']:.2f};"
                f"kv={s['serve_plan']['kv_dtype']}",
            )
        )
    # rolled-loop A/B at the dispatch-bound operating point (batch=1)
    for k in (1, 4):
        p = _drive_rolled(cfg, 1, k, gen=16)
        out.append(
            emit(
                f"serve_rolled/b1k{k}",
                p["wall_s"] * 1e6,
                f"tok_s={p['tok_per_s']:.1f};"
                f"spans={p['rolled']['dispatches']}",
            )
        )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rolled", action="store_true",
                    help="decode tok/s vs rolled cap K -> BENCH_rolled.json")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--out", default="BENCH_serve.json")
    a = ap.parse_args()
    if a.smoke:
        serving_smoke(a.arch, a.out)
    elif a.rolled:
        rolled_sweep(a.arch, a.out if a.out != "BENCH_serve.json"
                     else "BENCH_rolled.json")
    else:
        run()
