"""Benchmark utilities: timing, CSV emission, and the provenance envelope
every BENCH_*.json artifact carries (:func:`bench_record`)."""
from __future__ import annotations

import hashlib
import json
import time

import jax

# Bump when the envelope's own keys change meaning; per-bench payload
# schemas evolve independently underneath it.
BENCH_SCHEMA_VERSION = 1


def bench_record(bench: str, payload: dict, *, config: dict | None = None,
                 seed: int | None = None, elapsed_s: float | None = None) -> dict:
    """Wrap one benchmark's payload in the shared provenance envelope.

    Every BENCH_*.json emitter goes through this so CI artifacts from
    different lanes/dates are comparable: the envelope pins the schema
    version, which device actually ran, the seed, and a short hash of the
    bench's own configuration (two artifacts with equal ``config_hash``
    measured the same thing).
    """
    dev = jax.devices()[0]
    record = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "bench": bench,
        "device": getattr(dev, "device_kind", str(dev)),
        "backend": jax.default_backend(),
        "seed": seed,
        "config_hash": (
            hashlib.sha256(
                json.dumps(config, sort_keys=True, default=str).encode()
            ).hexdigest()[:12]
            if config is not None
            else None
        ),
        "elapsed_s": None if elapsed_s is None else round(elapsed_s, 3),
    }
    record.update(payload)
    return record


def time_fn(fn, *args, iters: int = 5, warmup: int = 2, **kw) -> float:
    """Median wall-clock microseconds per call (post-jit)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
