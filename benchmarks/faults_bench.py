"""Fault-injection bench: goodput under chaos vs the fault-free baseline.

Replays the SAME seeded multi-tenant trace twice through the hardened
continuous-batching engine — once clean, once with a fixed
:class:`FaultInjector` schedule (transient dispatch faults, NaN-poisoned
logits, block-pool pressure, step-time spikes) — and reports what the
fault machinery *costs*: goodput (finished-stream tokens/s), TTFT, retries
taken, quarantine replays, ladder escalations.

The replay *asserts* the robustness contract while measuring it:

  1. every request the chaotic engine finishes is byte-identical to the
     clean run (faults change latency, never tokens);
  2. after the stream drains (and the injector releases any squeezed
     blocks) the pool is whole — zero leaked blocks;
  3. the no-retrace contract holds: at most the unified step, the rolled
     loop, and ONE ladder-fallback compile.

Two entry points:

* ``faults_smoke(arch, out)`` — the CI hook: full-size config, writes
  ``BENCH_faults.json`` with clean/chaos headline numbers + degradation
  ratios + the engine's fault counters and final health.
* ``run()`` — the benchmarks/run.py hook: reduced config, emits
  ``faults/{clean,chaos}`` CSV rows.

    PYTHONPATH=src:. python -m benchmarks.faults_bench --smoke \
        --arch smollm-135m --out BENCH_faults.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_record, emit
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.models.params import init_params
from repro.serve import FaultInjector, make_trace
from repro.serve.engine import ServingEngine

MIX = {"chat": 3, "summarize": 2, "classify": 2}

# the fixed chaos schedule (seed + rates = the whole experiment; horizon
# guarantees the stream drains even on slow hosts)
CHAOS = dict(
    seed=13,
    transient_rate=0.2, transient_burst=2,
    nan_rate=0.15,
    pressure_rate=0.2, pressure_frac=0.3, pressure_steps=2,
    spike_rate=0.2, spike_ms=0.5,
    horizon=48,
)


def _engine(cfg, *, max_seq=128, decode_batch=4, seed=0):
    mesh = {"data": 1, "model": 1}
    plan = derive_plan(
        cfg, mesh, TPU_V5E, batch=decode_batch, seq_len=32, training=False
    )
    serve = derive_serve_plan(
        cfg, mesh, TPU_V5E,
        max_seq_len=max_seq,
        decode_batch=decode_batch,
        prefill_chunk=16,
        mixed_slab_width=8,
        retry_backoff_s=0.0,  # measure machinery cost, not sleeps
    )
    params = init_params(jax.random.PRNGKey(seed), cfg, plan, dtype=jnp.float32)
    return ServingEngine(params, cfg, plan, serve)


def _replay(cfg, mk, *, injector=None, max_seq=128):
    """Replay ``mk()``'s trace on a fresh engine.  Warmup runs the SAME
    trace chaos-free first, so every lazy compile (unified step, rolled
    loop, fork copies) is paid before the timer starts and the measured
    delta is the fault machinery alone."""
    engine = _engine(cfg, max_seq=max_seq)
    engine.run(mk())
    engine.reset_stats()
    # armed only after warmup; reset_stats() rewound the iteration clock,
    # so the schedule replays from iteration 0 of the measured stream
    engine.injector = injector
    t0 = time.perf_counter()
    out = engine.run(mk())
    wall = time.perf_counter() - t0
    if injector is not None:
        injector.release(engine.sched.alloc)
    assert engine.sched.alloc.in_use == 0, "replay leaked blocks"
    tr = engine.trace_counts
    assert tr["step"] == 1 and tr.get("rolled_step", 0) <= 1 and (
        tr.get("fallback_step", 0) <= 1
    ), f"fault replay retraced a serving step: {tr}"
    s = engine.summary()
    s["wall_s"] = wall
    return out, s, engine


def _headline(s: dict) -> dict:
    return {
        "wall_s": s["wall_s"],
        "goodput_tok_per_s": (
            s["generated_tokens"] / s["wall_s"] if s["wall_s"] else None
        ),
        "generated_tokens": s["generated_tokens"],
        "steps": s["steps"],
        "requests": s["requests"],
        "ttft_s": s["ttft_s"],
        "faults": {k: v for k, v in s["faults"].items() if k != "injector"},
    }


def chaos_ab(cfg, *, max_seq=128, tenants=2, seed=3) -> dict:
    """A/B the same trace clean vs chaotic; assert byte parity."""
    mk = lambda: make_trace(
        cfg, MIX, tenants=tenants, system_prompt_len=24, stagger=1,
        seed=seed, max_tokens=max_seq,
    )
    out_clean, s_clean, _ = _replay(cfg, mk, max_seq=max_seq)
    inj = FaultInjector(**CHAOS)
    out_chaos, s_chaos, eng = _replay(cfg, mk, injector=inj, max_seq=max_seq)
    for rid, toks in out_chaos.items():
        assert toks == out_clean[rid], (
            f"chaos changed tokens on {rid} (must be byte-identical)"
        )
    clean, chaos = _headline(s_clean), _headline(s_chaos)
    ratio = lambda a, b: (a / b) if (a and b) else None
    return {
        "mix": MIX,
        "tenants": tenants,
        "requests": len(out_clean),
        "parity": "byte-identical",
        "injector": inj.summary(),
        "clean": clean,
        "chaos": chaos,
        "degradation": {
            "goodput_ratio": ratio(
                chaos["goodput_tok_per_s"], clean["goodput_tok_per_s"]
            ),
            "wall_ratio": ratio(chaos["wall_s"], clean["wall_s"]),
            "ttft_p50_ratio": ratio(
                (chaos["ttft_s"] or {}).get("p50"),
                (clean["ttft_s"] or {}).get("p50"),
            ),
        },
        "health": eng.health(),
    }


def faults_smoke(arch: str = "smollm-135m", out: str = "BENCH_faults.json") -> dict:
    cfg = get_config(arch)
    t0 = time.perf_counter()
    record = bench_record(
        "faults", {"arch": arch, "chaos_ab": chaos_ab(cfg)},
        config={"arch": arch}, seed=0, elapsed_s=time.perf_counter() - t0,
    )
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    ab = record["chaos_ab"]
    print(
        f"wrote {out}: parity={ab['parity']} "
        f"goodput x{ab['degradation']['goodput_ratio']:.2f} "
        f"retries={ab['chaos']['faults']['retries']} "
        f"quarantines={ab['chaos']['faults']['quarantines']} "
        f"injected={ab['injector']['injected']}"
    )
    return record


def run() -> list[str]:
    """Clean-vs-chaos replay on the reduced config (benchmarks/run.py hook)."""
    cfg = get_config("smollm-135m").reduced()
    ab = chaos_ab(cfg, max_seq=96)
    rows = []
    for label in ("clean", "chaos"):
        h = ab[label]
        f = h["faults"]
        rows.append(
            emit(
                f"faults/{label}",
                h["wall_s"] * 1e6,
                f"goodput={h['goodput_tok_per_s']:.0f};"
                f"retries={f['retries']};quar={f['quarantines']};"
                f"shed={f['shed']}",
            )
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--out", default="BENCH_faults.json")
    a = ap.parse_args()
    if a.smoke:
        faults_smoke(a.arch, a.out)
    else:
        run()
