"""Observability overhead A/B: the same serving stream with tracing off
(the default) vs the full bundle on (ring-buffer tracer + metrics + drift).

The tentpole claim is that observability hooks are host-side accounting
only — enabling them can never change the engine's *bytes* or its
no-retrace contract, and the wall-clock overhead is small.  This bench
pins all three, recorded honestly:

* **byte parity** — both runs produce identical token streams (asserted,
  not sampled);
* **trace contract** — both runs keep ``{step: 1, rolled_step <= 1}``;
* **overhead** — median wall ratio on/off over ``repeats`` alternating
  runs (alternating so drift in machine load hits both arms equally).
  A CPU interpreter's step time dwarfs the hooks, so expect ~1.0x; the
  ratio is recorded either way, not clamped.

Plus the export-side invariants CI wants off the same run: the Chrome
trace validates (monotone timestamps, >= 1 complete request lifecycle)
and the metrics registry round-trips through Prometheus text exposition.

    PYTHONPATH=src:. python -m benchmarks.obs_bench --smoke --out BENCH_obs.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_record, emit
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.models.params import init_params
from repro.obs import (
    Observability,
    prometheus_roundtrip_ok,
    validate_chrome_trace,
)
from repro.serve.engine import ServingEngine
from repro.serve.scheduler import random_stream

MESH1 = {"data": 1, "model": 1}


def _build(cfg):
    plan = derive_plan(cfg, MESH1, TPU_V5E, batch=3, seq_len=16, training=False)
    serve = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=64, decode_batch=3, block_size=8,
        prefill_chunk=8, mixed_slab_width=8,
    )
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    return params, plan, serve


def _drive(params, cfg, plan, serve, obs):
    engine = ServingEngine(params, cfg, plan, serve, obs=obs)
    # warm the jitted step so the measured stream times serving, not XLA
    engine.run(random_stream(cfg, 1, 8, 2, seed=99, rid_prefix="warm"))
    engine.reset_stats()
    t0 = time.perf_counter()
    out = engine.run(random_stream(cfg, 6, 8, 10, stagger=1, seed=7))
    wall = time.perf_counter() - t0
    tr = dict(engine.trace_counts)
    assert tr.get("step") == 1 and tr.get("rolled_step", 0) <= 1, (
        f"obs bench retraced the serving step: {tr}"
    )
    return out, wall, tr


def ab(arch: str = "smollm-135m", repeats: int = 3) -> dict:
    """Alternating off/on runs over the identical request stream."""
    cfg = get_config(arch).reduced()
    params, plan, serve = _build(cfg)
    walls_off, walls_on = [], []
    out_off = out_on = None
    obs_on = None
    for _ in range(repeats):
        out_off, w_off, tr_off = _drive(
            params, cfg, plan, serve, Observability()
        )
        obs_on = Observability(tracing=True)
        out_on, w_on, tr_on = _drive(params, cfg, plan, serve, obs_on)
        walls_off.append(w_off)
        walls_on.append(w_on)
    assert out_off == out_on, "tracing changed the engine's bytes"

    doc = obs_on.tracer.chrome_trace()
    events = validate_chrome_trace(doc)
    lifecycles = [
        e for e in events if e["name"] == "request" and e.get("ph") == "X"
    ]
    assert lifecycles, "trace export carries no complete request lifecycle"
    assert prometheus_roundtrip_ok(obs_on.metrics)

    off = statistics.median(walls_off)
    on = statistics.median(walls_on)
    return {
        "arch": cfg.name,
        "repeats": repeats,
        "parity": "byte-identical",
        "traces_bounded": True,
        "wall_s_off_median": off,
        "wall_s_on_median": on,
        # honest ratio: > 1 means the hooks cost wall time on this backend
        "overhead_ratio": on / off,
        "trace_events": len(events),
        "complete_lifecycles": len(lifecycles),
        "prometheus_roundtrip": True,
        "calibration_note": obs_on.drift.report()["note"],
    }


def obs_smoke(arch: str = "smollm-135m", out: str = "BENCH_obs.json") -> dict:
    t0 = time.perf_counter()
    record = bench_record(
        "obs_overhead", ab(arch), config={"arch": arch}, seed=7,
        elapsed_s=time.perf_counter() - t0,
    )
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    print(
        f"wrote {out}: overhead x{record['overhead_ratio']:.3f} "
        f"({record['trace_events']} trace events, "
        f"{record['complete_lifecycles']} complete lifecycles, "
        f"parity={record['parity']})"
    )
    return record


def run() -> list[str]:
    """benchmarks/run.py hook: one CSV row for the on/off A/B."""
    r = ab(repeats=1)
    return [
        emit(
            "obs/trace_on_vs_off",
            r["wall_s_on_median"] * 1e6,
            f"overhead={r['overhead_ratio']:.3f};"
            f"events={r['trace_events']};parity=1",
        )
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--out", default="BENCH_obs.json")
    a = ap.parse_args()
    if a.smoke:
        obs_smoke(a.arch, a.out)
    else:
        run()
