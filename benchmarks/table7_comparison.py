"""Paper Table VII — cross-design comparison, reframed for one platform:
a naive JAX implementation (the "general framework" a CHARM-style MM-operator
approach produces) vs the CAT-planned implementation, same BERT-Base model.

naive:  per-head QKV matmuls, materialized-score attention, no epilogue
        fusion, fp32 scores in HBM.
cat:    fused QKV, blocked online-softmax attention, epilogue-fused FFN.

CPU wall time + the v5e roofline-predicted throughput ratio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from benchmarks.table2_parallel_modes import _derived_speedup
from repro.configs import get_config
from repro.core.plan import derive_plan
from repro.models import init_params, lm_loss

B, L = 2, 256


def run() -> list[str]:
    cfg = get_config("bert-base")
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (B, L), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, L), 0, cfg.vocab_size),
    }
    out = []
    results = {}
    for name, fuse in (("naive", False), ("cat", True)):
        plan = derive_plan(
            cfg, {"data": 1, "model": 1}, batch=B, seq_len=L, fuse_qkv=fuse
        )
        params = init_params(key, cfg, plan, dtype=jnp.float32)
        fn = jax.jit(lambda p, b, plan=plan: lm_loss(p, b, cfg=cfg, plan=plan))
        us = time_fn(fn, params, batch, iters=3)
        results[name] = us
    pred = _derived_speedup(False, False, 1) / _derived_speedup(True, True, 12)
    out.append(emit("table7/naive_jax", results["naive"], "speedup=1.00x"))
    out.append(
        emit(
            "table7/cat_planned",
            results["cat"],
            f"cpu_speedup={results['naive']/results['cat']:.2f}x;"
            f"v5e_pred={pred:.2f}x",
        )
    )
    return out


if __name__ == "__main__":
    run()
