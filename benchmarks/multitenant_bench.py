"""Multi-tenant trace replay: prefix sharing A/B + per-class latency tables.

Two entry points:

* ``multitenant_smoke(arch, out)`` — replay a heterogeneous workload trace
  (chat / long-doc summarize / short classify, two tenants sharing per-tenant
  system prompts) through the continuous-batching engine twice — prefix
  sharing on and off — and write ``BENCH_multitenant.json``.  The smoke
  *asserts* the three invariants the sharing design promises:

    1. greedy outputs are byte-identical sharing on vs off (KV pages are a
       pure function of the token prefix, so shared pages == recomputed
       pages);
    2. the shared system prompts actually hit the radix index
       (``prefix.hit_rate > 0``);
    3. the serving programs still compile at most once each
       (``trace_counts`` bounded by ``{"step": 1, "rolled_step": 1}`` —
       fork copies ride a separate jit).

  It also runs the N-requests-one-prompt microbench: N staggered requests on
  a single prompt should prefill the prompt ~once, not ~N times, and consume
  ~1/N of the pool blocks the unshared baseline needs.

* ``run()`` — the benchmarks/run.py hook: replay the reduced-config trace
  sharing on/off and emit ``multitenant/{shared,unshared}`` CSV rows (the
  derived column carries hit rate, prefill tokens, and peak blocks).

    PYTHONPATH=src:. python -m benchmarks.multitenant_bench --smoke \
        --arch smollm-135m --out BENCH_multitenant.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_record, emit
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.models.params import init_params
from repro.serve import Request, make_trace, per_class_report, random_stream
from repro.serve.engine import ServingEngine

MIX = {"chat": 3, "summarize": 2, "classify": 2}


def _engine(cfg, *, prefix_sharing, max_seq=128, decode_batch=4, seed=0):
    mesh = {"data": 1, "model": 1}
    plan = derive_plan(
        cfg, mesh, TPU_V5E, batch=decode_batch, seq_len=32, training=False
    )
    serve = derive_serve_plan(
        cfg, mesh, TPU_V5E,
        max_seq_len=max_seq,
        decode_batch=decode_batch,
        prefill_chunk=16,
        mixed_slab_width=8,
        prefix_sharing=prefix_sharing,
    )
    params = init_params(jax.random.PRNGKey(seed), cfg, plan, dtype=jnp.float32)
    engine = ServingEngine(params, cfg, plan, serve)
    # warm the unified jitted step so the measured replay times serving,
    # not XLA compilation
    engine.run(random_stream(cfg, 1, 16, 2, seed=99, rid_prefix="warm"))
    engine.reset_stats()
    return engine


def _replay(cfg, reqs, *, prefix_sharing):
    engine = _engine(cfg, prefix_sharing=prefix_sharing)
    t0 = time.perf_counter()
    out = engine.run(reqs)
    wall = time.perf_counter() - t0
    s = engine.summary()
    s["wall_s"] = wall
    tr = engine.trace_counts
    assert set(tr) <= {"step", "rolled_step"} and tr["step"] == 1 and (
        tr.get("rolled_step", 0) <= 1
    ), f"trace replay retraced a serving step: {tr}"
    return out, s, engine


def trace_replay(cfg, *, max_seq=128, tenants=2, seed=3) -> dict:
    """A/B the same heterogeneous trace sharing on vs off; assert parity."""
    # fresh Request objects per run (the scheduler mutates them in place);
    # same seed -> identical prompts/arrivals, so outputs must match
    mk = lambda: make_trace(
        cfg, MIX, tenants=tenants, system_prompt_len=24, stagger=1,
        seed=seed, max_tokens=max_seq,
    )
    out_on, s_on, eng_on = _replay(cfg, mk(), prefix_sharing=True)
    out_off, s_off, _ = _replay(cfg, mk(), prefix_sharing=False)
    assert out_on == out_off, "sharing changed greedy outputs (must be byte-identical)"
    assert s_on["prefix"]["hit_rate"] > 0, (
        f"shared system prompts missed the radix index: {s_on['prefix']}"
    )
    return {
        "mix": MIX,
        "tenants": tenants,
        "requests": len(eng_on.sched.finished),
        "parity": "byte-identical",
        "shared": _headline(s_on),
        "unshared": _headline(s_off),
        "per_tenant": s_on["tenants"],
        "classes": per_class_report(eng_on.sched.finished),
    }


def _headline(s: dict) -> dict:
    return {
        "tokens_per_s": s["tok_per_s"],
        "prefill_tokens": s["prefill_tokens"],
        "generated_tokens": s["generated_tokens"],
        "steps": s["steps"],
        "mean_occupancy": s["mean_occupancy"],
        "wall_s": s["wall_s"],
        "prefix": s["prefix"],
    }


def one_prompt_scaling(cfg, *, n_requests=4, prompt_len=64, gen=16) -> dict:
    """N staggered requests on ONE prompt: sharing should collapse N prefills
    of the prompt into ~1 and the pool footprint by ~N x."""
    import numpy as np

    rng = np.random.default_rng(11)
    prompt = [int(t) for t in rng.integers(0, cfg.vocab_size, prompt_len)]
    # the leader arrives alone and prefills the prompt (one block per
    # iteration at slab width 8); followers land right after its pages are
    # registered, so each re-prefills only the un-shared tail block
    lead = prompt_len // 8 + 1
    mk = lambda: [
        Request(rid=f"one-{i}", prompt=list(prompt), max_new_tokens=gen,
                arrival=0 if i == 0 else lead)
        for i in range(n_requests)
    ]
    out_on, s_on, _ = _replay(cfg, mk(), prefix_sharing=True)
    out_off, s_off, _ = _replay(cfg, mk(), prefix_sharing=False)
    assert out_on == out_off, "one-prompt scaling: outputs diverged"
    # the unshared run prefills the prompt N times and holds N copies of its
    # pages; shared must beat it decisively (ratios ~N up to tail effects)
    assert s_on["prefill_tokens"] < s_off["prefill_tokens"], (
        s_on["prefill_tokens"], s_off["prefill_tokens"],
    )
    assert s_on["prefix"]["peak_blocks"] < s_off["prefix"]["peak_blocks"], (
        s_on["prefix"]["peak_blocks"], s_off["prefix"]["peak_blocks"],
    )
    return {
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "prefill_tokens": {
            "shared": s_on["prefill_tokens"],
            "unshared": s_off["prefill_tokens"],
            "ratio": s_off["prefill_tokens"] / max(s_on["prefill_tokens"], 1),
        },
        "peak_blocks": {
            "shared": s_on["prefix"]["peak_blocks"],
            "unshared": s_off["prefix"]["peak_blocks"],
            "ratio": s_off["prefix"]["peak_blocks"]
            / max(s_on["prefix"]["peak_blocks"], 1),
        },
        "tokens_saved": s_on["prefix"]["tokens_saved"],
    }


def multitenant_smoke(
    arch: str = "smollm-135m", out: str = "BENCH_multitenant.json"
) -> dict:
    cfg = get_config(arch)
    t0 = time.perf_counter()
    record = bench_record("multitenant", {
        "arch": arch,
        "trace_replay": trace_replay(cfg),
        "one_prompt_scaling": one_prompt_scaling(cfg),
    }, config={"arch": arch}, seed=0, elapsed_s=time.perf_counter() - t0)
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    tr = record["trace_replay"]
    sc = record["one_prompt_scaling"]
    print(
        f"wrote {out}: hit_rate={tr['shared']['prefix']['hit_rate']:.2f} "
        f"prefill {tr['unshared']['prefill_tokens']}->"
        f"{tr['shared']['prefill_tokens']} tok; "
        f"one-prompt x{sc['n_requests']}: prefill ratio "
        f"{sc['prefill_tokens']['ratio']:.1f}x, "
        f"blocks ratio {sc['peak_blocks']['ratio']:.1f}x"
    )
    return record


def run() -> list[str]:
    """Trace-replay A/B on the reduced config (benchmarks/run.py hook)."""
    cfg = get_config("smollm-135m").reduced()
    mk = lambda: make_trace(
        cfg, MIX, tenants=2, system_prompt_len=16, stagger=1, seed=3,
        max_tokens=96,
    )
    out = []
    for label, sharing in (("shared", True), ("unshared", False)):
        _, s, _ = _replay(cfg, mk(), prefix_sharing=sharing)
        out.append(
            emit(
                f"multitenant/{label}",
                s["wall_s"] * 1e6,
                f"hit={s['prefix']['hit_rate']:.2f};"
                f"prefill={s['prefill_tokens']};"
                f"blocks={s['prefix']['peak_blocks']}",
            )
        )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--out", default="BENCH_multitenant.json")
    a = ap.parse_args()
    if a.smoke:
        multitenant_smoke(a.arch, a.out)
    else:
        run()
