"""Paper Fig. 5 — throughput vs batch size (saturation curve).

BERT-Base forward on CPU: tokens/s per batch size; derived column gives the
v5e roofline prediction (batch amortizes weight streaming until the MXU
saturates — the paper sees saturation at batch 16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan
from repro.models import forward, init_params

L = 256


def _v5e_tokens_per_s(cfg, batch: int) -> float:
    hw = TPU_V5E
    n = cfg.param_count()
    flops = 2.0 * n * batch * L
    t_compute = flops / hw.peak_flops_bf16
    t_weights = 2.0 * n / hw.hbm_bandwidth  # stream weights once per step
    return batch * L / max(t_compute, t_weights)


def run() -> list[str]:
    cfg = get_config("bert-base")
    key = jax.random.PRNGKey(0)
    out = []
    for batch in (1, 2, 4, 8, 16):
        plan = derive_plan(cfg, {"data": 1, "model": 1}, batch=batch, seq_len=L)
        params = init_params(key, cfg, plan, dtype=jnp.float32)
        tokens = jax.random.randint(key, (batch, L), 0, cfg.vocab_size)
        fn = jax.jit(lambda p, t: forward(p, {"tokens": t}, cfg=cfg, plan=plan)[0])
        us = time_fn(fn, params, tokens, iters=3)
        tps = batch * L / (us / 1e6)
        out.append(
            emit(
                f"fig5/batch_{batch}",
                us,
                f"cpu_tok_s={tps:.0f};v5e_pred_tok_s={_v5e_tokens_per_s(cfg, batch):.2e}",
            )
        )
    return out


if __name__ == "__main__":
    run()
