"""Fused paged-attention kernel vs the gather-path attention microbenchmark.

Times one decode-attention layer step the two ways the serve engine can run
it, over (batch, context, block_size, kv_dtype):

* **gather** — what the engine did before the fused kernel: materialize the
  dense (B, cache_len, KH, D) cache from the block pool in HBM
  (``models/cache.paged_gather`` at full table width, exactly like the old
  jitted step), then ``models/layers.paged_attention`` over it.
* **fused** — ``kernels/paged_attention``: walk the block table, stream
  pages into VMEM tiles, online-softmax in place.  Slots only pay for their
  own live context (tiles past a slot's high-water mark are skipped), while
  the gather path always pays ``cache_len``.

Slots carry a realistic mixed decode state (live lengths drawn between half
and full context).  Emits ``BENCH_paged_attn.json`` and registers in
``benchmarks/run.py``; CI uploads the JSON next to BENCH_serve.json.

    PYTHONPATH=src:. python -m benchmarks.paged_attn_bench \
        --out BENCH_paged_attn.json
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_record, emit
from repro.core.hardware import TPU_V5E
from repro.kernels.paged_attention.ops import paged_attention as fused_attn
from repro.models.cache import paged_gather
from repro.models.layers import paged_attention as gather_attn


def _build(B, ctx, bs, kv_dtype, H=4, KH=2, D=64, seed=0):
    key = jax.random.PRNGKey(seed)
    MB = ctx // bs
    N = 1 + B * MB
    q = jax.random.normal(key, (B, 1, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (N, bs, KH, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (N, bs, KH, D), jnp.float32)
    if kv_dtype == "int8":
        def q8(x):
            s = jnp.maximum(jnp.abs(x).max(-1, keepdims=True), 1e-12) / 127.0
            return jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8), s

        qk, sk = q8(k)
        qv, sv = q8(v)
        entry = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    else:
        entry = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
    rng = np.random.default_rng(seed)
    lens = rng.integers(ctx // 2, ctx, B).astype(np.int32)  # mixed decode state
    table = np.zeros((B, MB), np.int32)
    for b in range(B):
        nb = -(-(int(lens[b]) + 1) // bs)
        table[b, :nb] = 1 + b * MB + np.arange(nb)
    return q, entry, jnp.asarray(table), jnp.asarray(lens)


@functools.partial(jax.jit, static_argnames=("bs",))
def _gather_step(q, entry, table, lens, *, bs):
    # the old engine's jitted path: dense gather at full table width (the
    # trace sees a Tracer table, so no high-water clamp applies — exactly
    # the over-materialization the fused kernel removes)
    kf, vf = paged_gather(entry, table, bs)
    return gather_attn(q, kf, vf, lens[:, None])


def _time(fn, *args, iters=10, repeats=5):
    jax.block_until_ready(fn(*args))  # compile + warm
    best = float("inf")
    for _ in range(repeats):  # min over repeats rejects scheduler noise
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def bench_case(B, ctx, bs, kv_dtype, ppt=None):
    q, entry, table, lens = _build(B, ctx, bs, kv_dtype)
    q_lens = jnp.ones((B,), jnp.int32)
    MB = table.shape[1]
    ppt = ppt or max(1, MB // 8)

    def fused(q, entry, table, lens, q_lens):
        return fused_attn(
            q, entry, table, lens, q_lens, block_size=bs, pages_per_tile=ppt
        )

    t_fused = _time(fused, q, entry, table, lens, q_lens)
    t_gather = _time(
        functools.partial(_gather_step, bs=bs), q, entry, table, lens
    )
    return {
        "batch": B,
        "context": ctx,
        "block_size": bs,
        "kv_dtype": kv_dtype,
        "pages_per_tile": ppt,
        "fused_us": t_fused * 1e6,
        "gather_us": t_gather * 1e6,
        "fused_decode_tok_s": B / t_fused,
        "gather_decode_tok_s": B / t_gather,
        "speedup": t_gather / t_fused,
    }


SWEEP = [
    # (batch, context, block_size, kv_dtype).  At the largest context the
    # derived plans flip KV pages to int8 (the bf16 pool cannot host the
    # roofline batch at full context — test_serve_plan_derivation), so the
    # headline cases carry the plan's own dtype; bf16 covers the small end.
    (4, 512, 16, "bf16"),
    (4, 2048, 16, "bf16"),
    (8, 2048, 32, "bf16"),
    (4, 2048, 16, "int8"),
    (8, 4096, 64, "bf16"),
    (8, 8192, 64, "int8"),
    (16, 8192, 64, "int8"),
]


def sweep(out: str = "BENCH_paged_attn.json") -> dict:
    t0 = time.perf_counter()
    cases = [bench_case(*c) for c in SWEEP]
    max_ctx = max(c["context"] for c in cases)
    at_largest = [c for c in cases if c["context"] == max_ctx]
    record = bench_record("paged_attn", {
        "hardware": TPU_V5E.name + " (cpu interpret timings)",
        "cases": cases,
        "largest_context": max_ctx,
        "fused_beats_gather_at_largest_context": bool(
            all(
                c["fused_decode_tok_s"] > c["gather_decode_tok_s"]
                for c in at_largest
            )
        ),
    }, config={"sweep": SWEEP}, seed=0, elapsed_s=time.perf_counter() - t0)
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    for c in cases:
        print(
            f"B{c['batch']} ctx{c['context']} bs{c['block_size']} "
            f"{c['kv_dtype']}: fused {c['fused_us']:.0f}us vs gather "
            f"{c['gather_us']:.0f}us ({c['speedup']:.2f}x)"
        )
    print(f"wrote {out}")
    return record


def run() -> list[str]:
    """benchmarks/run.py hook: the small end of the sweep as CSV rows."""
    rows = []
    for B, ctx, bs, kvd in SWEEP[:3]:
        c = bench_case(B, ctx, bs, kvd)
        rows.append(
            emit(
                f"paged_attn/b{B}_ctx{ctx}_{kvd}",
                c["fused_us"],
                f"gather_us={c['gather_us']:.0f};speedup={c['speedup']:.2f}",
            )
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_paged_attn.json")
    a = ap.parse_args()
    sweep(a.out)
