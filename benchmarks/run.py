# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        family_search,
        faults_bench,
        fig5_batch_sweep,
        multitenant_bench,
        obs_bench,
        paged_attn_bench,
        serve_sweep,
        spec_decode_bench,
        table2_parallel_modes,
        table5_utilization,
        table6_stage_perf,
        table7_comparison,
    )

    print("name,us_per_call,derived")
    ok = True
    for mod in (
        table2_parallel_modes,
        table5_utilization,
        table6_stage_perf,
        table7_comparison,
        fig5_batch_sweep,
        serve_sweep,
        paged_attn_bench,
        spec_decode_bench,
        multitenant_bench,
        faults_bench,
        family_search,
        obs_bench,
    ):
        try:
            mod.run()
        except Exception:
            ok = False
            traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
