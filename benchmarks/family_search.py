"""Accelerator-family search benchmark: predicted Pareto frontiers + a CPU
replay that sanity-checks the predicted ordering where it is measurable.

Two parts:

* **Predicted frontiers** — run ``core/search.search_family`` on qwen3-1.7b
  for two registered devices (tpu_v5e and vck5000, the paper's platform) and
  record the full frontier (tokens/s, $/Mtok, mJ/tok per point).  The search
  is pure host math, so this also asserts the frontier invariants CI cares
  about: every point feasible, no point dominated, tpu_v5e keeps >= 3
  non-dominated points (the family-mode acceptance bar), and a repeated
  search is identical (determinism).
* **Replay** — sweep a small measurable space (decode_batch x gamma on the
  reduced smollm config at max_seq 64), then actually drive the serving
  engine with each candidate's ServePlan and record measured tok/s next to
  the prediction.  ``ordering_holds`` / ``top_agrees`` report whether the
  predicted ranking survived contact with the CPU backend — recorded
  honestly either way (the cost model is a TPU roofline; a CPU interpreter
  legitimately disagrees at small scales).

    PYTHONPATH=src:. python -m benchmarks.family_search --smoke \
        --out BENCH_family.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_record, emit
from repro.configs import get_config
from repro.core.hardware import get_hardware
from repro.core.plan import derive_plan
from repro.core.search import (
    SearchSpace,
    dominates,
    search_family,
)
from repro.models.params import init_params
from repro.serve.engine import ServingEngine
from repro.serve.scheduler import random_stream
from repro.serve.speculative import NGramDraft

PREDICT_ARCH = "qwen3-1.7b"
PREDICT_DEVICES = ("tpu_v5e", "vck5000")


def predicted_frontiers() -> dict:
    """Search both devices; assert the frontier invariants."""
    out = {}
    for hw_name in PREDICT_DEVICES:
        hw = get_hardware(hw_name)
        result = search_family(PREDICT_ARCH, hw)
        again = search_family(PREDICT_ARCH, hw)
        assert [p.to_record() for p in result.frontier] == [
            p.to_record() for p in again.frontier
        ], f"family search is nondeterministic on {hw_name}"
        assert result.frontier, f"empty frontier on {hw_name}"
        assert all(p.feasible for p in result.frontier)
        for p in result.frontier:
            assert not any(
                dominates(q, p) for q in result.frontier if q is not p
            ), f"dominated point on the {hw_name} frontier"
        out[hw_name] = result.to_record()
    assert len(out["tpu_v5e"]["frontier"]) >= 3, (
        "tpu_v5e frontier collapsed below 3 non-dominated points"
    )
    return out


def _replay_point(cfg, plan, serve, *, gen=24, seed=7) -> dict:
    """Drive the engine with one design point's ServePlan; measured tok/s."""
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    draft = NGramDraft() if serve.spec_len > 0 else None
    engine = ServingEngine(params, cfg, plan, serve, draft=draft)
    b = serve.decode_batch
    engine.run(random_stream(cfg, 1, 8, 4, seed=99, rid_prefix="warm"))
    engine.reset_stats()
    t0 = time.perf_counter()
    engine.run(random_stream(cfg, max(b, 2), 8, gen, 0, seed=seed))
    wall = time.perf_counter() - t0
    s = engine.summary()
    return {
        "measured_tok_per_s": s["generated_tokens"] / wall,
        "generated_tokens": s["generated_tokens"],
        "wall_s": wall,
        # drift meter: measured/predicted per-dispatch ratio on THIS point,
        # from the same roofline that ranked the candidates
        "calibration": s["calibration"],
    }


def replay(max_points: int = 4) -> dict:
    """Predict a small measurable space, then measure every candidate."""
    cfg = get_config("smollm-135m").reduced()
    hw = get_hardware("tpu_v5e")
    space = SearchSpace(
        decode_batches=(1, 4),
        spec_lens=(0, 2),
        rolled_steps=(1,),
        max_seq_len=64,
    )
    result = search_family(cfg, hw, space)
    plan = derive_plan(
        cfg, {"data": 1, "model": 1}, hw, batch=4, seq_len=8, training=False
    )
    candidates = sorted(
        (p for p in result.points if p.feasible),
        key=lambda p: -p.tokens_per_s,
    )[:max_points]
    rows = []
    for p in candidates:
        m = _replay_point(cfg, plan, p.plan)
        drift = (m["calibration"] or {}).get("overall_ratio")
        rows.append(
            {
                "decode_batch": p.plan.decode_batch,
                "spec_len": p.plan.spec_len,
                "predicted_tok_per_s": p.tokens_per_s,
                "on_frontier": any(q is p for q in result.frontier),
                "drift_ratio": drift,
                **m,
            }
        )
        print(
            f"replay B={p.plan.decode_batch} gamma={p.plan.spec_len}: "
            f"predicted {p.tokens_per_s:.0f}, "
            f"measured {m['measured_tok_per_s']:.1f} tok/s"
            + (f", drift {drift:.0f}x" if drift else "")
        )
    pred_rank = sorted(
        range(len(rows)), key=lambda i: -rows[i]["predicted_tok_per_s"]
    )
    meas_rank = sorted(
        range(len(rows)), key=lambda i: -rows[i]["measured_tok_per_s"]
    )
    ordering_holds = pred_rank == meas_rank
    drifts = [r["drift_ratio"] for r in rows if r["drift_ratio"]]
    return {
        "arch": cfg.name,
        "points": rows,
        # predicted ordering vs measured, recorded honestly: the model is a
        # TPU roofline, the measurement a CPU interpreter — disagreement at
        # this scale is informative, not a failure
        "ordering_holds": ordering_holds,
        "top_agrees": bool(rows) and pred_rank[0] == meas_rank[0],
        # and WHY: the drift meter's per-point measured/predicted ratio,
        # plus the spread across points — a wide spread means the roofline
        # misprices candidates *relative to each other*, which is precisely
        # the failure mode that breaks orderings (a uniform offset wouldn't)
        "drift": {
            "per_point_ratio": drifts,
            "spread": (max(drifts) / min(drifts)) if drifts else None,
            "explanation": _ordering_explanation(ordering_holds, drifts),
        },
    }


def _ordering_explanation(ordering_holds: bool, drifts: list) -> str:
    if not drifts:
        return "no calibrated dispatches; drift unknown"
    spread = max(drifts) / min(drifts)
    lo, hi = min(drifts), max(drifts)
    if ordering_holds:
        return (
            f"predicted ordering held; per-point drift {lo:.3g}x-{hi:.3g}x "
            f"(spread {spread:.2f}x) was uniform enough to preserve ranks"
        )
    return (
        f"predicted ordering broke: per-point drift spans {lo:.3g}x-{hi:.3g}x "
        f"(spread {spread:.2f}x) — the roofline misprices these candidates "
        "relative to each other on this backend, so the predicted ranking "
        "cannot survive replay"
    )


def smoke(out: str = "BENCH_family.json") -> dict:
    t0 = time.perf_counter()
    record = bench_record("family_search", {
        "predicted": predicted_frontiers(),
        "replay": replay(),
    }, config={"arch": PREDICT_ARCH, "devices": PREDICT_DEVICES}, seed=7,
        elapsed_s=time.perf_counter() - t0)
    with open(out, "w") as f:
        json.dump(record, f, indent=1, default=str)
    sizes = {
        k: len(v["frontier"]) for k, v in record["predicted"].items()
    }
    print(
        f"wrote {out}: frontier sizes {sizes}, "
        f"replay top_agrees={record['replay']['top_agrees']} "
        f"ordering_holds={record['replay']['ordering_holds']}"
    )
    print(record["replay"]["drift"]["explanation"])
    return record


def run() -> list[str]:
    """benchmarks/run.py hook: frontier sweep timing + one replay point."""
    out = []
    for hw_name in PREDICT_DEVICES:
        t0 = time.perf_counter()
        result = search_family(PREDICT_ARCH, get_hardware(hw_name))
        us = (time.perf_counter() - t0) * 1e6
        best = result.frontier[0]
        out.append(
            emit(
                f"family_search/{hw_name}",
                us,
                f"frontier={len(result.frontier)};"
                f"best_tok_s={best.tokens_per_s:.0f};"
                f"best_usd_mtok={best.usd_per_mtok:.3f};"
                f"best_mj_tok={best.mj_per_tok:.2f}",
            )
        )
    rep = replay(max_points=2)
    for r in rep["points"]:
        out.append(
            emit(
                f"family_replay/b{r['decode_batch']}g{r['spec_len']}",
                r["wall_s"] * 1e6,
                f"measured={r['measured_tok_per_s']:.1f};"
                f"predicted={r['predicted_tok_per_s']:.0f}",
            )
        )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_family.json")
    a = ap.parse_args()
    if a.smoke:
        smoke(a.out)
    else:
        run()
