"""Paper Table VI — per-stage performance (MHA Stage vs FFN Stage vs system)
for the paper's own models (BERT-Base L=256, ViT-Base L=197).

CPU wall time per stage + derived v5e TOPS from the roofline model; the
paper's structural claims replicated: system sits between the two stages,
ViT's MHA throughput suffers from L=197 padding.

Also emits ``BENCH_dist.json``: the gradient-exchange bytes-on-wire
comparison (fp32 baseline vs the bf16/int8 ``compressed_psum`` wire
formats from ``dist/collectives.py``) plus the measured int8 round-trip
error of the exchange on a tiny gradient tree.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import bench_record, emit, time_fn
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan
from repro.core.pu import pick_pu
from repro.kernels.mm_pu.ops import pad_overhead
from repro.models import init_params
from repro.models import transformer as T
from repro.models.layers import apply_norm


def _stage_fns(cfg, plan, params):
    lp = jax.tree.map(lambda x: x[0], params["blocks"]["stack"])[0]
    positions = jnp.arange(256)[None]

    @jax.jit
    def mha_stage(x):
        h = apply_norm(lp["attn"]["ln"], x, cfg.norm)
        out, _, _ = T.attention_stage(
            lp["attn"], h, cfg=cfg, plan=plan, kind="attn",
            positions=positions[:, : x.shape[1]], cache=None, prefix_len=0,
        )
        return x + out

    @jax.jit
    def ffn_stage(x):
        from repro.models.layers import mlp

        h = apply_norm(lp["ffn"]["ln"], x, cfg.norm)
        return x + mlp(lp["ffn"], h, cfg.activation)

    return mha_stage, ffn_stage


def _v5e_tops(cfg, L, stage: str) -> float:
    """Roofline-derived achievable TOPS for one stage on one chip."""
    hw = TPU_V5E
    D, H, F = cfg.d_model, cfg.n_heads, cfg.d_ff
    if stage == "mha":
        flops = 2 * L * D * 3 * D + 2 * 2 * L * L * D + 2 * L * D * D
        spec = pick_pu(L, 3 * D, D, hw)
        t = hw.matmul_time_s(L, 3 * D, D) * (1 + max(pad_overhead(L, 3 * D, D, spec), 0))
        t += 2 * 2 * L * L * D / hw.peak_flops_bf16 + hw.matmul_time_s(L, D, D)
    else:
        flops = 2 * L * D * F * 2
        t = hw.matmul_time_s(L, F, D) + hw.matmul_time_s(L, D, F)
    return flops / t / 1e12


def grad_exchange_report(archs=("bert-base", "vit-base"), out_path="BENCH_dist.json"):
    """Bytes-on-wire per gradient exchange, compressed vs uncompressed.

    Analytic per full-size model (one replica's payload per all-reduce, from
    the parameter count), plus a measured int8 exchange error on the
    reduced config so the number is grounded in the real collective.
    """
    from repro.core.plan import derive_plan
    from repro.dist.collectives import compressed_psum, wire_bytes
    from repro.models.params import param_count_tree

    import time as _time

    _t0 = _time.perf_counter()
    report = {"benchmark": "grad_exchange_bytes_on_wire", "archs": {}}
    for arch in archs:
        cfg = get_config(arch)
        n = cfg.param_count()
        per_mode = {m: wire_bytes(n, m) for m in ("none", "bf16", "int8")}
        report["archs"][arch] = {
            "params": n,
            "bytes_on_wire": per_mode,
            "reduction_vs_fp32": {
                m: round(per_mode["none"] / b, 2) for m, b in per_mode.items()
            },
        }
    # measured: int8 exchange on a reduced-config gradient tree (1 device:
    # psum over a size-1 axis still runs the full quantize/sum/dequant path)
    cfg = get_config("bert-base-reduced")
    plan = derive_plan(cfg, {"data": 1, "model": 1}, batch=2, seq_len=16)
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape) * 1e-2, params
    )
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh(
        (1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,)
    )
    exchanged = shard_map(
        lambda g: jax.tree.map(lambda x: compressed_psum(x, "data", "int8"), g),
        mesh=mesh, in_specs=(P(),), out_specs=P(), check_rep=False,
    )(grads)
    errs = [
        float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-12))
        for a, b in zip(jax.tree.leaves(exchanged), jax.tree.leaves(grads))
    ]
    report["int8_exchange_max_rel_err"] = max(errs)
    report["grad_leaves_measured"] = len(errs)
    report["params_measured"] = param_count_tree(params)
    report = bench_record(
        "grad_exchange", report, config={"archs": list(archs)}, seed=0,
        elapsed_s=_time.perf_counter() - _t0,
    )
    pathlib.Path(out_path).write_text(json.dumps(report, indent=1))
    print(f"wrote {out_path} ({len(report['archs'])} archs)", flush=True)
    return report


def run() -> list[str]:
    out = []
    for arch, L in (("bert-base", 256), ("vit-base", 197)):
        cfg = get_config(arch)
        plan = derive_plan(cfg, {"data": 1, "model": 1}, batch=2, seq_len=L)
        params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, L, cfg.d_model), jnp.float32)
        mha, ffn = _stage_fns(cfg, plan, params)
        t_mha = time_fn(mha, x)
        t_ffn = time_fn(ffn, x)
        tops_mha = _v5e_tops(cfg, L, "mha")
        tops_ffn = _v5e_tops(cfg, L, "ffn")
        out.append(emit(f"table6/{arch}/mha_stage", t_mha, f"v5e_tops={tops_mha:.1f}"))
        out.append(emit(f"table6/{arch}/ffn_stage", t_ffn, f"v5e_tops={tops_ffn:.1f}"))
        sys_tops = (tops_mha * t_mha + tops_ffn * t_ffn) / (t_mha + t_ffn)
        out.append(
            emit(f"table6/{arch}/system", t_mha + t_ffn, f"v5e_tops={sys_tops:.1f}")
        )
    rep = grad_exchange_report()
    for arch, r in rep["archs"].items():
        out.append(
            emit(
                f"table6/{arch}/grad_wire_int8_reduction",
                r["bytes_on_wire"]["int8"] / 1e6,
                f"x{r['reduction_vs_fp32']['int8']}_vs_fp32",
            )
        )
    return out


if __name__ == "__main__":
    run()
