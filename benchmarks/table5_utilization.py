"""Paper Table V — hardware utilization (C8 metrics re-derived for TPU).

deployment_rate  = chips holding useful (non-duplicated) work
effective_util   = MODEL_FLOPS / (HLO_FLOPs x chips) from the dry-run
Read from benchmarks/results/dryrun (falls back to computing the paper's
BERT walk-through numbers if the sweep has not run).
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.plan import derive_plan

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun" / "single"

ARCHS = ["mistral-large-123b", "smollm-135m", "qwen3-moe-30b-a3b", "rwkv6-1.6b"]


def deployment_rate(arch: str, batch: int = 256, seq: int = 4096) -> float:
    cfg = get_config(arch)
    mesh = {"data": 16, "model": 16}
    plan = derive_plan(cfg, mesh, batch=batch, seq_len=seq)
    total = 16 * 16
    if plan.mha.mode == "spatial":
        used = total  # every chip holds a weight slice and activation shard
    elif plan.dp_over_model:
        used = min(total, batch)  # chips beyond the batch idle
    else:
        used = 16 * min(16, batch // 16 if batch >= 16 else 1)
    return used / total


def run() -> list[str]:
    out = []
    for arch in ARCHS:
        rec_path = RESULTS / f"{arch}__train_4k.json"
        eff = None
        if rec_path.exists():
            rec = json.loads(rec_path.read_text())
            if rec.get("status") == "ok":
                eff = rec["model_flops_ratio"]
        dep = deployment_rate(arch)
        derived = f"deployment_rate={dep:.2f};effective_util={eff if eff is None else round(eff,3)}"
        out.append(emit(f"table5/{arch}", 0.0, derived))
    return out


if __name__ == "__main__":
    run()
