"""Paper Table II — the customization-attribute ablation.

Five labs over the ViT-Base MHA stage (Embed 768, 12 heads, L=197->256):
  Lab 1  per-head QKV MMs, unfused attention, 1 head at a time   (baseline)
  Lab 2  per-head QKV, blocked/fused attention ("pipeline parallel")
  Lab 3  Independent-Linear (fused QKV), unfused attention, 4-way head batch
  Lab 4  per-head QKV, blocked attention, 4-way head batch
  Lab 5  Independent-Linear + blocked attention + head batch  (CAT choice)

On CPU the wall-clock ratios are schedule-level analogs (no PL pipelining);
the derived column reports the v5e roofline prediction for each lab from the
CAT cost model (tile occupancy x HBM-roundtrip terms).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.hardware import TPU_V5E
from repro.core.pu import pick_pu
from repro.kernels.mm_pu.ops import pad_overhead

B, L, D, H = 4, 256, 768, 12
DH = D // H


def _mk(key):
    x = jax.random.normal(key, (B, L, D), jnp.float32)
    wq = jax.random.normal(jax.random.fold_in(key, 1), (H, D, DH), jnp.float32) * 0.04
    wk = jax.random.normal(jax.random.fold_in(key, 2), (H, D, DH), jnp.float32) * 0.04
    wv = jax.random.normal(jax.random.fold_in(key, 3), (H, D, DH), jnp.float32) * 0.04
    return x, wq, wk, wv


def _attn_unfused(q, k, v):
    s = jnp.einsum("blhd,bmhd->bhlm", q, k) / DH**0.5
    p = jax.nn.softmax(s, axis=-1)  # scores round-trip "HBM"
    return jnp.einsum("bhlm,bmhd->blhd", p, v)


def _attn_blocked(q, k, v):
    from repro.models.layers import blocked_attention

    return blocked_attention(q, k, v, causal=False, q_chunk=128, k_chunk=128)


@functools.partial(jax.jit, static_argnames=("fused_qkv", "blocked", "head_batch"))
def mha_stage(x, wq, wk, wv, *, fused_qkv: bool, blocked: bool, head_batch: int):
    if fused_qkv:  # C5: one (D, 3D) MM
        wqkv = jnp.concatenate(
            [wq.transpose(1, 0, 2).reshape(D, D), wk.transpose(1, 0, 2).reshape(D, D),
         wv.transpose(1, 0, 2).reshape(D, D)], axis=1)
        qkv = x @ wqkv
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(B, L, H, DH)
        k = k.reshape(B, L, H, DH)
        v = v.reshape(B, L, H, DH)
        attn = _attn_blocked if blocked else _attn_unfused
        return attn(q, k, v).reshape(B, L, D)
    # per-head MMs, processed head_batch heads at a time (P_ATB analog)
    outs = []
    for h0 in range(0, H, head_batch):
        hs = slice(h0, h0 + head_batch)
        q = jnp.einsum("bld,hdk->blhk", x, wq[hs])
        k = jnp.einsum("bld,hdk->blhk", x, wk[hs])
        v = jnp.einsum("bld,hdk->blhk", x, wv[hs])
        attn = _attn_blocked if blocked else _attn_unfused
        outs.append(attn(q, k, v))
    return jnp.concatenate(outs, axis=2).reshape(B, L, D)


def _derived_speedup(fused_qkv: bool, blocked: bool, head_batch: int) -> float:
    """v5e roofline model of the lab: MM tile occupancy x softmax HBM trips."""
    hw = TPU_V5E
    # QKV MMs: per-head (L x D x DH) vs fused (L x D x 3D)
    if fused_qkv:
        spec = pick_pu(B * L, 3 * D, D, hw)
        mm_t = hw.matmul_time_s(B * L, 3 * D, D)
        mm_t *= 1.0 + max(pad_overhead(B * L, 3 * D, D, spec), 0.0)
    else:
        spec = pick_pu(B * L, DH * head_batch, D, hw)
        per = hw.matmul_time_s(B * L, DH * head_batch, D)
        per *= 1.0 + max(pad_overhead(B * L, DH * head_batch, D, spec), 0.0)
        mm_t = per * (3 * H / head_batch)
    # attention: blocked keeps scores in VMEM; unfused round-trips them
    attn_flops = 2 * 2 * B * H * L * L * DH
    attn_t = attn_flops / hw.peak_flops_bf16
    if not blocked:
        score_bytes = 2 * B * H * L * L * 4  # write + read fp32 scores
        attn_t += score_bytes / hw.hbm_bandwidth
    return mm_t + attn_t


def run() -> list[str]:
    key = jax.random.PRNGKey(0)
    x, wq, wk, wv = _mk(key)
    labs = [
        ("lab1_baseline", dict(fused_qkv=False, blocked=False, head_batch=1)),
        ("lab2_pipeline", dict(fused_qkv=False, blocked=True, head_batch=1)),
        ("lab3_indep_linear", dict(fused_qkv=True, blocked=False, head_batch=H)),
        ("lab4_pipeline_atb4", dict(fused_qkv=False, blocked=True, head_batch=4)),
        ("lab5_cat_full", dict(fused_qkv=True, blocked=True, head_batch=H)),
    ]
    base_t = None
    base_d = _derived_speedup(False, False, 1)
    out = []
    for name, kw in labs:
        us = time_fn(mha_stage, x, wq, wk, wv, **kw)
        if base_t is None:
            base_t = us
        pred = base_d / _derived_speedup(**kw)
        out.append(
            emit(
                f"table2/{name}",
                us,
                f"cpu_speedup={base_t/us:.2f}x;v5e_pred={pred:.2f}x",
            )
        )
    return out


if __name__ == "__main__":
    run()
