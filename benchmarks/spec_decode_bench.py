"""Speculative decoding: acceptance-rate x tokens/step sweep.

Sweeps (draft source, gamma, context) on the reduced config and records,
per point, the draft acceptance rate and the mean output tokens per
speculating slot-step (1.0 = plain decode; > 1 means the free MXU slack is
buying real tokens).  Draft sources:

* ``ngram``  — prompt-lookup self-drafting (host-side, model-free);
* ``self``   — the target model drafting for itself (the acceptance *upper
  bound*: every draft matches, so tokens/step == gamma+1 minus end-of-
  request truncation — labelled honestly as an oracle, not a deployment);
* ``model``  — an independently initialized copy of the same reduced config.
  NOTE: random-init models collapse to a shared repeat-token attractor
  (tied embeddings make "repeat the last token" the argmax), so this row's
  acceptance is attractor-inflated — it is NOT a deployment floor; only
  trained draft/target pairs measure real cross-model acceptance.

Two entry points, same shape as ``serve_sweep``:

* ``spec_smoke(arch, out)`` — CI hook: run the sweep, assert greedy-token
  parity against the non-speculative engine and ONE trace of both the
  unified step and the draft step, and write ``BENCH_spec.json`` next to
  BENCH_serve.json.
* ``run()`` — benchmarks/run.py hook: emit ``spec/<draft>-g<g>-ctx<c>``
  CSV rows.

    PYTHONPATH=src:. python -m benchmarks.spec_decode_bench --smoke \
        --out BENCH_spec.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_record, emit
from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.models.params import init_params
from repro.serve.engine import ServingEngine
from repro.serve.scheduler import Request
from repro.serve.speculative import NGramDraft, make_draft_source

MESH1 = {"data": 1, "model": 1}


def _stream(cfg, prompt_len: int, gen: int, n: int = 4, seed: int = 7):
    """Half random prompts, half repetitive ones (prompt-lookup's habitat)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        if i % 2:
            pat = list(rng.integers(0, cfg.vocab_size, max(2, prompt_len // 4)))
            p = (pat * prompt_len)[:prompt_len]
        else:
            p = list(rng.integers(0, cfg.vocab_size, prompt_len))
        reqs.append(Request(rid=f"s{i:02d}", prompt=p, max_new_tokens=gen,
                            arrival=i))
    return reqs


def _draft_for(name: str, cfg, serve, params):
    if name == "ngram":
        return NGramDraft()
    if name == "self":  # oracle: the target drafts for itself
        return make_draft_source(cfg.name[: -len("-reduced")], cfg, serve,
                                 hw=TPU_V5E, params=params, reduced=True)
    # independent random weights of the same reduced config
    return make_draft_source(cfg.name[: -len("-reduced")], cfg, serve,
                             hw=TPU_V5E, seed=99, reduced=True)


def sweep(arch: str = "smollm-135m", gammas=(1, 2, 4), contexts=(16, 48),
          gen: int = 12) -> list[dict]:
    cfg = get_config(arch).reduced()
    plan = derive_plan(cfg, MESH1, TPU_V5E, batch=4, seq_len=max(contexts),
                       training=False)
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    records = []
    for ctx in contexts:
        base_kw = dict(
            max_seq_len=max(64, ctx + gen + 1), decode_batch=4, block_size=8,
            kv_dtype="fp32", prefill_chunk=min(ctx, 16),
        )
        plain_serve = derive_serve_plan(cfg, MESH1, TPU_V5E, **base_kw)
        plain = ServingEngine(params, cfg, plan, plain_serve)
        want = plain.run(_stream(cfg, ctx, gen))
        for name in ("ngram", "self", "model"):
            for g in gammas:
                serve = derive_serve_plan(
                    cfg, MESH1, TPU_V5E, **base_kw, draft=name, spec_len=g
                )
                draft = _draft_for(name, cfg, serve, params)
                eng = ServingEngine(params, cfg, plan, serve, draft=draft)
                t0 = time.perf_counter()
                got = eng.run(_stream(cfg, ctx, gen))
                wall = time.perf_counter() - t0
                s = eng.summary()
                assert got == want, f"spec parity broken: {name} g={g} ctx={ctx}"
                assert eng.trace_counts == {"step": 1}, eng.trace_counts
                dtr = s["spec"]["draft_traces"]
                assert dtr is None or sum(dtr.values()) <= 1, dtr
                records.append({
                    "draft": name,
                    "gamma": g,
                    "context": ctx,
                    "acceptance_rate": s["spec"]["acceptance_rate"],
                    "tokens_per_spec_step": s["spec"]["tokens_per_spec_step"],
                    "generated_tokens": s["generated_tokens"],
                    "steps": s["steps"],
                    "wall_s": wall,
                    "parity": True,
                    "traces": s["traces"],
                })
    return records


def spec_smoke(arch: str = "smollm-135m", out: str = "BENCH_spec.json") -> dict:
    t0 = time.perf_counter()
    records = sweep(arch)
    best = max(
        (r for r in records if r["tokens_per_spec_step"]),
        key=lambda r: r["tokens_per_spec_step"],
    )
    record = bench_record("spec_decode", {
        "arch": arch + "-reduced",
        "points": records,
        "best": best,
        "all_parity": all(r["parity"] for r in records),
    }, config={"arch": arch}, seed=0, elapsed_s=time.perf_counter() - t0)
    with open(out, "w") as f:
        json.dump(record, f, indent=1)
    print(
        f"wrote {out}: {len(records)} points; best {best['draft']} "
        f"gamma={best['gamma']} ctx={best['context']}: "
        f"{best['tokens_per_spec_step']:.2f} tok/spec-step "
        f"(acceptance {best['acceptance_rate']:.2f})"
    )
    return record


def run() -> list[str]:
    """benchmarks/run.py hook: one CSV row per sweep point."""
    out = []
    for r in sweep(gammas=(1, 2), contexts=(16,), gen=8):
        acc = r["acceptance_rate"]
        tps = r["tokens_per_spec_step"]
        out.append(
            emit(
                f"spec/{r['draft']}-g{r['gamma']}-ctx{r['context']}",
                r["wall_s"] * 1e6,
                f"acc={acc if acc is None else round(acc, 2)};"
                f"tok_step={tps if tps is None else round(tps, 2)}",
            )
        )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--out", default="BENCH_spec.json")
    a = ap.parse_args()
    if a.smoke:
        spec_smoke(a.arch, a.out)
    else:
        run()
