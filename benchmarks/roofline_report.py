"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single|multi]
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"
PEAK = 197e12


def load(mesh: str) -> list[dict]:
    out = []
    d = RESULTS / mesh
    if not d.exists():
        return out
    for f in sorted(d.glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


HBM_BW = 819e9


def roofline_fraction(r: dict) -> float:
    """Train/prefill: useful-FLOPs fraction of compute peak.
    Decode: useful-bytes fraction of HBM bandwidth (decode is bandwidth-
    bound by construction — weights+cache are read once per token)."""
    step = max(r["compute_s"], r["memory_floor_s"], r["collective_s"])
    if step <= 0:
        return 0.0
    if r["shape"] in ("decode_32k", "long_500k"):
        useful_bytes = r.get("memory_floor_bytes")
        if useful_bytes is None:
            useful_bytes = r["memory_floor_s"] * HBM_BW
        return (useful_bytes / step) / HBM_BW
    useful = r["model_flops"] / r["n_chips"]
    return useful / step / PEAK


def table(mesh: str) -> str:
    rows = []
    head = (
        "| arch | shape | status | compute | mem(HLO) | mem(floor) | coll(ring) "
        "| bottleneck | MODEL/HLO flops | roofline frac | fits HBM |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|"
    )
    rows.append(head)
    for r in load(mesh):
        if r.get("status") == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | skip | - | - | - | - | - | - | - |"
            )
            continue
        if r.get("status") != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | - | - | - | - |"
            )
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['memory_floor_s'])} "
            f"| {fmt_s(r['collective_s'])} | {r['bottleneck']} "
            f"| {r['model_flops_ratio']:.3f} | {roofline_fraction(r):.3f} "
            f"| {'yes' if r['fits_hbm'] else 'NO'} |"
        )
    return "\n".join(rows)


def summary(mesh: str) -> dict:
    rs = [r for r in load(mesh) if r.get("status") == "ok"]
    bn = {}
    for r in rs:
        bn[r["bottleneck"]] = bn.get(r["bottleneck"], 0) + 1
    fracs = sorted(
        ((roofline_fraction(r), r["arch"], r["shape"]) for r in rs)
    )
    return {
        "cells_ok": len(rs),
        "bottlenecks": bn,
        "worst": fracs[:5],
        "best": fracs[-5:],
        "all_fit_hbm": all(r["fits_hbm"] for r in rs),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    a = ap.parse_args()
    print(table(a.mesh))
    print()
    print(json.dumps(summary(a.mesh), indent=1, default=str))


if __name__ == "__main__":
    main()
