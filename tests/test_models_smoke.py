"""Per-arch smoke tests (task spec f): reduced config of the same family,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import ALL_ARCHS, get_config
from repro.core.plan import derive_plan
from repro.models import forward, init_params, lm_loss

MESH1 = {"data": 1, "model": 1}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch, key):
    cfg = get_config(arch).reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=16)
    params = init_params(key, cfg, plan, dtype=jnp.float32)
    batch = make_batch(cfg, key)

    x, _, aux = jax.jit(lambda p, b: forward(p, b, cfg=cfg, plan=plan))(params, batch)
    S_expected = 16 + (cfg.n_prefix_embeds if cfg.frontend != "none" else 0)
    assert x.shape == (2, S_expected, cfg.d_model)
    assert not np.any(np.isnan(np.asarray(x, np.float32)))

    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: lm_loss(p, batch, cfg=cfg, plan=plan))
    )(params)
    assert np.isfinite(float(loss))
    gnorm = sum(
        float(np.sum(np.square(np.asarray(g, np.float32))))
        for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mixtral-8x7b", "rwkv6-1.6b"])
def test_two_steps_reduce_loss(arch, key):
    """One gradient step on a repeated batch must reduce its loss."""
    from repro.train.optimizer import OptimizerConfig, init_state
    from repro.train.train_step import make_train_step

    cfg = get_config(arch).reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=16)
    params = init_params(key, cfg, plan, dtype=jnp.float32)
    batch = make_batch(cfg, key)
    step = jax.jit(
        make_train_step(cfg, plan, OptimizerConfig(peak_lr=1e-2, warmup_steps=1))
    )
    state = init_state(params)
    losses = []
    for _ in range(4):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_fused_vs_split_qkv_same_function(key):
    """C5 toggle changes the kernel schedule, not the function computed
    (same math, different param layout -> losses start in the same range)."""
    cfg = get_config("qwen3-1.7b").reduced()
    batch = make_batch(cfg, key)
    vals = {}
    for fuse in (True, False):
        plan = derive_plan(cfg, MESH1, batch=2, seq_len=16, fuse_qkv=fuse)
        params = init_params(key, cfg, plan, dtype=jnp.float32)
        vals[fuse] = float(lm_loss(params, batch, cfg=cfg, plan=plan))
    assert abs(vals[True] - vals[False]) < 1.0  # same init scale & task
