"""Int8 deployment mode (the paper's precision): weight-quantized MM PU
epilogue approximates the fp path at the model-layer level."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mm_pu.ops import mm_pu
from repro.kernels.mm_pu.ref import mm_pu_ref, quantize_weights_int8

KEY = jax.random.PRNGKey(0)


def test_int8_ffn_stage_close_to_fp():
    """A SwiGLU FFN stage computed entirely through int8 mm_pu kernels."""
    d, F, T = 64, 128, 32
    x = jax.random.normal(KEY, (T, d), jnp.float32)
    w1 = jax.random.normal(jax.random.fold_in(KEY, 1), (d, F), jnp.float32) * 0.1
    w3 = jax.random.normal(jax.random.fold_in(KEY, 2), (d, F), jnp.float32) * 0.1
    w2 = jax.random.normal(jax.random.fold_in(KEY, 3), (F, d), jnp.float32) * 0.1

    def ffn_fp(x):
        return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2

    q1, s1 = quantize_weights_int8(w1)
    q3, s3 = quantize_weights_int8(w3)
    q2, s2 = quantize_weights_int8(w2)

    h = mm_pu(x, q1, w_scale=s1, activation="silu")
    g = mm_pu(x, q3, w_scale=s3)
    y = mm_pu(h * g, q2, w_scale=s2)

    want = ffn_fp(x)
    rel = np.abs(np.asarray(y - want)).max() / np.abs(np.asarray(want)).max()
    assert rel < 0.05, f"int8 FFN deviates {rel:.3f} from fp"


def test_int8_memory_saving_is_real():
    w = jax.random.normal(KEY, (256, 256), jnp.float32)
    q, s = quantize_weights_int8(w)
    assert q.dtype == jnp.int8
    assert q.nbytes * 4 == w.nbytes  # 4x weight compression vs fp32
    # and the dequantized product stays close
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (16, 256), jnp.float32)
    rel = np.abs(
        np.asarray(mm_pu_ref(x, q, w_scale=s) - x @ w)
    ).max() / np.abs(np.asarray(x @ w)).max()
    assert rel < 0.03
