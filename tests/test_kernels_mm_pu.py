"""MM PU kernel: shape/dtype sweeps + epilogue fusion vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.kernels.mm_pu.ops import mm_pu, pad_overhead
from repro.kernels.mm_pu.ref import mm_pu_ref, quantize_weights_int8
from repro.core.pu import MMTileSpec

KEY = jax.random.PRNGKey(0)


def _mk(m, k, n, dtype):
    x = jax.random.normal(KEY, (m, k), jnp.float32).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), jnp.float32) * 0.05).astype(dtype)
    return x, w


SHAPES = [(128, 128, 128), (256, 512, 384), (197, 768, 768), (64, 100, 32), (300, 64, 513)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(shape, dtype):
    m, k, n = shape
    x, w = _mk(m, k, n, dtype)
    got = np.asarray(mm_pu(x, w), np.float32)
    want = np.asarray(mm_pu_ref(x, w), np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("activation", ["gelu", "silu", "relu", "relu2", "none"])
def test_epilogue_activation(activation):
    x, w = _mk(256, 256, 256, jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 256), jnp.float32)
    r = jax.random.normal(jax.random.fold_in(KEY, 3), (256, 256), jnp.float32)
    got = np.asarray(mm_pu(x, w, bias=b, residual=r, activation=activation))
    want = np.asarray(mm_pu_ref(x, w, bias=b, residual=r, activation=activation))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_int8_dequant_epilogue():
    x, w = _mk(256, 384, 512, jnp.float32)
    q, s = quantize_weights_int8(w)
    got = np.asarray(mm_pu(x, q, w_scale=s))
    want = np.asarray(mm_pu_ref(x, q, w_scale=s))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    # the quantized result approximates the fp matmul
    full = np.asarray(mm_pu_ref(x, w))
    rel = np.abs(got - full).max() / (np.abs(full).max() + 1e-9)
    assert rel < 0.05


def test_pad_overhead_vit_observation():
    """Paper §V.D: ViT L=197 pads to 256 on a 64-tile -> measurable waste."""
    spec = MMTileSpec("t", 128, 128, 128)
    assert pad_overhead(197, 768, 768, spec) > 0.25
    assert pad_overhead(256, 768, 768, spec) == 0.0


@given(
    m=st.integers(8, 300),
    k=st.integers(8, 300),
    n=st.integers(8, 300),
)
@settings(max_examples=10, deadline=None)
def test_property_random_shapes(m, k, n):
    x, w = _mk(m, k, n, jnp.float32)
    got = np.asarray(mm_pu(x, w))
    want = np.asarray(mm_pu_ref(x, w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
