"""Data pipeline: determinism, resume, token-file source."""
import numpy as np

from repro.data.pipeline import DataConfig, DataIterator, synthetic_batch


def test_batch_pure_function_of_step():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=3)
    a = synthetic_batch(cfg, 7)
    b = synthetic_batch(cfg, 7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = synthetic_batch(cfg, 8)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_targets_are_shifted_tokens():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
    b = synthetic_batch(cfg, 0)
    assert b["tokens"].shape == (2, 8) and b["targets"].shape == (2, 8)


def test_iterator_seek_resume():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2)
    it = DataIterator(cfg)
    seq = [next(it) for _ in range(5)]
    it2 = DataIterator(cfg, start_step=3)
    np.testing.assert_array_equal(
        np.asarray(seq[3]["tokens"]), np.asarray(next(it2)["tokens"])
    )


def test_token_file_source(tmp_path):
    toks = np.arange(10_000, dtype=np.uint32) % 512
    path = tmp_path / "tokens.bin"
    toks.tofile(path)
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4, token_file=str(path))
    it = DataIterator(cfg)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 32)
    # next token property holds for the contiguous corpus
    np.testing.assert_array_equal(
        np.asarray(b1["targets"][:, :-1]), np.asarray(b1["tokens"][:, 1:])
    )
    # deterministic replay
    it2 = DataIterator(cfg)
    np.testing.assert_array_equal(
        np.asarray(next(it2)["tokens"]), np.asarray(b1["tokens"])
    )
