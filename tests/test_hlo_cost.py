"""Trip-count-aware HLO cost model: parity with unrolled reference."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.hlo_cost import analyze_hlo


def test_scan_flops_equal_unrolled():
    N, D = 8, 128
    w = jnp.zeros((N, D, D), jnp.float32)
    x = jnp.zeros((4, D), jnp.float32)

    def scanned(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None

        h, _ = lax.scan(body, x, w)
        return h.sum()

    def unrolled(x, w):
        h = x
        for i in range(N):
            h = jnp.tanh(h @ w[i])
        return h.sum()

    fs = analyze_hlo(jax.jit(scanned).lower(x, w).compile().as_text()).flops
    fu = analyze_hlo(jax.jit(unrolled).lower(x, w).compile().as_text()).flops
    expected = 2 * 4 * D * D * N
    assert abs(fs - expected) / expected < 0.02
    assert abs(fu - expected) / expected < 0.02


def test_nested_scan_multiplies():
    def nested(x):
        def outer(c, _):
            def inner(h, _):
                return jnp.tanh(h @ h), None

            h, _ = lax.scan(inner, c, None, length=3)
            return h, None

        y, _ = lax.scan(outer, x, None, length=5)
        return y.sum()

    x = jnp.eye(64)
    f = analyze_hlo(jax.jit(nested).lower(x).compile().as_text()).flops
    expected = 2 * 64**3 * 15
    assert abs(f - expected) / expected < 0.05


def test_collective_multiplier_inside_scan():
    # collectives require >1 device: emulate via a reduce over a sharded dim
    # If only 1 device is present, the partitioner emits no collectives; this
    # test then degrades to asserting the parse returns an empty list.
    hlo = """
HloModule test
%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8] all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]) tuple(%ni, %ar)
}
%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%z, %a)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""
    hc = analyze_hlo(hlo)
    assert len(hc.collectives) == 1
    op, b, g, m = hc.collectives[0]
    assert op == "all-reduce" and g == 4 and m == 12 and b == 32
