"""Plan autotuner: candidate search over a tiny dry-run (subprocess)."""
import json
import subprocess
import sys

from repro.core.autotune import default_candidates
from repro.configs import get_config


def test_candidate_sets():
    dense = default_candidates(get_config("qwen3-1.7b"))
    moe = default_candidates(get_config("mixtral-8x7b"))
    assert {c.name for c in dense} == {
        "planner-default", "force-spatial", "force-temporal", "split-qkv"
    }
    assert "moe-sort-dispatch" in {c.name for c in moe}


_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax
import repro.launch.mesh as mesh_mod
mesh_mod.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
    (2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
from repro.configs import TRAIN_4K, get_config
from repro.core.autotune import autotune
import repro.core.autotune as at
shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=8)
best, scored = autotune("qwen3-1.7b-reduced", shape)
print(json.dumps({
    "best": best.name if best else None,
    "n_ok": sum(1 for c in scored if c.step_s is not None),
    "steps": {c.name: c.step_s for c in scored if c.step_s is not None},
}))
"""


def test_autotune_small_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd=".",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["best"] is not None
    assert out["n_ok"] >= 3  # all dense candidates should compile
    assert out["steps"][out["best"]] == min(out["steps"].values())
