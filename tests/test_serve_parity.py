"""Differential trace replay: one seeded workload through EVERY engine
configuration axis, byte-compared to the eager oracle per request.

The serving stack now has four independently-toggleable mechanisms that all
promise "changes speed, never tokens": prefix sharing (refcounted blocks +
copy-on-write forks), speculative decoding (draft gamma), the rolled
on-device decode loop (K decode iterations per dispatch) and the KV page
dtype.  Hand-picked scenarios cover each mechanism alone; this harness
replays the SAME multi-tenant trace (``serve/workload.make_trace``, fixed
seed) through the full cross product and asserts every request's output is
byte-identical to ``greedy_generate`` — so any interaction bug between two
mechanisms (e.g. a rolled span crossing a forked block, or draft rollback
under int8 pages) fails loudly with the config tuple in the test id.

Conventions (docs/TESTING.md): extend AXES when a new engine mechanism
lands, rather than adding a one-off scenario file — the matrix is the
regression net.
"""
import itertools

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import assert_traces_bounded

from repro.configs import get_config
from repro.core.plan import derive_plan, derive_serve_plan
from repro.obs import (
    Observability,
    prometheus_roundtrip_ok,
    validate_chrome_trace,
)
from repro.serve import Request, ServingEngine, greedy_generate, make_trace
from repro.serve.speculative import NGramDraft

pytestmark = pytest.mark.slow

MESH1 = {"data": 1, "model": 1}
MIX = {"chat": 2, "classify": 2}
MAX_SEQ = 96

# the full configuration cross product: prefix sharing x draft gamma x
# rolled cap K x KV page dtype (16 engines, one trace, one oracle)
AXES = list(itertools.product(
    (False, True),      # prefix sharing
    (0, 2),             # speculation gamma
    (1, 4),             # rolled cap K
    ("int8", "bf16"),   # KV page dtype
))


@pytest.fixture(scope="module")
def world(key):
    """Model, plan, params, the seeded trace shape, and the per-request
    oracle — computed once for all 16 configurations."""
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=4, seq_len=16, training=False)
    from repro.models.params import init_params

    params = init_params(key, cfg, plan, dtype=jnp.float32)
    trace = make_trace(cfg, MIX, tenants=2, system_prompt_len=16,
                       stagger=1, seed=5, max_tokens=MAX_SEQ)
    oracle = {}
    for r in trace:
        out = greedy_generate(
            params, cfg, plan, {"tokens": jnp.asarray(r.prompt)[None]},
            n_steps=r.max_new_tokens, cache_len=len(r.prompt) + r.max_new_tokens,
            cache_dtype=jnp.float32,
        )
        oracle[r.rid] = [int(t) for t in np.asarray(out)[0]]
    return cfg, plan, params, oracle


def _fresh_trace(cfg):
    # the scheduler mutates Request state in place: fresh objects per
    # engine, same seed -> identical prompts/arrivals/budgets
    return make_trace(cfg, MIX, tenants=2, system_prompt_len=16,
                      stagger=1, seed=5, max_tokens=MAX_SEQ)


@pytest.mark.parametrize("sharing,gamma,rolled,kv", AXES,
                         ids=lambda v: str(v).lower())
def test_differential_trace_replay(world, sharing, gamma, rolled, kv):
    cfg, plan, params, oracle = world
    serve = derive_serve_plan(
        cfg, MESH1,
        max_seq_len=MAX_SEQ, decode_batch=3, block_size=8, kv_dtype=kv,
        prefill_chunk=8, prefix_sharing=sharing,
        draft="ngram" if gamma else "none", spec_len=gamma,
        rolled_steps=rolled,
    )
    # the observability axis piggybacks on the matrix: half the rows run
    # with the full bundle (lifecycle tracing on), half with the default —
    # byte parity and the trace contract must hold identically in both
    # modes, or the hooks leaked into the hot path
    obs = Observability(tracing=True) if sharing else None
    engine = ServingEngine(
        params, cfg, plan, serve, draft=NGramDraft() if gamma else None,
        obs=obs,
    )
    got = engine.run(_fresh_trace(cfg))
    for rid, want in oracle.items():
        assert got[rid] == want, (
            f"sharing={sharing} gamma={gamma} K={rolled} kv={kv}: "
            f"{rid} diverged: {got[rid]} != {want}"
        )
    assert_traces_bounded(engine.trace_counts)
    # each mechanism must actually have engaged, or the row proves nothing
    if sharing:
        assert engine.sched.n_prefix_hits > 0
    if gamma:
        assert engine.spec_len == gamma
        assert engine.trace_counts == {"step": 1}  # rolled gated off
    if rolled > 1 and gamma == 0:
        assert engine.rolled_cap == rolled
        assert engine.stats["rolled_dispatches"] >= 1
        assert engine.stats["rolled_steps"] >= engine.stats["rolled_dispatches"]
    if obs is not None:
        # the exported Chrome trace must validate (monotone timestamps)
        # and carry at least one complete request lifecycle
        events = validate_chrome_trace(obs.tracer.chrome_trace())
        assert any(
            e["name"] == "request" and e.get("ph") == "X" for e in events
        ), "obs-on row exported no complete request lifecycle"
        assert prometheus_roundtrip_ok(obs.metrics)
