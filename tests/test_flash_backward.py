"""Flash-attention custom_vjp (recomputation backward) vs jax autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.backward import flash_attention_grad
from repro.models.layers import blocked_attention

KEY = jax.random.PRNGKey(0)


def _mk(B, S, H, KH, D):
    q = jax.random.normal(KEY, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KH, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KH, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize(
    "case",
    [
        dict(B=2, S=64, H=4, KH=2, D=32, causal=True, window=0),
        dict(B=1, S=128, H=2, KH=1, D=16, causal=True, window=32),
        dict(B=1, S=64, H=3, KH=3, D=16, causal=False, window=0),
    ],
)
def test_custom_vjp_matches_autodiff(case):
    q, k, v = _mk(case["B"], case["S"], case["H"], case["KH"], case["D"])
    kw = dict(causal=case["causal"], window=case["window"])

    def loss_custom(q, k, v):
        return jnp.sum(jnp.square(flash_attention_grad(q, k, v, **kw)))

    def loss_auto(q, k, v):
        return jnp.sum(jnp.square(blocked_attention(q, k, v, q_chunk=32,
                                                    k_chunk=32, **kw)))

    g_custom = jax.grad(loss_custom, argnums=(0, 1, 2))(q, k, v)
    g_auto = jax.grad(loss_auto, argnums=(0, 1, 2))(q, k, v)
    for gc, ga, name in zip(g_custom, g_auto, "qkv"):
        np.testing.assert_allclose(
            np.asarray(gc), np.asarray(ga), rtol=2e-4, atol=2e-4,
            err_msg=f"d{name} mismatch",
        )


def test_forward_value_matches():
    q, k, v = _mk(2, 64, 4, 2, 32)
    a = np.asarray(flash_attention_grad(q, k, v, causal=True))
    b = np.asarray(blocked_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
