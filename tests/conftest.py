import jax
import jax.numpy as jnp
import pytest

# Smoke tests and benches see the real (single) device; ONLY the dry-run
# sets xla_force_host_platform_device_count (in its own process).

try:  # real hypothesis when installed (CI); deterministic stub otherwise
    import hypothesis  # noqa: F401
except ImportError:
    from _hypothesis_stub import install as _install_hypothesis_stub

    _install_hypothesis_stub()


def assert_traces_bounded(trace_counts: dict) -> None:
    """The serving engine's no-retrace contract: at most TWO compiled
    device programs in normal operation — the unified mixed step (exactly
    once) and, when rolling is enabled and engaged, the rolled decode loop
    (at most once).  Request churn, draft depth, horizon K and the chaos
    harness's NaN-poison vector are data, never shapes.  The one sanctioned
    extra compile is the degradation ladder's bottom rung: the eager gather
    fallback (``fallback_step``), built lazily and at most once, and only
    after transient faults exhausted the fused rungs."""
    assert set(trace_counts) <= {"step", "rolled_step", "fallback_step"}, (
        trace_counts
    )
    assert trace_counts["step"] == 1, trace_counts
    assert trace_counts.get("rolled_step", 0) <= 1, trace_counts
    assert trace_counts.get("fallback_step", 0) <= 1, trace_counts


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def mesh1():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh()


def make_batch(cfg, key, B=2, S=16, dtype=jnp.float32):
    """Standard smoke batch for any arch config."""
    batch = {}
    if cfg.vocab_size > 1:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["targets"] = jax.random.randint(
            jax.random.fold_in(key, 7), (B, S), 0, cfg.vocab_size
        )
    if cfg.frontend != "none" and cfg.n_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.n_prefix_embeds, cfg.d_model), dtype
        )
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), dtype
        )
    if cfg.n_classes:
        batch["label"] = jax.random.randint(key, (B,), 0, cfg.n_classes)
    return batch
