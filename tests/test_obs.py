"""Unified observability: metrics registry, lifecycle tracing, drift meter.

Three groups:

* **unit** — registry semantics (labels, histograms, Prometheus round-trip
  as an exact parse-of-exposition == flat-samples oracle), tracer ring
  buffer + Chrome trace_event structure, drift-meter arithmetic.  Pure
  host code, no jax.
* **engine integration** — one real serving run with the full bundle on:
  golden Chrome-trace validity (monotone timestamps, >= 1 complete request
  lifecycle nested under step spans), metric/summary back-compat
  agreement, finite calibration for both phases, and the disabled-mode
  no-op guarantee (tracing off leaves the ring empty).
* **launcher** — ``--replay-trace`` is the canonical replay spelling and
  ``--trace`` keeps working as a deprecation alias (both spellings, plus
  the conflict error).
"""
import json
import math

import jax.numpy as jnp
import pytest

from conftest import assert_traces_bounded

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.obs import (
    PID_ENGINE,
    Observability,
    Tracer,
    parse_prometheus_text,
    prometheus_roundtrip_ok,
    validate_chrome_trace,
)
from repro.obs.calibrate import DriftMeter, step_time_model
from repro.obs.metrics import MetricsRegistry
from repro.serve import ServingEngine
from repro.serve.scheduler import random_stream

MESH1 = {"data": 1, "model": 1}


# ---------------------------------------------------------------- metrics
def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests", ("tenant",))
    c.inc(tenant="a")
    c.inc(2, tenant="b")
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    g.set(3)
    h = reg.histogram("lat_ms", "latency", ("tenant",))
    h.observe(0.4, tenant="a")
    h.observe(12.0, tenant="a")
    h.observe(1e9, tenant="a")  # beyond the last bucket -> +Inf only
    snap = reg.snapshot()
    assert snap["reqs_total"]["type"] == "counter"
    assert snap["depth"]["samples"][0]["value"] == 3
    # exact round-trip: parse(exposition) == flat_samples
    assert prometheus_roundtrip_ok(reg)
    parsed = parse_prometheus_text(reg.to_prometheus())
    assert parsed[("reqs_total", (("tenant", "b"),))] == 2
    assert parsed[("lat_ms_count", (("tenant", "a"),))] == 3
    assert parsed[("lat_ms_bucket", (("le", "+Inf"), ("tenant", "a")))] == 3


def test_metrics_registry_rejects_mismatches():
    reg = MetricsRegistry()
    reg.counter("x_total", "x")
    with pytest.raises(ValueError):
        reg.gauge("x_total", "x")  # type mismatch on re-registration
    with pytest.raises(ValueError):
        reg.counter("x_total", "x", ("tenant",))  # label mismatch
    with pytest.raises(ValueError):
        reg.counter("bad name", "x")
    c = reg.counter("y_total", "y")
    with pytest.raises(ValueError):
        c.inc(-1)
    # get-or-create returns the same object
    assert reg.counter("x_total", "x") is reg.counter("x_total", "x")


# ---------------------------------------------------------------- tracer
def test_tracer_ring_buffer_and_chrome_structure():
    import time

    tr = Tracer(buffer=4, enabled=True)
    base = time.perf_counter()
    for i in range(10):
        tr.instant(f"e{i}", PID_ENGINE, 0, base + i * 1e-3)
    doc = tr.chrome_trace()
    events = validate_chrome_trace(doc)
    assert len(events) == 4  # ring kept the newest 4
    assert [e["name"] for e in events] == ["e6", "e7", "e8", "e9"]
    assert doc["otherData"]["dropped_events"] == 6
    ts = [e["ts"] for e in events]
    assert ts == sorted(ts)


def test_tracer_disabled_is_noop():
    tr = Tracer(enabled=False)
    tr.instant("x", PID_ENGINE, 0, 1.0)
    tr.complete("y", PID_ENGINE, 0, 1.0, 2.0)
    tr.request_span("z", "r0", 1.0, 2.0)
    assert len(validate_chrome_trace(tr.chrome_trace())) == 0


# ------------------------------------------------------------ drift meter
def test_drift_meter_report_arithmetic():
    dm = DriftMeter()
    assert dm.empty
    for _ in range(4):
        dm.record("decode", predicted_s=0.001, measured_s=0.002)
    dm.record("prefill", predicted_s=0.002, measured_s=0.001)
    rep = dm.report()
    assert rep["phases"]["decode"]["ratio"] == pytest.approx(2.0)
    assert rep["phases"]["prefill"]["ratio"] == pytest.approx(0.5)
    # aggregate over total time, not mean-of-ratios
    assert rep["overall_ratio"] == pytest.approx(9.0 / 6.0)
    assert "roofline" in rep["note"]


def test_step_time_model_scales_with_rows_and_k():
    cfg = get_config("smollm-135m").reduced()
    serve = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=64)
    m = step_time_model(cfg, serve, TPU_V5E)
    one = m.predict_s(1, 64)
    assert math.isfinite(one) and one > 0
    # k iterations pay k rooflines but ONE dispatch overhead
    k4 = m.predict_s(1, 64, k=4)
    assert k4 < 4 * one
    assert k4 > m.predict_s(1, 64, k=1)
    # more resident context -> more KV bytes -> no cheaper
    assert m.predict_s(1, 4096) >= one


# ---------------------------------------------------- engine integration
@pytest.fixture(scope="module")
def obs_run(key):
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=3, seq_len=16, training=False)
    from repro.models.params import init_params

    params = init_params(key, cfg, plan, dtype=jnp.float32)
    serve = derive_serve_plan(
        cfg, MESH1, max_seq_len=64, decode_batch=3, block_size=8,
        prefill_chunk=8, mixed_slab_width=8, rolled_steps=4,
    )
    stream = lambda: random_stream(cfg, 5, 8, 10, stagger=1, seed=11)
    obs = Observability(tracing=True)
    engine = ServingEngine(params, cfg, plan, serve, obs=obs)
    out_on = engine.run(stream())
    plain = ServingEngine(params, cfg, plan, serve)
    out_off = plain.run(stream())
    return engine, plain, obs, out_on, out_off


def test_obs_parity_and_trace_contract(obs_run):
    engine, plain, obs, out_on, out_off = obs_run
    assert out_on == out_off, "observability changed the engine's bytes"
    assert_traces_bounded(engine.trace_counts)
    assert engine.trace_counts == plain.trace_counts


def test_golden_chrome_trace(obs_run, tmp_path):
    engine, _, obs, out_on, _ = obs_run
    path = tmp_path / "trace.json"
    n = obs.tracer.write(str(path))
    doc = json.loads(path.read_text())
    events = validate_chrome_trace(doc)  # structure + monotone timestamps
    assert len(events) == n > 0
    names = {e["name"] for e in events}
    # >= one COMPLETE lifecycle: queued -> admitted -> first-token ->
    # finished, plus the whole-request span, nested under step spans
    for required in ("queued", "admitted", "first-token", "finished",
                     "request", "prefill-chunk"):
        assert required in names, f"missing {required!r} in {sorted(names)}"
    assert {"step", "rolled_step"} & names, "no dispatch spans exported"
    # every per-request event rides the requests track with its rid
    reqs = [e for e in events if e.get("pid") == 2]
    assert reqs and all("rid" in e.get("args", {}) for e in reqs)
    # lifecycle nests under the dispatch spans' wall-clock envelope
    steps = [e for e in events
             if e["name"] in ("step", "rolled_step") and e["ph"] == "X"]
    t_lo = min(e["ts"] for e in steps)
    t_hi = max(e["ts"] + e["dur"] for e in steps)
    fin = [e for e in events if e["name"] == "finished"]
    assert fin and all(t_lo <= e["ts"] <= t_hi + 1e6 for e in fin)


def test_metrics_agree_with_summary(obs_run):
    engine, _, obs, out_on, _ = obs_run
    s = engine.summary()
    m = obs.metrics.snapshot()

    def total(name):
        return sum(x["value"] for x in m[name]["samples"])

    assert total("serve_requests_submitted_total") == len(out_on)
    assert total("serve_requests_finished_total") == len(out_on)
    assert total("serve_tokens_total") >= s["generated_tokens"]
    # the steps counter counts DISPATCHES (a rolled span is one), while
    # stats["steps"] counts device iterations (a rolled span adds K)
    dispatches = (s["steps"] - engine.stats["rolled_steps"]
                  + engine.stats["rolled_dispatches"])
    assert total("serve_steps_total") == dispatches
    assert prometheus_roundtrip_ok(obs.metrics)


def test_calibration_finite_for_both_phases(obs_run):
    engine, _, obs, _, _ = obs_run
    cal = engine.summary()["calibration"]
    for phase in ("prefill", "decode"):
        rep = cal["phases"].get(phase)
        assert rep is not None, f"no {phase} dispatches calibrated"
        assert rep["n"] >= 1
        for k, v in rep.items():
            assert v is not None and math.isfinite(v), (phase, k, v)
    assert math.isfinite(cal["overall_ratio"]) and cal["overall_ratio"] > 0
    assert cal["note"]


def test_default_obs_keeps_tracing_off(obs_run):
    _, plain, _, _, _ = obs_run
    # the default bundle: metrics + drift on, tracer disabled and EMPTY
    assert plain.obs.tracer.enabled is False
    assert len(validate_chrome_trace(plain.obs.tracer.chrome_trace())) == 0
    assert not plain.obs.drift.empty  # drift still accumulated


def test_fault_events_carry_determinism_key(key):
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=16, training=False)
    from repro.models.params import init_params
    from repro.serve import FaultInjector

    params = init_params(key, cfg, plan, dtype=jnp.float32)
    serve = derive_serve_plan(
        cfg, MESH1, max_seq_len=64, decode_batch=2, prefill_chunk=8,
        mixed_slab_width=8,
    )
    obs = Observability(tracing=True)
    inj = FaultInjector(3, transient_rate=0.2, nan_rate=0.1, horizon=30)
    engine = ServingEngine(params, cfg, plan, serve, injector=inj, obs=obs)
    engine.run(random_stream(cfg, 3, 8, 8, stagger=1, seed=2))
    events = validate_chrome_trace(obs.tracer.chrome_trace())
    faults = [e for e in events if e["name"].startswith("fault:")]
    assert faults, "chaos run traced no fault events"
    for e in faults:
        assert e["args"]["seed"] == 3
        assert e["args"]["salt"] in (1, 2, 3, 4)
        assert e["args"]["iteration"] >= 0
    kinds = {e["name"].split(":", 1)[1] for e in faults}
    assert kinds <= {"transient", "nan", "pressure", "spike"}
    if inj.counts["transient"]:
        assert "transient" in kinds
    if engine.stats["injected_nans"]:
        assert "nan" in kinds


# ------------------------------------------------------------- launcher
def test_replay_trace_flag_spellings():
    from repro.launch.serve import ServeArgs, build_parser

    ns = build_parser().parse_args(["--arch", "x", "--replay-trace", "chat:2"])
    a = ServeArgs.from_namespace(ns)
    assert a.replay_trace == a.trace == "chat:2"
    # deprecated spelling still lands in BOTH fields
    ns2 = build_parser().parse_args(["--arch", "x", "--trace", "chat:3"])
    a2 = ServeArgs.from_namespace(ns2)
    assert a2.replay_trace == a2.trace == "chat:3"
    with pytest.raises(ValueError):
        ServeArgs(arch="x", trace="a:1", replay_trace="b:1")
    a3 = ServeArgs(arch="x")
    assert a3.trace is None and a3.replay_trace is None
    assert a3.make_observability().tracer.enabled is False
    assert ServeArgs(arch="x", trace_out="t.json").make_observability(
    ).tracer.enabled is True
