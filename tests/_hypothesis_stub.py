"""Minimal property-testing fallback when ``hypothesis`` is not installed.

CI pins the real library (requirements.txt); this stub keeps the suite
collectable and meaningful in hermetic environments where new packages
cannot be installed.  It implements exactly the surface the tests use —
``given``, ``settings``, ``strategies.sampled_from``, ``strategies.integers``
— by running each test body ``max_examples`` times over deterministic
pseudo-random draws (fixed seed: reproducible, no flaky CI).

Activated by ``conftest.py`` only when ``import hypothesis`` fails.
"""
from __future__ import annotations

import random
import sys
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng: rng.choice(options))


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


class settings:
    def __init__(self, max_examples: int = 20, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(**strategies):
    def deco(fn):
        def runner(*args, **kwargs):
            cfg = getattr(runner, "_stub_settings", None) or getattr(
                fn, "_stub_settings", None
            )
            n = cfg.max_examples if cfg else 20
            rng = random.Random(0)
            for _ in range(n):
                drawn = {k: s.example_from(rng) for k, s in strategies.items()}
                fn(*args, **kwargs, **drawn)

        # No functools.wraps: pytest follows __wrapped__ to the original
        # signature and would treat the strategy kwargs as fixtures.
        runner.__name__ = getattr(fn, "__name__", "given_test")
        runner.__qualname__ = getattr(fn, "__qualname__", runner.__name__)
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner

    return deco


def install() -> None:
    """Register stub ``hypothesis`` / ``hypothesis.strategies`` modules."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__stub__ = True
    st = types.ModuleType("hypothesis.strategies")
    st.sampled_from = sampled_from
    st.integers = integers
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
