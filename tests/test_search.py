"""Family-search invariants: feasibility, non-domination, determinism, and
the single-candidate degeneration to the stock planner."""
import pytest

from repro.configs import get_config
from repro.core.hardware import get_hardware
from repro.core.plan import derive_serve_plan, serve_feasible
from repro.core.search import (
    DesignPoint,
    SearchSpace,
    dominates,
    expected_accepted,
    family_report,
    pareto_frontier,
    search_family,
)


@pytest.fixture(scope="module")
def v5e_family():
    return search_family("qwen3-1.7b", get_hardware("tpu_v5e"))


# ---------------------------------------------------------------- frontier
def test_every_frontier_point_is_feasible(v5e_family):
    ok, reason = serve_feasible(get_config("qwen3-1.7b"))
    assert ok, reason
    assert v5e_family.frontier
    for p in v5e_family.frontier:
        assert p.feasible, p.reason
        assert p.tokens_per_s > 0
        assert p.step_s > 0


def test_no_dominated_point_on_frontier(v5e_family):
    for p in v5e_family.frontier:
        assert not any(
            dominates(q, p) for q in v5e_family.frontier if q is not p
        )


def test_frontier_meets_acceptance_floor(v5e_family):
    # the --family acceptance bar: >= 3 non-dominated points on tpu_v5e
    assert len(v5e_family.frontier) >= 3


def test_search_is_deterministic(v5e_family):
    again = search_family("qwen3-1.7b", get_hardware("tpu_v5e"))
    assert [p.to_record() for p in v5e_family.points] == [
        p.to_record() for p in again.points
    ]
    assert [p.to_record() for p in v5e_family.frontier] == [
        p.to_record() for p in again.frontier
    ]


def test_vck5000_search_nonempty_and_single_chip():
    result = search_family("qwen3-1.7b", get_hardware("vck5000"))
    assert result.frontier
    # no interconnect => the mesh axis never leaves model=1
    assert all(p.mesh["model"] == 1 for p in result.points)


# ------------------------------------------------------------- degeneration
def test_single_candidate_space_degenerates_to_planner():
    """A space of all-None singletons must reproduce exactly the plan
    ``derive_serve_plan`` derives today — search adds options, never drift."""
    cfg = get_config("qwen3-1.7b")
    hw = get_hardware("tpu_v5e")
    space = SearchSpace(spec_lens=(None,))
    result = search_family(cfg, hw, space)
    assert len(result.points) == 1
    stock = derive_serve_plan(
        cfg, {"data": 1, "model": 1}, hw, max_seq_len=space.max_seq_len,
        draft=space.draft,
    )
    assert result.points[0].plan == stock
    assert result.frontier[0].plan == stock


# ------------------------------------------------------------------- units
def _pt(tok, usd, mj):
    return DesignPoint(
        hardware="h", arch="a", mesh={"data": 1, "model": 1}, plan=None,
        tile="", tokens_per_s=tok, usd_per_mtok=usd, mj_per_tok=mj,
        step_s=1.0, tokens_per_step=1.0, bound="memory", feasible=True,
    )


def test_dominates_semantics():
    a, b = _pt(10, 1.0, 1.0), _pt(5, 2.0, 2.0)
    assert dominates(a, b) and not dominates(b, a)
    # equal on all axes: neither dominates
    c = _pt(10, 1.0, 1.0)
    assert not dominates(a, c) and not dominates(c, a)
    # trade: faster but pricier — incomparable
    d = _pt(20, 3.0, 1.0)
    assert not dominates(a, d) and not dominates(d, a)


def test_pareto_frontier_filters_and_dedupes():
    pts = [_pt(5, 2.0, 2.0), _pt(10, 1.0, 1.0), _pt(10, 1.0, 1.0),
           _pt(20, 3.0, 1.0)]
    pts.append(_pt(1, 9.0, 9.0))
    pts[-1].feasible = False  # infeasible points never reach the frontier
    f = pareto_frontier(pts)
    assert [p.tokens_per_s for p in f] == [20, 10]  # sorted desc, deduped


def test_expected_accepted():
    assert expected_accepted(0, 0.6) == 1.0
    assert expected_accepted(4, 1.0) == 5.0
    # geometric series: 1 + a + ... + a^gamma
    assert expected_accepted(2, 0.5) == pytest.approx(1.75)


# ------------------------------------------------------------------ report
def test_family_report_record_and_markdown(tmp_path):
    result, record = family_report(
        "qwen3-1.7b", "tpu_v5e", out_dir=tmp_path
    )
    assert record["n_feasible"] >= len(record["frontier"]) >= 3
    md = record["markdown"]
    assert "| tok/s | $/Mtok | mJ/tok |" in md
    assert (tmp_path / "tpu_v5e__qwen3-1.7b.json").exists()
    # every frontier record carries a runnable plan + resolved tile
    for rec in record["frontier"]:
        assert rec["plan"]["decode_batch"] >= 1
        assert rec["tile"]
