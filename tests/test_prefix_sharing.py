"""Copy-on-write prefix sharing: radix index, refcounted blocks, and the
byte-parity anchor — greedy outputs identical with sharing on vs off across
staggered arrivals, eviction, int8 pages, forks and speculation."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_traces_bounded

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.serve import Request, ServingEngine, greedy_generate, make_draft_source
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import Scheduler

MESH1 = {"data": 1, "model": 1}


def _setup(key, arch="smollm-135m", **serve_kw):
    cfg = get_config(arch).reduced()
    plan = derive_plan(cfg, MESH1, batch=4, seq_len=16, training=False)
    serve_kw.setdefault("max_seq_len", 64)
    serve_kw.setdefault("decode_batch", 4)
    serve_kw.setdefault("block_size", 8)
    serve_kw.setdefault("kv_dtype", "fp32")
    serve_kw.setdefault("prefill_chunk", 8)
    serve = derive_serve_plan(cfg, MESH1, **serve_kw)
    from repro.models.params import init_params

    params = init_params(key, cfg, plan, dtype=jnp.float32)
    return cfg, plan, serve, params


def _oracle(params, cfg, plan, prompt, gen):
    out = greedy_generate(
        params, cfg, plan, {"tokens": jnp.asarray(prompt)[None]},
        n_steps=gen, cache_len=len(prompt) + gen, cache_dtype=jnp.float32,
    )
    return list(np.asarray(out)[0])


def _ab(params, cfg, plan, serve, make_reqs, **engine_kw):
    """Run the same stream with sharing on and off; returns both engines'
    (outputs, engine) pairs.  Fresh Request objects per run — the scheduler
    mutates them."""
    runs = {}
    for sharing in (True, False):
        s = dataclasses.replace(serve, prefix_sharing=sharing)
        eng = ServingEngine(params, cfg, plan, s, **engine_kw)
        runs[sharing] = (eng.run(make_reqs()), eng)
    return runs


# ------------------------------------------------------------- radix index
def test_prefix_index_full_partial_and_cap():
    ix = PrefixIndex(4)
    ix.register(list(range(12)), [5, 6, 7])
    assert len(ix) == 3
    # exact full-block prefix, capped at len-1: a fully resident prompt
    # still leaves its last token to prefill
    full, partial, n = ix.match(list(range(12)))
    assert full == [5, 6] and partial == (7, 3) and n == 11
    # block-aligned shorter prompt
    full, partial, n = ix.match(list(range(8)) + [99])
    assert full == [5, 6] and partial is None and n == 8
    # mid-block divergence: partial head of the next resident block
    full, partial, n = ix.match([0, 1, 2, 3, 4, 5, 99, 98, 97])
    assert full == [5] and partial == (6, 2) and n == 6
    # no match at all
    assert ix.match([99, 98, 97, 96, 95]) == ([], None, 0)
    # too short to share anything (cap = len-1 < block)
    assert ix.match([0, 1])[2] <= 1


def test_prefix_index_register_dedup_and_forget():
    ix = PrefixIndex(4)
    assert ix.register(list(range(8)), [3, 4]) == 2
    # same content in different physical blocks: first resident copy wins
    assert ix.register(list(range(8)), [8, 9]) == 0
    assert ix.match(list(range(8)) + [0])[0] == [3, 4]
    # forgetting an interior block drops its subtree too
    ix.register(list(range(12)), [3, 4, 5])
    ix.forget(4)
    full, partial, n = ix.match(list(range(12)))
    assert full == [3] and partial is None and n == 4
    ix.forget(4)  # idempotent
    ix.forget(77)  # never-indexed blocks tolerated


def test_scheduler_shares_blocks_and_skips_prefill():
    """Host-side: the second request on a registered prefix holds the same
    physical blocks (refcount 2) and prefills only its tail."""
    cfg = get_config("smollm-135m").reduced()
    serve = derive_serve_plan(
        cfg, MESH1, max_seq_len=32, decode_batch=2, block_size=4,
        kv_dtype="fp32", prefill_chunk=4,
    )
    s = Scheduler(serve)
    base = list(range(2, 10))  # two full blocks
    r0 = Request(rid="a", prompt=base + [40, 41], max_new_tokens=4)
    s.submit(r0)
    s.admit(0)
    while r0.state == "prefill":
        _, _, _, kinds = s._slab_view(serve.mixed_slab_width)
        s._slab_done(np.full((2,), 7, np.int64), kinds)
    assert r0.registered == 2  # base blocks indexed once resident
    r1 = Request(rid="b", prompt=base + [50, 51], max_new_tokens=4, arrival=0)
    s.submit(r1)
    s.admit(1)
    assert r1.blocks[:2] == r0.blocks[:2]  # same physical blocks
    assert all(s.alloc.refcount(b) == 2 for b in r0.blocks[:2])
    assert r1.pos == 8 and r1.shared == 2  # only the tail left to prefill
    assert s.n_prefix_hits == 1 and s.prefix_tokens_saved == 8
    # finishing r0 must NOT release the shared blocks under r1
    s.evict(r0)
    assert all(s.alloc.refcount(b) == 1 for b in r1.blocks[:2])
    assert s.index is not None and len(s.index) >= 2


# ------------------------------------------------- engine byte-parity suite
def test_shared_system_prompt_staggered_parity(key):
    """N staggered requests on one system prompt: byte-identical outputs
    with sharing on vs off, against the eager oracle, with prefill tokens
    and peak pool blocks strictly reduced."""
    cfg, plan, serve, params = _setup(key)
    rng = np.random.default_rng(0)
    sysp = [int(t) for t in rng.integers(0, cfg.vocab_size, 19)]
    tails = [list(map(int, rng.integers(0, cfg.vocab_size, n))) for n in (5, 9, 3, 7)]

    def reqs():
        return [
            Request(rid=f"r{i}", prompt=sysp + t, max_new_tokens=6, arrival=2 * i)
            for i, t in enumerate(tails)
        ]

    runs = _ab(params, cfg, plan, serve, reqs)
    (on, eng_on), (off, eng_off) = runs[True], runs[False]
    assert on == off
    for i, t in enumerate(tails):
        assert on[f"r{i}"] == _oracle(params, cfg, plan, sysp + t, 6)
    assert_traces_bounded(eng_on.trace_counts)
    p = eng_on.summary()["prefix"]
    assert p["hits"] >= 3 and p["tokens_saved"] > 0
    assert eng_on.stats["prefill_tokens"] < eng_off.stats["prefill_tokens"]
    assert p["peak_blocks"] < eng_off.summary()["prefix"]["peak_blocks"]


def test_fork_on_write_non_block_aligned_divergence(key):
    """A prompt diverging *inside* a resident block forks it (device page
    copy) and still matches the oracle byte-for-byte."""
    cfg, plan, serve, params = _setup(key)
    rng = np.random.default_rng(1)
    p0 = [int(t) for t in rng.integers(0, cfg.vocab_size, 12)]
    # diverges at token 10 — two tokens into p0's second block (block 8)
    p1 = p0[:10] + [int(t) + 1 if int(t) + 1 < cfg.vocab_size else 0
                    for t in p0[10:12]] + [3, 5]

    def reqs():
        return [
            Request(rid="own", prompt=p0, max_new_tokens=8, arrival=0),
            Request(rid="div", prompt=p1, max_new_tokens=8, arrival=8),
        ]

    runs = _ab(params, cfg, plan, serve, reqs)
    (on, eng_on), (off, _) = runs[True], runs[False]
    assert on == off
    assert on["own"] == _oracle(params, cfg, plan, p0, 8)
    assert on["div"] == _oracle(params, cfg, plan, p1, 8)
    p = eng_on.summary()["prefix"]
    assert p["forks"] >= 1 and p["fork_copies"] >= 1
    assert_traces_bounded(eng_on.trace_counts)


def test_shared_prefix_eviction_while_sharer_decodes(key):
    """Pool pressure evicts one sharer mid-stream; the survivor keeps
    reading the shared pages (eviction must not release them) and both
    finish oracle-exact."""
    cfg, plan, serve, params = _setup(
        key, decode_batch=2, block_size=2, prefill_chunk=4, max_seq_len=16
    )
    serve = dataclasses.replace(serve, n_blocks=1 + 9)
    rng = np.random.default_rng(2)
    base = [int(t) for t in rng.integers(0, cfg.vocab_size, 4)]
    p0 = base + [int(t) for t in rng.integers(0, cfg.vocab_size, 2)]
    p1 = base + [int(t) for t in rng.integers(0, cfg.vocab_size, 2)]

    def reqs():
        return [
            Request(rid="e0", prompt=p0, max_new_tokens=8, arrival=0),
            Request(rid="e1", prompt=p1, max_new_tokens=8, arrival=3),
        ]

    runs = _ab(params, cfg, plan, serve, reqs)
    (on, eng_on), (off, _) = runs[True], runs[False]
    assert eng_on.sched.n_evictions >= 1
    assert on == off
    assert on["e0"] == _oracle(params, cfg, plan, p0, 8)
    assert on["e1"] == _oracle(params, cfg, plan, p1, 8)
    # everything returned to the pool at the end (no leaked refcounts)
    assert eng_on.sched.alloc.available == 9
    assert len(eng_on.sched.index) == 0


def test_int8_pages_shared_then_forked(key):
    """int8 pool: sharing quantized pages (and forking them, scales
    included) is byte-deterministic — same tokens as the unshared int8
    engine."""
    cfg, plan, serve, params = _setup(key, kv_dtype="int8")
    rng = np.random.default_rng(3)
    p0 = [int(t) for t in rng.integers(0, cfg.vocab_size, 12)]
    p1 = p0[:10] + [(int(p0[10]) + 1) % cfg.vocab_size, 7, 2, 4]
    p2 = p0[:8] + [int(t) for t in rng.integers(0, cfg.vocab_size, 4)]

    def reqs():
        # i0 must still be resident (blocks registered, not yet released)
        # when i1/i2 arrive: its block 1 fills at written length 16 =
        # 12 prompt + 4 outputs, around iteration 5
        return [
            Request(rid="i0", prompt=p0, max_new_tokens=10, arrival=0),
            Request(rid="i1", prompt=p1, max_new_tokens=6, arrival=7),
            Request(rid="i2", prompt=p2, max_new_tokens=6, arrival=8),
        ]

    runs = _ab(params, cfg, plan, serve, reqs)
    (on, eng_on), (off, _) = runs[True], runs[False]
    assert on == off
    p = eng_on.summary()["prefix"]
    assert p["hits"] >= 2 and p["forks"] >= 1


def test_speculative_decode_over_shared_prefix_parity(key):
    """gamma > 0 (prompt-lookup drafting) over a shared prefix: outputs
    stay byte-identical to both the unshared speculative engine and the
    plain (no-draft) engine."""
    cfg, plan, serve, params = _setup(
        key, mixed_slab_width=8, spec_len=3, draft="ngram"
    )
    assert serve.spec_len == 3
    rng = np.random.default_rng(4)
    sysp = [int(t) for t in rng.integers(0, cfg.vocab_size, 17)]
    tails = [list(map(int, rng.integers(0, cfg.vocab_size, n))) for n in (4, 6, 9)]

    def reqs():
        # accepted drafts finish requests in few iterations: arrivals stay
        # tight so the prefix owner is still resident when sharers land
        return [
            Request(rid=f"g{i}", prompt=sysp + t, max_new_tokens=9, arrival=2 * i)
            for i, t in enumerate(tails)
        ]

    draft = lambda: make_draft_source("ngram", cfg, serve, hw=TPU_V5E)
    runs = _ab(params, cfg, plan, serve, reqs, draft=draft())
    (on, eng_on), (off, _) = runs[True], runs[False]
    assert on == off
    plain = ServingEngine(
        params, cfg, plan, dataclasses.replace(serve, spec_len=0, draft="none")
    )
    assert plain.run(reqs()) == on
    assert eng_on.summary()["prefix"]["hits"] >= 2
    assert_traces_bounded(eng_on.trace_counts)


def test_plan_prefix_sharing_flag_reaches_engine(key):
    cfg, plan, serve, params = _setup(key)
    assert serve.prefix_sharing  # derived plans default to sharing on
    off = dataclasses.replace(serve, prefix_sharing=False)
    assert ServingEngine(params, cfg, plan, off).sched.index is None
    assert "prefix_sharing" in serve.to_record()
    with pytest.raises(dataclasses.FrozenInstanceError):
        serve.prefix_sharing = False
