"""Chunked CE vs direct CE; compression error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.models.transformer import chunked_softmax_xent
from repro.train.compression import CompressionConfig, compress_grads, init_residual

KEY = jax.random.PRNGKey(0)


def _direct_ce(x, w, t):
    logits = (x @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tl = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
    return (lse - tl).sum()


@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([8, 16, 32]),
    V=st.sampled_from([50, 128]),
)
@settings(max_examples=12, deadline=None)
def test_chunked_ce_equals_direct(B, S, V):
    d = 16
    x = jax.random.normal(KEY, (B, S, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, V), jnp.float32)
    t = jax.random.randint(jax.random.fold_in(KEY, 2), (B, S), 0, V)
    total, n = chunked_softmax_xent(x, w, t, chunk=8)
    np.testing.assert_allclose(float(total), float(_direct_ce(x, w, t)), rtol=1e-5)
    assert float(n) == B * S


def test_chunked_ce_respects_mask():
    B, S, d, V = 2, 16, 8, 32
    x = jax.random.normal(KEY, (B, S, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, V), jnp.float32)
    t = jax.random.randint(jax.random.fold_in(KEY, 2), (B, S), 0, V)
    mask = jnp.zeros((B, S)).at[:, :4].set(1.0)
    total, n = chunked_softmax_xent(x, w, t, loss_mask=mask, chunk=8)
    direct = _direct_ce(x[:, :4], w, t[:, :4])
    np.testing.assert_allclose(float(total), float(direct), rtol=1e-5)
    assert float(n) == 8


def test_error_feedback_unbiased_over_steps():
    """Sum of compressed grads + final residual == sum of true grads."""
    g = {"w": jax.random.normal(KEY, (64, 64), jnp.float32)}
    cc = CompressionConfig(mode="int8")
    res = init_residual(g)
    sent_total = jnp.zeros((64, 64))
    true_total = jnp.zeros((64, 64))
    for i in range(5):
        gi = {"w": g["w"] * (i + 1) * 0.1}
        sent, res = compress_grads(gi, res, cc)
        sent_total += sent["w"]
        true_total += gi["w"]
    gap = np.abs(np.asarray(sent_total + res["w"] - true_total)).max()
    assert gap < 1e-4


def test_bf16_compression_close():
    g = {"w": jax.random.normal(KEY, (32, 32), jnp.float32)}
    cc = CompressionConfig(mode="bf16")
    sent, res = compress_grads(g, init_residual(g), cc)
    rel = np.abs(np.asarray(sent["w"] - g["w"])).max() / np.abs(np.asarray(g["w"])).max()
    assert rel < 0.01
