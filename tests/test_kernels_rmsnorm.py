"""Fused RMSNorm kernel vs oracle + model-path agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.models.layers import rmsnorm as model_rmsnorm

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("shape", [(64, 128), (2, 100, 64), (7, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, jnp.float32).astype(dtype)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[-1],), jnp.float32) * 0.1
    got = np.asarray(rmsnorm(x, s), np.float32)
    want = np.asarray(rmsnorm_ref(x, s), np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_fused_residual():
    x = jax.random.normal(KEY, (32, 64), jnp.float32)
    r = jax.random.normal(jax.random.fold_in(KEY, 2), (32, 64), jnp.float32)
    s = jnp.zeros((64,))
    got = np.asarray(rmsnorm(x, s, residual=r))
    want = np.asarray(rmsnorm_ref(x, s, residual=r))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_agrees_with_model_path():
    """kernel == the jnp norm the models/dry-run use."""
    x = jax.random.normal(KEY, (2, 16, 64), jnp.float32)
    s = jax.random.normal(jax.random.fold_in(KEY, 3), (64,), jnp.float32) * 0.1
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, s)), np.asarray(model_rmsnorm(x, s)),
        rtol=1e-5, atol=1e-6,
    )
