"""Megatron-SP (plan.seq_parallel_acts): numerics vs the replicated
baseline, and the HLO guarantee — the sequence-parallel layernorm path
lowers with zero all-gather ops (subprocess: 4 fake devices)."""
import json
import subprocess
import sys

from repro.configs import get_config
from repro.core.plan import derive_plan


def test_seq_parallel_gating():
    cfg = get_config("bert-base-reduced")
    mesh = {"data": 2, "model": 2}
    on = derive_plan(
        cfg, mesh, batch=8, seq_len=32, training=True,
        seq_parallel=True, force_mode="spatial",
    )
    assert on.seq_parallel_acts
    assert not on.fuse_qkv  # the manual ring needs per-projection shards
    # opt-in: nothing changes without the flag
    off = derive_plan(cfg, mesh, batch=8, seq_len=32, training=True)
    assert not off.seq_parallel_acts
    # infeasible (kv heads % model axis != 0 on the reduced GQA config)
    gqa = derive_plan(
        get_config("smollm-135m-reduced"), mesh, batch=8, seq_len=32,
        training=True, seq_parallel=True, force_mode="spatial",
    )
    assert not gqa.seq_parallel_acts


_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.plan import derive_plan
from repro.models.params import init_params
from repro.models import transformer as T

cfg = get_config("bert-base-reduced")
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
plan = derive_plan(cfg, dict(mesh.shape), batch=4, seq_len=16, training=True,
                   seq_parallel=True, force_mode="spatial")
assert plan.seq_parallel_acts
params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
key = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
         "targets": jax.random.randint(jax.random.fold_in(key, 1), (4, 16),
                                       0, cfg.vocab_size)}

# forward numerics: same params, SP stack vs replicated GSPMD stack
plan_base = dataclasses.replace(plan, seq_parallel_acts=False)
x_base, _, _ = T.forward(params, batch, cfg=cfg, plan=plan_base)
x_sp = jax.jit(lambda p, b: T.forward(p, b, cfg=cfg, plan=plan, mesh=mesh)[0])(
    params, batch)
fwd_err = float(jnp.max(jnp.abs(x_sp - x_base)))

# HLO: the SP layer stack (the layernorm path) contains no all-gather
pos = jnp.arange(16)[None, :]
stack_fn = jax.jit(lambda s, x: T.sp_stack_forward(
    s, x, cfg=cfg, plan=plan, mesh=mesh, positions=pos))
xh = jax.random.normal(key, (4, 16, cfg.d_model), jnp.float32)
hlo = stack_fn.lower(params["blocks"]["stack"], xh).compile().as_text()
n_ag = sum(1 for l in hlo.splitlines()
           if " all-gather(" in l or " all-gather-start(" in l)
n_perm = hlo.count("collective-permute")

# gradients flow through the manual collectives
g_sp = jax.grad(lambda p: T.lm_loss(p, batch, cfg=cfg, plan=plan, mesh=mesh))(params)
g_b = jax.grad(lambda p: T.lm_loss(p, batch, cfg=cfg, plan=plan_base))(params)
grad_err = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(g_sp), jax.tree.leaves(g_b)))
print(json.dumps({"fwd_err": fwd_err, "n_ag": n_ag, "n_perm": n_perm,
                  "grad_err": grad_err}))
"""


def test_seq_parallel_numerics_and_hlo_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd=".",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["fwd_err"] < 1e-4, f"SP forward diverges: {out}"
    assert out["n_ag"] == 0, f"all-gather on the SP layernorm path: {out}"
    assert out["n_perm"] >= 1, f"ring schedule missing: {out}"
    assert out["grad_err"] < 1e-5, f"SP gradients diverge: {out}"
