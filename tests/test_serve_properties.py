"""Property-based fuzz of the host-side serving state machine.

Six PRs of scheduler features (refcounted blocks, radix prefix sharing,
copy-on-write forks, eviction, rolled spans) share a handful of conserved
invariants.  The hand-picked scenario tests exercise each feature's happy
path; this file drives *randomized* admit/prefill/decode/rolled/evict/
finish sequences against the real :class:`Scheduler` (pure numpy — no
device, no model) and asserts every invariant after every operation:

* **conservation** — free + resident blocks always partition the pool;
* **refcount exactness** — each block's refcount equals the number of live
  requests holding it (so no block is reachable from two block tables
  without refcount > 1, and the free list is exactly the refcount-0 set);
* **index liveness** — every radix-indexed block is owned by some live
  request (``forget`` leaves no dangling node or subtree) and the trie's
  parent/child links stay bidirectionally consistent;
* **table mirroring** — each slot's device-visible block-table row is its
  request's block list (then trash), and every pre-reserved rolled span
  is fully covered before dispatch.

The chaos variant layers the fault machinery on the same churn: a
:class:`FaultInjector` squeezing the free list, zero-deadline expiry,
random cancels and admission shedding — the invariants must hold with the
injector holding blocks, and every request must end finished or shed.

Strategies come from ``hypothesis`` when installed (CI) or the
deterministic stub in ``_hypothesis_stub.py`` otherwise; either way the
sequence is derived from drawn integer seeds, so failures reproduce.
"""
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core.plan import derive_serve_plan
from repro.serve.faults import FaultInjector
from repro.serve.scheduler import PREFILL, RUNNING, Request, Scheduler

pytestmark = pytest.mark.slow

MESH1 = {"data": 1, "model": 1}


def _serve_plan(n_blocks=None, decode_batch=3, block_size=4):
    cfg = get_config("smollm-135m").reduced()
    sp = derive_serve_plan(
        cfg, MESH1, max_seq_len=32, decode_batch=decode_batch,
        block_size=block_size, kv_dtype="fp32", prefill_chunk=8,
    )
    if n_blocks is not None:
        import dataclasses

        sp = dataclasses.replace(sp, n_blocks=n_blocks)
    return sp


def _check_invariants(s: Scheduler, held=()) -> None:
    alloc, serve = s.alloc, s.serve
    # conservation: free + resident == allocatable pool
    assert alloc.available + alloc.in_use == serve.n_blocks - 1
    # refcount exactness vs the live holders (slot owners are the only
    # block-holding requests; waiting/finished/evicted hold none —
    # plus whatever blocks a chaos injector is squeezing out of the pool)
    holders: dict[int, int] = {}
    for b in held:
        holders[b] = holders.get(b, 0) + 1
    for r in s.slots:
        if r is None:
            continue
        assert len(set(r.blocks)) == len(r.blocks), f"{r.rid} duplicate block"
        for b in r.blocks:
            holders[b] = holders.get(b, 0) + 1
    for b in range(1, serve.n_blocks):
        assert alloc.refcount(b) == holders.get(b, 0), (
            f"block {b}: refcount {alloc.refcount(b)} != "
            f"{holders.get(b, 0)} holders"
        )
        assert (alloc.refcount(b) == 0) == (b in alloc._free)
    assert alloc.double_frees == 0
    # block tables mirror the block lists exactly (trash elsewhere)
    for r in s.slots:
        if r is None:
            continue
        row = s.table[r.slot]
        assert list(row[: len(r.blocks)]) == r.blocks
        assert not row[len(r.blocks):].any()
    # radix index: every node's block is live, links are consistent
    if s.index is not None:
        for b, node in s.index._by_block.items():
            assert node.block == b
            assert alloc.refcount(b) >= 1, f"indexed block {b} is free"
            assert node.parent is not None
            assert node.parent.children.get(node.key) is node
        # no dangling subtree: everything reachable from the root is in
        # _by_block, and nothing else (forget() removed whole subtrees)
        reachable = set()
        stack = list(s.index._root.children.values())
        while stack:
            n = stack.pop()
            reachable.add(n.block)
            stack.extend(n.children.values())
        assert reachable == set(s.index._by_block)
    # pending fork copies read from still-resident sources
    for src, _dst in s.pending_copies:
        assert alloc.refcount(src) >= 1


def _random_request(rng, i: int, t: int) -> Request:
    # small token alphabet -> frequent prefix collisions (shares + forks)
    n = int(rng.integers(1, 17))
    return Request(
        rid=f"r{i:04d}",
        prompt=[int(x) for x in rng.integers(0, 6, n)],
        max_new_tokens=int(rng.integers(1, 7)),
        arrival=t,
        priority=int(rng.integers(0, 3)),
    )


def _host_step(s: Scheduler, rng) -> None:
    """One engine iteration minus the device: the K=1 slab path with
    fabricated sampled tokens (content never matters to the invariants)."""
    if not s.busy():
        return
    W = s.serve.mixed_slab_width
    _tokens, _tables, _lens, kinds = s._slab_view(W)
    sampled = rng.integers(0, 6, s.serve.decode_batch).astype(np.int32)
    s._slab_done(sampled, kinds)


def _rolled_span(s: Scheduler, rng, t: int) -> int:
    """The rolled path: horizon + pre-reservation, then the span's
    bookkeeping with fabricated device output.  Returns iterations used."""
    cap = int(rng.integers(2, 9))
    k, steps = s.plan_rolled(t, cap)
    if k <= 1:
        return 0
    # pre-reservation invariant: every runner's table already covers its span
    for r in s.running():
        need = -(-(int(s.lens[r.slot]) + int(steps[r.slot])) // s.serve.block_size)
        assert len(r.blocks) >= need, (r.rid, len(r.blocks), need)
    out = rng.integers(0, 6, (s.serve.decode_batch, k)).astype(np.int32)
    s._rolled_done(out, steps)
    return int(steps.max())


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_scheduler_invariants_under_random_churn(seed):
    """Randomized admit/prefill/decode/rolled/evict/finish sequences keep
    every conserved invariant, with prefix sharing on and a pool small
    enough that eviction and admission-blocking actually occur."""
    rng = np.random.default_rng(seed)
    s = Scheduler(_serve_plan(n_blocks=1 + 14))
    t, n_submitted = 0, 0
    for _ in range(60):
        op = rng.random()
        if op < 0.35 and n_submitted < 24:
            s.submit(_random_request(rng, n_submitted, t))
            n_submitted += 1
        s.admit(t)
        s.drain_copies()  # engine applies the page copies here
        _check_invariants(s)
        if op < 0.08:  # adversarial preemption of a random holder
            active = s._active()
            if active:
                s.evict(active[int(rng.integers(len(active)))])
                _check_invariants(s)
        if op > 0.75:
            adv = _rolled_span(s, rng, t)
            if adv:
                t += adv
                _check_invariants(s)
                continue
        s._grow_for_decode()
        _check_invariants(s)
        _host_step(s, rng)
        _check_invariants(s)
        t += 1
    # drain to idle: every submitted request must terminate cleanly
    guard = 0
    while not s.idle and guard < 500:
        s.admit(t)
        s.drain_copies()
        s._grow_for_decode()
        _host_step(s, rng)
        _check_invariants(s)
        t += 1
        guard += 1
    assert s.idle, "stream failed to drain"
    assert len(s.finished) == n_submitted
    # a drained scheduler owns nothing: the pool is whole again
    assert s.alloc.in_use == 0
    assert s.index is not None and len(s.index) == 0


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    sharing=st.sampled_from([True, False]),
)
def test_allocator_and_index_survive_tiny_pools(seed, sharing):
    """The degenerate pools: barely more blocks than one request needs.
    Admission blocking, self-preemption and forget-on-release must still
    conserve the pool (regression net for the eviction/refcount corners)."""
    import dataclasses

    rng = np.random.default_rng(seed)
    sp = _serve_plan(decode_batch=2, block_size=4)
    sp = dataclasses.replace(sp, n_blocks=1 + 6, prefix_sharing=sharing)
    s = Scheduler(sp)
    t = 0
    for i in range(20):
        if rng.random() < 0.5:
            n = int(rng.integers(1, 9))
            s.submit(Request(
                rid=f"t{i:03d}",
                prompt=[int(x) for x in rng.integers(0, 4, n)],
                max_new_tokens=int(rng.integers(1, 5)),
                arrival=t,
            ))
        s.admit(t)
        s.drain_copies()
        _check_invariants(s)
        try:
            s._grow_for_decode()
        except RuntimeError:
            # "pool exhausted by a single request" is a legal terminal
            # diagnosis for adversarial streams; state must stay consistent
            _check_invariants(s)
            return
        _host_step(s, rng)
        _check_invariants(s)
        t += 1


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_scheduler_invariants_under_chaos_churn(seed):
    """The random churn with the fault machinery layered on: injector
    pool squeezes, zero-deadline expiry, random cancels and admission
    shedding.  The conserved invariants must hold with the injector
    holding blocks (they count as one extra holder each), every request
    must terminate as finished *or* shed — never lost — and releasing the
    squeeze must make the pool whole again."""
    rng = np.random.default_rng(seed)
    inj = FaultInjector(
        int(seed) % 2**31, pressure_rate=0.3, pressure_frac=0.5,
        pressure_steps=3,
    )
    import dataclasses

    sp = dataclasses.replace(
        _serve_plan(n_blocks=1 + 14), admission_patience=6
    )
    s = Scheduler(sp)
    t, n_submitted = 0, 0

    def tick():
        inj.pressure(t, s.alloc)
        s.expire_deadlines(time.perf_counter())
        s.admit(t)
        s.shed_starved(t)
        s.drain_copies()
        _check_invariants(s, held=inj.held)

    for _ in range(60):
        op = rng.random()
        if op < 0.35 and n_submitted < 24:
            r = _random_request(rng, n_submitted, t)
            if rng.random() < 0.15:
                r.deadline_ms = 0.0  # expires the moment it is checked
            s.submit(r)
            n_submitted += 1
        tick()
        if op < 0.10:
            live = s._active() + list(s.waiting)
            if live:
                s.cancel(live[int(rng.integers(len(live)))])
                _check_invariants(s, held=inj.held)
        try:
            s._grow_for_decode()
        except RuntimeError:
            # a squeeze can leave too little pool for a single request's
            # growth: legal terminal diagnosis, state must stay consistent
            _check_invariants(s, held=inj.held)
            return
        _host_step(s, rng)
        _check_invariants(s, held=inj.held)
        t += 1
    guard = 0
    while not s.idle and guard < 500:
        tick()
        try:
            s._grow_for_decode()
        except RuntimeError:
            _check_invariants(s, held=inj.held)
            return
        _host_step(s, rng)
        _check_invariants(s, held=inj.held)
        t += 1
        guard += 1
    assert s.idle, "chaotic stream failed to drain"
    # nothing vanished: every submission is accounted finished or shed
    assert len(s.finished) + len(s.shed) == n_submitted
    for r in s.shed:
        assert r.status in ("shed", "expired", "cancelled", "poisoned")
    inj.release(s.alloc)
    _check_invariants(s)
    assert s.alloc.in_use == 0
    if s.index is not None:
        assert len(s.index) == 0


def test_prefill_then_rolled_spans_preserve_state():
    """Deterministic mixed sequence touching every transition at least once
    (collectable without hypothesis; the seeded tests above generalize it)."""
    rng = np.random.default_rng(0)
    s = Scheduler(_serve_plan(n_blocks=1 + 20, decode_batch=2))
    for i in range(4):
        s.submit(_random_request(rng, i, 0))
    t = 0
    for _ in range(80):
        if s.idle:
            break
        s.admit(t)
        s.drain_copies()
        if not s.prefilling() and s.running():
            adv = _rolled_span(s, rng, t)
            if adv:
                _check_invariants(s)
                t += adv
                continue
        s._grow_for_decode()
        _host_step(s, rng)
        _check_invariants(s)
        t += 1
    assert s.idle and s.alloc.in_use == 0
