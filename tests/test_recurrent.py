"""RG-LRU and RWKV6 recurrence invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.models.rglru import (
    causal_conv1d,
    rglru,
    rglru_decode_step,
    rglru_scan_ref,
)

KEY = jax.random.PRNGKey(0)


def _gparams(W, H):
    bh = W // H
    k = jax.random.split(KEY, 4)
    return {
        "w_gate_a": jax.random.normal(k[0], (H, bh, bh), jnp.float32) * 0.1,
        "b_gate_a": jnp.zeros((W,)),
        "w_gate_x": jax.random.normal(k[1], (H, bh, bh), jnp.float32) * 0.1,
        "b_gate_x": jnp.zeros((W,)),
        "lam": jnp.linspace(-2.0, 1.0, W),
    }


def test_associative_scan_equals_sequential():
    B, S, W, H = 2, 32, 16, 2
    p = _gparams(W, H)
    u = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, W), jnp.float32)
    y1, h1 = rglru(p, u, H)
    y2, h2 = rglru_scan_ref(p, u, H)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)


def test_decode_steps_continue_scan():
    B, S, W, H = 1, 16, 8, 2
    p = _gparams(W, H)
    u = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, W), jnp.float32)
    y_full, _ = rglru(p, u, H)
    _, h = rglru(p, u[:, :8], H)
    outs = []
    for t in range(8, S):
        y1, h = rglru_decode_step(p, u[:, t], h, H)
        outs.append(np.asarray(y1))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, np.asarray(y_full[:, 8:]), rtol=1e-4, atol=1e-4)


def test_causal_conv_streaming():
    B, S, W, cw = 2, 12, 8, 4
    x = jax.random.normal(KEY, (B, S, W), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (cw, W), jnp.float32)
    full, _ = causal_conv1d(x, w)
    y1, st = causal_conv1d(x[:, :5], w)
    y2, _ = causal_conv1d(x[:, 5:], w, st)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=1e-5, atol=1e-6)


@given(S=st.sampled_from([8, 16, 33]), W=st.sampled_from([8, 16]))
@settings(max_examples=10, deadline=None)
def test_rglru_state_bounded(S, W):
    """|h_t| stays bounded: a in (0,1) and b scaled by sqrt(1-a^2)."""
    H = 2
    p = _gparams(W, H)
    u = jnp.ones((1, S, W), jnp.float32) * 3.0
    y, h = rglru(p, u, H)
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.max(np.abs(np.asarray(h))) < 100.0
