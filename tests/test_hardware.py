"""Hardware registry, degenerate-device hazards, and the energy table."""
import dataclasses
import math

import pytest

from repro.core.hardware import (
    ENERGY_PJ,
    HARDWARE_VARIANTS,
    TPU_V5E,
    VCK5000,
    HardwareSpec,
    energy_params,
    get_hardware,
    register_variant,
    registered_hardware,
)
from repro.core.plan import derive_plan, derive_serve_plan
from repro.core.pu import pick_pu
from repro.configs import get_config


# ---------------------------------------------------------------- registry
def test_registry_resolves_builtin_devices():
    assert get_hardware("tpu_v5e") is TPU_V5E
    assert get_hardware("vck5000") is VCK5000


def test_unknown_name_lists_registered_variants():
    with pytest.raises(KeyError) as e:
        get_hardware("tpu_v9")
    msg = str(e.value)
    assert "tpu_v5e" in msg and "vck5000" in msg


def test_declared_variants_are_registered():
    names = registered_hardware()
    for name in HARDWARE_VARIANTS:
        assert name in names
    hbm2x = get_hardware("tpu_v5e-hbm2x")
    assert hbm2x.hbm_bandwidth == pytest.approx(2 * TPU_V5E.hbm_bandwidth)
    # non-replaced fields inherit from the base spec
    assert hbm2x.hbm_bytes == TPU_V5E.hbm_bytes


def test_register_variant_replaces_fields_only():
    v = register_variant("tpu_v5e-testonly", "tpu_v5e", tdp_watts=1.0)
    assert v.tdp_watts == 1.0
    assert v.peak_flops_bf16 == TPU_V5E.peak_flops_bf16
    assert get_hardware("tpu_v5e-testonly") is v


# ------------------------------------------------- degenerate-device hazards
def _degenerate(**kw) -> HardwareSpec:
    base = dict(
        name="degenerate",
        peak_flops_bf16=1e12,
        peak_ops_int8=2e12,
        vmem_bytes=1 << 20,
        hbm_bytes=16 * 1024**3,
        hbm_bandwidth=0.0,
        ici_bandwidth_per_link=0.0,
        ici_links_per_chip=0,
    )
    base.update(kw)
    return HardwareSpec(**base)


def test_zero_bandwidth_machine_balance_is_inf():
    hw = _degenerate()
    assert math.isinf(hw.machine_balance_bf16)
    assert hw.ici_bandwidth == 0.0


def test_zero_bandwidth_matmul_time_is_inf_not_crash():
    assert math.isinf(_degenerate().matmul_time_s(128, 128, 128))


def test_planner_total_on_degenerate_device():
    """derive_plan / derive_serve_plan / pick_pu must not divide by zero on
    a device with no HBM bandwidth or no interconnect (VCK5000 ships
    ici_links_per_chip=0; SRAM-only variants ship hbm_bandwidth=0)."""
    cfg = get_config("smollm-135m")
    hw = _degenerate()
    mesh = {"data": 1, "model": 1}
    plan = derive_plan(cfg, mesh, hw, batch=4, seq_len=64, training=False)
    assert plan is not None
    serve = derive_serve_plan(cfg, mesh, hw, max_seq_len=128)
    assert serve.decode_batch >= 1
    tile = pick_pu(8, cfg.d_model, cfg.d_model, hw, dtype_bytes=2)
    assert tile.block_m >= 1


def test_vck5000_no_ici_paths_total():
    cfg = get_config("smollm-135m")
    serve = derive_serve_plan(cfg, {"data": 1, "model": 1}, VCK5000,
                              max_seq_len=256)
    assert serve.decode_batch >= 1
    assert VCK5000.ici_bandwidth == 0.0


# ------------------------------------------------------------ energy table
def test_energy_params_merges_node_row_with_overrides():
    ep = energy_params(VCK5000)
    assert ep["mem_byte"] == 150.0  # device override wins
    assert ep["flop_bf16"] == ENERGY_PJ["7nm"]["flop_bf16"]  # node row


def test_energy_params_empty_without_tech_node():
    hw = _degenerate(tech_node="")
    assert energy_params(hw) == {}
    hw2 = dataclasses.replace(hw, energy_pj=(("mem_byte", 9.0),))
    assert energy_params(hw2) == {"mem_byte": 9.0}


def test_spec_stays_hashable():
    hash(TPU_V5E)
    hash(get_hardware("vck5000-int8w"))
