"""MoE: dispatch equivalence (gshard vs sort), routing invariants."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.moe import MoESettings, moe_ffn, router_topk

KEY = jax.random.PRNGKey(0)


def _params(d, E, F, key=KEY):
    k = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(k[0], (d, E), jnp.float32) * 0.1,
        "w1": jax.random.normal(k[1], (E, d, F), jnp.float32) * 0.05,
        "w3": jax.random.normal(k[2], (E, d, F), jnp.float32) * 0.05,
        "w2": jax.random.normal(k[3], (E, F, d), jnp.float32) * 0.05,
    }


def test_gshard_equals_sort_when_dropfree():
    d, E, F, T = 32, 4, 64, 64
    p = _params(d, E, F)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (T, d), jnp.float32)
    # capacity_factor=E guarantees no drops in either implementation
    y1, a1 = moe_ffn(p, x, MoESettings(E, 2, capacity_factor=float(E), dispatch="gshard"), "swiglu")
    y2, a2 = moe_ffn(p, x, MoESettings(E, 2, capacity_factor=float(E), dispatch="sort"), "swiglu")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_router_gates_normalized():
    d, E = 16, 8
    x = jax.random.normal(KEY, (32, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (d, E), jnp.float32)
    gates, idx, aux = router_topk(x, w, 2)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < E
    assert float(aux) >= 1.0 - 1e-5  # E * sum(me*ce) >= 1 by Cauchy-Schwarz


def test_capacity_drops_reduce_output_norm():
    """Tokens over capacity are dropped -> lower-capacity output differs."""
    d, E, F, T = 16, 4, 32, 64
    p = _params(d, E, F)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (T, d), jnp.float32)
    y_full, _ = moe_ffn(p, x, MoESettings(E, 2, capacity_factor=float(E), dispatch="sort"), "swiglu")
    y_tight, _ = moe_ffn(p, x, MoESettings(E, 2, capacity_factor=0.25, dispatch="sort"), "swiglu")
    assert not np.allclose(np.asarray(y_full), np.asarray(y_tight))


@given(
    T=st.sampled_from([16, 32, 64]),
    E=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    dispatch=st.sampled_from(["gshard", "sort"]),
)
@settings(max_examples=12, deadline=None)
def test_moe_output_finite(T, E, k, dispatch):
    d, F = 16, 32
    p = _params(d, E, F)
    x = jax.random.normal(jax.random.fold_in(KEY, T + E), (T, d), jnp.float32)
    y, aux = moe_ffn(p, x, MoESettings(E, k, dispatch=dispatch), "swiglu")
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.isfinite(float(aux))
