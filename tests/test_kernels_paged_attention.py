"""Fused paged-attention kernel vs the jnp oracle: bf16/int8 pages, SWA
wraparound, ragged per-slot lengths, empty slots on trash block 0, mixed
prefill/decode slabs, GQA, and tile-sweep invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

KEY = jax.random.PRNGKey(0)


def _quantize(x):
    """train/compression.quantize's per-(token, kv-head) int8 grid."""
    s = jnp.maximum(jnp.abs(x).max(-1, keepdims=True), 1e-12) / 127.0
    return jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8), s


def _mk_case(B, W, H, KH, D, bs, MB, kv_dtype, seed=0):
    """Random pools + prefix-dense tables with ragged lens/q_lens; slot 0 is
    empty (kinds 0, whole table on trash block 0)."""
    key = jax.random.fold_in(KEY, seed)
    N = 1 + B * MB
    q = jax.random.normal(key, (B, W, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (N, bs, KH, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (N, bs, KH, D), jnp.float32)
    if kv_dtype == "int8":
        qk, sk = _quantize(k)
        qv, sv = _quantize(v)
        entry = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    else:
        entry = {"k": k.astype(kv_dtype), "v": v.astype(kv_dtype)}
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, MB * bs - W, B).astype(np.int32)
    q_lens = rng.integers(1, W + 1, B).astype(np.int32)
    table = np.zeros((B, MB), np.int32)
    for b in range(B):
        nb = -(-(int(lens[b]) + W) // bs)
        table[b, :nb] = 1 + b * MB + np.arange(nb)
    table[0], lens[0], q_lens[0] = 0, 0, 0  # empty slot on trash block 0
    return q, entry, jnp.asarray(table), jnp.asarray(lens), jnp.asarray(q_lens)


CASES = [
    # (B, W, H, KH, D, bs, MB, kv_dtype, window, pages_per_tile)
    (3, 1, 4, 2, 32, 4, 8, "float32", 0, 2),  # pure decode, GQA
    (3, 4, 4, 2, 32, 4, 8, "float32", 0, 8),  # mixed slab, one-tile sweep
    (2, 8, 2, 1, 64, 8, 16, "bfloat16", 0, 4),  # bf16 pages, prefill rows
    (2, 4, 4, 2, 32, 4, 16, "int8", 0, 16),  # int8 in-kernel dequant
    (3, 4, 2, 2, 32, 4, 16, "float32", 12, 1),  # SWA, page-at-a-time
    (2, 1, 4, 1, 64, 8, 8, "int8", 20, 2),  # SWA decode past the window
]


@pytest.mark.parametrize("case", CASES)
def test_kernel_matches_ref(case):
    B, W, H, KH, D, bs, MB, kv_dtype, window, ppt = case
    q, entry, table, lens, q_lens = _mk_case(B, W, H, KH, D, bs, MB, kv_dtype)
    got = np.asarray(
        paged_attention(
            q, entry, table, lens, q_lens,
            block_size=bs, window=window, pages_per_tile=ppt,
        ),
        np.float32,
    )
    want = np.asarray(
        paged_attention_ref(
            q, entry, table, lens, q_lens, block_size=bs, window=window
        ),
        np.float32,
    )
    tol = 3e-2 if kv_dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
    # the empty slot (trash table, q_lens 0) must come back exactly zero
    np.testing.assert_array_equal(got[0], np.zeros_like(got[0]))


def test_tile_sweep_invariance():
    """Plan knob contract (paper C2 analog): pages_per_tile changes the VMEM
    schedule, never the numbers."""
    q, entry, table, lens, q_lens = _mk_case(2, 4, 4, 2, 32, 4, 16, "float32")
    outs = [
        np.asarray(
            paged_attention(
                q, entry, table, lens, q_lens,
                block_size=4, pages_per_tile=ppt,
            )
        )
        for ppt in (1, 2, 4, 16)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


def test_swa_wraparound_ignores_pages_below_window():
    """With a sliding window, pages wholly below every row's window must not
    influence the output: corrupting them changes nothing (the kernel skips
    those tiles outright)."""
    B, W, H, KH, D, bs, MB, window = 1, 1, 2, 1, 32, 4, 8, 8
    q, entry, table, lens, q_lens = _mk_case(B, W, H, KH, D, bs, MB, "float32")
    lens = jnp.array([28], jnp.int32)  # deep context, window covers 21..28
    q_lens = jnp.array([1], jnp.int32)
    table = jnp.arange(MB, dtype=jnp.int32)[None] + 1
    base = np.asarray(
        paged_attention(
            q, entry, table, lens, q_lens,
            block_size=bs, window=window, pages_per_tile=2,
        )
    )
    smashed = dict(entry)
    smashed["k"] = entry["k"].at[1:4].set(1e3)  # positions 0..11, all dead
    smashed["v"] = entry["v"].at[1:4].set(-1e3)
    got = np.asarray(
        paged_attention(
            q, smashed, table, lens, q_lens,
            block_size=bs, window=window, pages_per_tile=2,
        )
    )
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)


def test_matches_model_fallback_path():
    """The kernel and the model's gather fallback
    (models/layers.paged_attention over models/cache.paged_gather) are the
    same op on live rows."""
    from repro.models.cache import paged_gather
    from repro.models.layers import paged_attention as gather_attn

    B, W, H, KH, D, bs, MB = 2, 4, 4, 2, 32, 4, 8
    q, entry, table, lens, q_lens = _mk_case(B, W, H, KH, D, bs, MB, "float32", seed=3)
    got = np.asarray(
        paged_attention(q, entry, table, lens, q_lens, block_size=bs)
    )
    kf, vf = paged_gather(entry, table, bs, max_blocks=MB)
    pos = np.asarray(lens)[:, None] + np.arange(W)[None]
    want = np.asarray(gather_attn(q, kf, vf, jnp.asarray(pos)))
    live = np.arange(W)[None] < np.asarray(q_lens)[:, None]  # (B, W)
    np.testing.assert_allclose(
        got[live], want[live], rtol=2e-5, atol=2e-5
    )
