"""Flash-attention kernel sweeps: GQA, causal, window, prefix-LM, dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models.layers import blocked_attention

KEY = jax.random.PRNGKey(0)


def _mk(B, Sq, Sk, H, KH, D, dtype=jnp.float32):
    q = jax.random.normal(KEY, (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Sk, KH, D), jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Sk, KH, D), jnp.float32).astype(dtype)
    return q, k, v


def _ref(q, k, v, **kw):
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KH, -1, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KH, -1, D)
    out = flash_attention_ref(qr, kr, vr, n_q_per_kv=H // KH, **kw)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


CASES = [
    dict(B=2, S=256, H=4, KH=2, D=64, causal=True, window=0, prefix=0),
    dict(B=1, S=128, H=3, KH=1, D=32, causal=True, window=0, prefix=0),
    dict(B=2, S=256, H=4, KH=4, D=64, causal=False, window=0, prefix=0),
    dict(B=1, S=256, H=2, KH=1, D=64, causal=True, window=64, prefix=0),
    dict(B=1, S=128, H=2, KH=2, D=64, causal=True, window=0, prefix=32),
    dict(B=1, S=512, H=1, KH=1, D=128, causal=True, window=128, prefix=0),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_ref(case):
    q, k, v = _mk(case["B"], case["S"], case["S"], case["H"], case["KH"], case["D"])
    kw = dict(causal=case["causal"], window=case["window"], prefix=case["prefix"])
    got = np.asarray(flash_attention(q, k, v, block_q=64, block_k=64, **kw))
    want = np.asarray(_ref(q, k, v, **kw))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_bf16(dtype):
    q, k, v = _mk(1, 128, 128, 2, 1, 64, dtype)
    got = np.asarray(flash_attention(q, k, v, causal=True), np.float32)
    want = np.asarray(_ref(q, k, v, causal=True), np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("blocks", [(32, 32), (64, 128), (128, 64)])
def test_block_shape_invariance(blocks):
    """Paper C2: PU scale must not change results, only the schedule."""
    bq, bk = blocks
    q, k, v = _mk(1, 256, 256, 2, 1, 64)
    got = np.asarray(flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk))
    want = np.asarray(_ref(q, k, v, causal=True))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_model_blocked_attention_agrees_with_kernel():
    """The jnp model path (dry-run) and the Pallas path (TPU) are the same op."""
    q, k, v = _mk(2, 128, 128, 4, 2, 32)
    a = np.asarray(flash_attention(q, k, v, causal=True))
    b = np.asarray(blocked_attention(q, k, v, causal=True, q_chunk=64, k_chunk=64))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
