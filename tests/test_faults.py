"""Fault-tolerant serving: the chaos harness and the hardened engine.

The robustness contract this file pins down:

* **Chaos parity** — under any seeded :class:`FaultInjector` schedule
  (transient dispatch faults, NaN-poisoned logits, block-pool pressure,
  step-time spikes) every request the engine *finishes* is byte-identical
  to the fault-free ``greedy_generate`` oracle.  Faults change latency and
  the path taken (retries, ladder rungs, quarantine replays), never tokens.
* **The degradation ladder** — rolled-K spans -> K=1 mixed step -> eager
  gather fallback, with bounded in-rung retries; exhaustion raises
  :class:`LadderExhausted` carrying ``health()``; sustained health climbs
  back up.  The fallback compiles at most once (``fallback_step`` <= 1).
* **Lifecycle edges** — submit() validation names the offending field,
  per-request deadlines expire cleanly, starved waiters are shed with a
  retry-after hint, a wedged scheduler raises :class:`StallError` instead
  of burning iterations, and ``summary()`` accounts every disposition
  (finished / shed / expired / cancelled / poisoned) per tenant.
* **Crash recovery** — ``snapshot()`` (logical state only, kilobytes, no
  KV) restored onto a fresh engine re-prefills each in-flight request's
  ``prompt + out[:-1]`` and continues byte-identically: KV pages are a
  pure function of the token prefix (the PR 6 invariant).

Fast-lane tests here are host-only (no jit); everything that dispatches
the device step is marked slow, same split as the differential matrix.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import assert_traces_bounded

from repro.configs import get_config
from repro.core.plan import derive_plan, derive_serve_plan
from repro.serve import (
    FaultInjector,
    LadderExhausted,
    Request,
    ServingEngine,
    StallError,
    greedy_generate,
    make_trace,
)
from repro.serve.faults import LADDER

MESH1 = {"data": 1, "model": 1}
MIX = {"chat": 2, "classify": 2}
MAX_SEQ = 96

# module-level memo instead of fixtures: the hypothesis stub's runner hides
# the test signature from pytest, so @given tests cannot request fixtures
_MEMO: dict = {}


def _base():
    if "base" not in _MEMO:
        cfg = get_config("smollm-135m").reduced()
        plan = derive_plan(cfg, MESH1, batch=4, seq_len=16, training=False)
        from repro.models.params import init_params

        params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
        _MEMO["base"] = (cfg, plan, params)
    return _MEMO["base"]


def _mk_trace(cfg):
    # fresh Request objects per engine (the scheduler mutates them in
    # place); same seed -> identical prompts/arrivals/budgets
    return make_trace(cfg, MIX, tenants=2, system_prompt_len=16, stagger=1,
                      seed=5, max_tokens=MAX_SEQ)


def _oracle():
    """Fault-free greedy reference for the shared trace (computed once)."""
    if "oracle" not in _MEMO:
        cfg, plan, params = _base()
        oracle = {}
        for r in _mk_trace(cfg):
            out = greedy_generate(
                params, cfg, plan, {"tokens": jnp.asarray(r.prompt)[None]},
                n_steps=r.max_new_tokens,
                cache_len=len(r.prompt) + r.max_new_tokens,
                cache_dtype=jnp.float32,
            )
            oracle[r.rid] = [int(t) for t in np.asarray(out)[0]]
        _MEMO["oracle"] = oracle
    return _MEMO["oracle"]


def _serve(cfg, **kw):
    n_blocks = kw.pop("n_blocks", None)
    kw.setdefault("max_seq_len", MAX_SEQ)
    kw.setdefault("decode_batch", 3)
    kw.setdefault("block_size", 8)
    kw.setdefault("kv_dtype", "fp32")
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("retry_backoff_s", 0.0)  # tests never sleep
    sp = derive_serve_plan(cfg, MESH1, **kw)
    if n_blocks is not None:
        sp = dataclasses.replace(sp, n_blocks=n_blocks)
    return sp


def _engine(serve_kw=None, injector=None, draft=None):
    cfg, plan, params = _base()
    serve = _serve(cfg, **(serve_kw or {}))
    return ServingEngine(
        params, cfg, plan, serve, injector=injector, draft=draft
    )


# ---------------------------------------------------------------------------
# FaultInjector: validation + deterministic replay (host-only, fast)
# ---------------------------------------------------------------------------
def test_injector_validates_knobs():
    with pytest.raises(ValueError, match="transient_burst"):
        FaultInjector(0, transient_burst=0)
    with pytest.raises(ValueError, match="nan_rate"):
        FaultInjector(0, nan_rate=1.5)
    with pytest.raises(ValueError, match="pressure_rate"):
        FaultInjector(0, pressure_rate=-0.1)


def test_injector_schedule_replays_identically():
    """Every decision is a pure function of (seed, kind, iteration): a
    second injector asked out of order and repeatedly gives the same
    schedule — the property chaos parity rests on."""
    mk = lambda: FaultInjector(7, nan_rate=0.3, spike_rate=0.2, spike_ms=1.0)
    a, b = mk(), mk()
    masks = [a.nan_mask(i, 4) for i in range(40)]
    spikes = [a.spike_s(i) for i in range(40)]
    for i in reversed(range(40)):
        np.testing.assert_array_equal(b.nan_mask(i, 4), masks[i])
        np.testing.assert_array_equal(b.nan_mask(i, 4), masks[i])  # re-ask
        assert b.spike_s(i) == spikes[i]
    assert any(m.any() for m in masks), "seed 7 schedule should poison"
    assert any(spikes), "seed 7 schedule should spike"


def test_nan_in_span_matches_per_iteration_mask():
    """The rolled span's first-poison offsets are exactly what K separate
    K=1 dispatches would have drawn — rolled vs mixed see ONE schedule."""
    inj, ref = FaultInjector(3, nan_rate=0.4), FaultInjector(3, nan_rate=0.4)
    off = inj.nan_in_span(10, 6, 5)
    for b in range(5):
        want = next(
            (t for t in range(6) if ref.nan_mask(10 + t, 5)[b]), -1
        )
        assert off[b] == want


def test_injector_horizon_silences_new_faults():
    inj = FaultInjector(1, transient_rate=1.0, nan_rate=1.0, spike_rate=1.0,
                        horizon=2)
    with pytest.raises(Exception):
        inj.check_dispatch(0)
    inj.check_dispatch(5)  # past horizon: no new trip
    assert not inj.nan_mask(5, 3).any()
    assert inj.spike_s(5) == 0.0


def test_transient_burst_spans_attempts():
    """One scheduled fault fails `burst` consecutive attempts, then clears
    — burst length vs retry_limit decides in-rung recovery vs escalation."""
    from repro.serve.faults import TransientDeviceError

    inj = FaultInjector(0, transient_rate=1.0, transient_burst=3, horizon=1)
    for _ in range(3):
        with pytest.raises(TransientDeviceError):
            inj.check_dispatch(0)
    inj.check_dispatch(0)  # burst spent: the retry goes through
    assert inj.counts["transient"] == 3


# ---------------------------------------------------------------------------
# Engine lifecycle edges (host-only, fast: no device dispatch happens)
# ---------------------------------------------------------------------------
def test_submit_validation_names_the_field():
    eng = _engine()
    vocab = eng.cfg.vocab_size
    with pytest.raises(ValueError, match=r"v0.*prompt must not be empty"):
        eng.submit(Request(rid="v0", prompt=[], max_new_tokens=4, arrival=0))
    with pytest.raises(ValueError, match=r"v1.*max_new_tokens"):
        eng.submit(Request(rid="v1", prompt=[1], max_new_tokens=0, arrival=0))
    with pytest.raises(ValueError, match=r"v2.*max_seq_len"):
        eng.submit(Request(
            rid="v2", prompt=[1] * MAX_SEQ, max_new_tokens=4, arrival=0
        ))
    with pytest.raises(ValueError, match=r"v3.*outside vocab"):
        eng.submit(Request(
            rid="v3", prompt=[1, vocab], max_new_tokens=4, arrival=0
        ))
    assert not eng.sched.waiting  # nothing half-queued


def test_stall_detector_raises_with_health():
    """A wedged scheduler (admission never happens, work pending) must
    raise StallError after stall_limit dead iterations, not burn the whole
    max_iterations budget; the error carries a health() snapshot."""
    eng = _engine({"stall_limit": 6})
    eng.sched.admit = lambda iteration: None  # wedge
    eng.submit(Request(rid="s0", prompt=[1, 2, 3], max_new_tokens=4, arrival=0))
    with pytest.raises(StallError) as ei:
        eng.run()
    h = ei.value.health
    assert h["queue"]["arrived"] == 1
    assert h["slots"]["running"] == 0
    assert h["rung_name"] in LADDER


def test_idle_until_future_arrival_is_not_a_stall():
    """An empty engine waiting for a future arrival is idle by design —
    the stall detector must not fire while the clock catches up."""
    eng = _engine({"stall_limit": 3, "deadline_ms": 0.0})
    # deadline 0 expires the request the moment it arrives (iteration 20),
    # so the run needs no device step — but it must *reach* iteration 20
    # through > stall_limit genuinely idle iterations first
    eng.submit(Request(rid="f0", prompt=[1, 2], max_new_tokens=2, arrival=20))
    assert eng.run() == {}
    assert eng.stats["expired"] == 1


def test_deadline_expiry_cancels_cleanly():
    eng = _engine()
    eng.submit(Request(
        rid="d0", prompt=[1, 2, 3], max_new_tokens=4, arrival=0,
        deadline_ms=0.0,
    ))
    assert eng.run() == {}
    (r,) = eng.sched.shed
    assert r.rid == "d0" and r.status == "expired"
    assert eng.stats["expired"] == 1
    assert eng.sched.alloc.in_use == 0


def test_plan_default_deadline_applies_at_submit():
    eng = _engine({"deadline_ms": 0.0})
    req = Request(rid="d1", prompt=[1], max_new_tokens=2, arrival=0)
    eng.submit(req)
    assert req.deadline_ms == 0.0
    eng.run()
    assert req.status == "expired"


def test_cancel_api():
    eng = _engine()
    eng.submit(Request(rid="c0", prompt=[1, 2], max_new_tokens=3, arrival=5))
    assert eng.cancel("c0") is True
    assert eng.cancel("missing") is False
    (r,) = eng.sched.shed
    assert r.status == "cancelled"
    assert eng.stats["cancelled"] == 1
    assert eng.sched.idle


def test_ladder_exhausted_raises_with_health():
    """A transient burst longer than every rung's retry budget must raise
    LadderExhausted *before* any device dispatch (the check runs before the
    jitted call, so donated pools are never consumed by a doomed step)."""
    inj = FaultInjector(0, transient_rate=1.0, transient_burst=8, horizon=1)
    # non-rolled engine: ladder floor is the mixed rung, so the budget is
    # (retry_limit + 1) attempts on mixed + the same on gather = 6 < 8
    eng = _engine({"rolled_steps": 1, "retry_limit": 2}, injector=inj)
    eng.submit(Request(rid="x0", prompt=[1, 2, 3], max_new_tokens=4, arrival=0))
    with pytest.raises(LadderExhausted) as ei:
        eng.run()
    assert ei.value.health["rung_name"] == "gather"
    assert eng.stats["rung_escalations"] == 1
    assert eng.trace_counts["step"] == 0  # nothing ever dispatched


def test_summary_accounts_every_disposition_per_tenant():
    """summary() splits finished vs shed/expired/cancelled/poisoned both
    globally and per tenant — goodput accounting can never conflate a shed
    stream with a completed one (satellite: per-tenant dispositions)."""
    eng = _engine({
        "admission_patience": 2, "n_blocks": 1 + 2, "block_size": 4,
    })
    # t-shed: needs 3 blocks, pool holds 2 -> admission-starved, then shed
    eng.submit(Request(
        rid="x0", prompt=[1] * 9, max_new_tokens=2, arrival=0, tenant="t-shed"
    ))
    # t-exp: deadline already passed at the first step
    eng.submit(Request(
        rid="x1", prompt=[1, 2], max_new_tokens=2, arrival=0, tenant="t-exp",
        deadline_ms=0.0,
    ))
    # t-can: cancelled by the API before it ever arrives
    eng.submit(Request(
        rid="x2", prompt=[1, 2], max_new_tokens=2, arrival=50, tenant="t-can"
    ))
    assert eng.cancel("x2")
    assert eng.run() == {}
    s = eng.summary()
    assert s["requests"] == {
        "finished": 0, "shed": 1, "expired": 1, "cancelled": 1, "poisoned": 0,
    }
    assert s["tenants"]["t-shed"]["shed"] == 1
    assert s["tenants"]["t-exp"]["expired"] == 1
    assert s["tenants"]["t-can"]["cancelled"] == 1
    assert all(t["finished"] == 0 for t in s["tenants"].values())
    shed_req = next(r for r in eng.sched.shed if r.rid == "x0")
    assert shed_req.retry_after_s is not None and shed_req.retry_after_s > 0
    assert s["faults"]["shed"] == 1 and s["faults"]["expired"] == 1


def test_health_shape():
    eng = _engine()
    h = eng.health()
    for k in ("iteration", "rung", "rung_name", "pool", "slots", "queue",
              "last_fault", "step_ms"):
        assert k in h, k
    assert h["rung_name"] == LADDER[h["rung"]]
    assert h["pool"]["available"] + h["pool"]["in_use"] == (
        eng.serve.n_blocks - 1
    )


def test_serve_plan_carries_robustness_knobs():
    cfg = get_config("smollm-135m").reduced()
    sp = _serve(
        cfg, deadline_ms=123.0, retry_limit=5, ladder_recovery=7,
        admission_patience=9, stall_limit=11, quarantine_limit=4,
    )
    rec = sp.to_record()
    assert rec["deadline_ms"] == 123.0
    assert rec["retry_limit"] == 5
    assert rec["retry_backoff_s"] == 0.0
    assert rec["ladder_recovery"] == 7
    assert rec["admission_patience"] == 9
    assert rec["stall_limit"] == 11
    assert rec["quarantine_limit"] == 4


# ---------------------------------------------------------------------------
# Device-dispatching robustness (slow lane)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_backpressure_sheds_with_retry_after_hint():
    """Pool sized for one stream: the second waiter starves past the
    admission patience and is shed with a positive retry-after hint while
    the first stream finishes untouched."""
    eng = _engine({
        "decode_batch": 2, "n_blocks": 1 + 3, "admission_patience": 3,
        "prefix_sharing": False, "rolled_steps": 1,
    })
    a = Request(rid="a", prompt=list(range(1, 17)), max_new_tokens=8, arrival=0)
    b = Request(rid="b", prompt=list(range(21, 37)), max_new_tokens=8, arrival=1)
    out = eng.run([a, b])
    assert list(out) == ["a"] and len(out["a"]) == 8
    assert b.status == "shed" and b.retry_after_s > 0
    assert eng.stats["shed"] == 1
    assert eng.sched.alloc.in_use == 0
    assert_traces_bounded(eng.trace_counts)


# the chaos matrix: each injector spec against the K=1 and rolled-K=4
# engines; every finished request must match the fault-free oracle and the
# targeted fault machinery must actually have engaged
CHAOS_SPECS = {
    "transient": dict(transient_rate=0.3, transient_burst=2, horizon=20),
    "nan": dict(nan_rate=0.25, horizon=20),
    "pressure": dict(pressure_rate=0.4, pressure_frac=0.4, pressure_steps=3,
                     horizon=20),
    "combined": dict(transient_rate=0.15, transient_burst=2, nan_rate=0.15,
                     pressure_rate=0.25, pressure_frac=0.3, pressure_steps=2,
                     spike_rate=0.2, spike_ms=0.5, horizon=24),
}
ENGAGED = {
    "transient": lambda e, inj: (
        e.stats["transient_faults"] >= 1 and e.stats["retries"] >= 1
    ),
    "nan": lambda e, inj: (
        e.stats["quarantines"] >= 1 and e.stats["injected_nans"] >= 1
    ),
    "pressure": lambda e, inj: inj.counts["squeeze"] >= 1,
    "combined": lambda e, inj: sum(inj.counts.values()) >= 2,
}


@pytest.mark.slow
@pytest.mark.parametrize("rolled", (1, 4))
@pytest.mark.parametrize("spec", sorted(CHAOS_SPECS))
def test_chaos_parity(spec, rolled):
    cfg, _, _ = _base()
    inj = FaultInjector(seed=11, **CHAOS_SPECS[spec])
    eng = _engine({"rolled_steps": rolled, "prefix_sharing": True},
                  injector=inj)
    got = eng.run(_mk_trace(cfg))
    for rid, want in _oracle().items():
        assert got[rid] == want, f"{spec} K={rolled}: {rid} diverged"
    assert ENGAGED[spec](eng, inj), (dict(eng.stats), dict(inj.counts))
    assert_traces_bounded(eng.trace_counts)
    inj.release(eng.sched.alloc)
    assert eng.sched.alloc.in_use == 0, "chaos leaked blocks"
    assert eng.summary()["faults"]["injector"]["injected"] == inj.counts


@pytest.mark.slow
@settings(max_examples=3, deadline=None)
@given(chaos_seed=st.integers(min_value=0, max_value=10_000))
def test_chaos_parity_property(chaos_seed):
    """Any drawn injector schedule: finished streams byte-match the oracle,
    nothing leaks, the no-retrace contract holds (satellite: fuzz)."""
    cfg, _, _ = _base()
    rng = np.random.default_rng(chaos_seed)
    inj = FaultInjector(
        chaos_seed,
        transient_rate=float(rng.uniform(0, 0.3)),
        transient_burst=int(rng.integers(1, 3)),
        nan_rate=float(rng.uniform(0, 0.25)),
        pressure_rate=float(rng.uniform(0, 0.3)),
        pressure_frac=0.3, pressure_steps=2, horizon=24,
    )
    eng = _engine({"rolled_steps": 4}, injector=inj)
    got = eng.run(_mk_trace(cfg))
    for rid, want in _oracle().items():
        assert got[rid] == want, f"seed {chaos_seed}: {rid} diverged"
    assert_traces_bounded(eng.trace_counts)
    inj.release(eng.sched.alloc)
    assert eng.sched.alloc.in_use == 0


@pytest.mark.slow
def test_ladder_reaches_gather_and_recovers():
    """A burst outlasting the mixed rung's retries escalates to the eager
    gather fallback (compiled exactly once, its own trace key), still emits
    byte-identical tokens, then climbs back to the floor."""
    inj = FaultInjector(0, transient_rate=1.0, transient_burst=4, horizon=1)
    eng = _engine({"rolled_steps": 1, "retry_limit": 2, "ladder_recovery": 4},
                  injector=inj)
    cfg, _, _ = _base()
    got = eng.run(_mk_trace(cfg))
    for rid, want in _oracle().items():
        assert got[rid] == want, f"gather fallback diverged on {rid}"
    assert eng.stats["rung_escalations"] == 1
    assert eng.trace_counts["fallback_step"] == 1
    assert eng.stats["rung_recoveries"] >= 1
    assert eng.rung == 1  # back at the non-rolled floor (mixed)
    assert_traces_bounded(eng.trace_counts)


@pytest.mark.slow
def test_rolled_ladder_escalates_and_recovers():
    """On a rolled engine the same burst drops to the K=1 rung, recovery
    climbs back to rung 0 and rolled spans resume — with parity."""
    inj = FaultInjector(0, transient_rate=1.0, transient_burst=4, horizon=1)
    eng = _engine({"rolled_steps": 4, "retry_limit": 2, "ladder_recovery": 2},
                  injector=inj)
    cfg, _, _ = _base()
    got = eng.run(_mk_trace(cfg))
    for rid, want in _oracle().items():
        assert got[rid] == want, f"rolled ladder diverged on {rid}"
    assert eng.stats["rung_escalations"] >= 1
    assert eng.stats["rung_recoveries"] >= 1
    assert eng.rung == 0
    assert eng.stats["rolled_dispatches"] >= 1
    assert_traces_bounded(eng.trace_counts)


@pytest.mark.slow
def test_snapshot_restore_resumes_byte_identically():
    """Interrupt mid-stream, snapshot (JSON round-trip), restore onto a
    fresh engine: the union of work finishes byte-identical to the oracle.
    The snapshot carries no KV — restore re-prefills prompt + out[:-1] and
    the pages rebuild exactly (pure function of the token prefix)."""
    cfg, _, _ = _base()
    eng = _engine()
    for r in _mk_trace(cfg):
        eng.submit(r)
    while eng.stats["generated_tokens"] < 5 and not eng.sched.idle:
        eng.step()
    assert not eng.sched.idle, "interrupted too late to be interesting"
    snap = json.loads(json.dumps(eng.snapshot()))  # crash-file round trip
    eng2 = _engine()
    eng2.restore(snap)
    got = eng2.run()
    oracle = _oracle()
    assert set(got) == set(oracle)
    for rid, want in oracle.items():
        assert got[rid] == want, f"restore diverged on {rid}"
    # a used engine refuses restore; so does a mismatched arch
    with pytest.raises(RuntimeError):
        eng2.restore(snap)
    eng3 = _engine()
    with pytest.raises(ValueError, match="arch"):
        eng3.restore(dict(snap, arch="not-this-model"))


@pytest.mark.slow
def test_draft_resyncs_after_quarantine():
    """Speculation + NaN chaos: quarantined slots make no progress, the
    drafter's self-healing prefix sync absorbs the replays, and the stream
    stays byte-identical to plain greedy (PR 5 invariant under faults)."""
    from repro.serve.speculative import make_draft_source

    cfg, plan, params = _base()
    serve = _serve(cfg, rolled_steps=1, draft="smollm-135m", spec_len=2)
    draft = make_draft_source("smollm-135m", cfg, serve, seed=3, reduced=True)
    inj = FaultInjector(5, nan_rate=0.3, horizon=16)
    eng = ServingEngine(params, cfg, plan, serve, draft=draft, injector=inj)
    got = eng.run(_mk_trace(cfg))
    for rid, want in _oracle().items():
        assert got[rid] == want, f"spec + chaos diverged on {rid}"
    assert eng.stats["quarantines"] >= 1
    assert eng.stats["draft_rows"] > 0
    assert_traces_bounded(eng.trace_counts)
