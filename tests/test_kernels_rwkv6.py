"""RWKV6 WKV kernel: chunked Pallas vs sequential-scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv6.ops import wkv
from repro.kernels.rwkv6.ref import wkv_ref
from repro.models.rwkv6 import wkv_chunked, wkv_scan_ref

KEY = jax.random.PRNGKey(0)


def _mk(B, S, H, D, decay_lo=0.45, decay_hi=0.95):
    r = jax.random.normal(KEY, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, D), jnp.float32)
    w = (
        jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, D)))
        * (decay_hi - decay_lo)
        + decay_lo
    )
    u = jax.random.normal(jax.random.fold_in(KEY, 4), (H, D), jnp.float32) * 0.1
    return r, k, v, w, u


def _kernel_vs_scan(B, S, H, D, chunk, **kw):
    r, k, v, w, u = _mk(B, S, H, D, **kw)
    got = np.asarray(wkv(r, k, v, w, u, chunk=chunk))
    to_k = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    want = np.asarray(
        wkv_ref(to_k(r), to_k(k), to_k(v), jnp.log(to_k(w)), u, n_heads=H)
        .reshape(B, H, S, D)
        .transpose(0, 2, 1, 3)
    )
    rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    return rel


@pytest.mark.parametrize("shape", [(2, 128, 2, 32), (1, 96, 3, 64), (1, 256, 1, 64)])
def test_kernel_matches_scan(shape):
    assert _kernel_vs_scan(*shape, chunk=32) < 1e-5


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_chunk_invariance(chunk):
    assert _kernel_vs_scan(1, 128, 2, 32, chunk) < 1e-5


def test_strong_decay_is_stable():
    """exp(L_{t-1}-L_j) form must survive decays ~ 0 (log w ~ -7)."""
    rel = _kernel_vs_scan(1, 128, 2, 32, 32, decay_lo=0.001, decay_hi=0.01)
    assert np.isfinite(rel) and rel < 1e-4


def test_model_chunked_matches_scan_oracle():
    """The jnp model path (wkv_chunked) equals the sequential semantics."""
    B, S, H, D = 2, 64, 2, 16
    r, k, v, w, u = _mk(B, S, H, D)
    a = np.asarray(wkv_chunked(r, k, v, w, u, chunk=16))
    b = np.asarray(wkv_scan_ref(r, k, v, w, u))
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_chunked_state_carry():
    """return_state continues exactly where the chunk left off."""
    B, S, H, D = 1, 64, 2, 16
    r, k, v, w, u = _mk(B, S, H, D)
    full = np.asarray(wkv_chunked(r, k, v, w, u, chunk=16))
    h1, st = wkv_chunked(
        r[:, :32], k[:, :32], v[:, :32], w[:, :32], u, chunk=16, return_state=True
    )
    h2 = wkv_chunked(
        r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, chunk=16, state=st
    )
    stitched = np.concatenate([np.asarray(h1), np.asarray(h2)], axis=1)
    np.testing.assert_allclose(stitched, full, rtol=2e-5, atol=2e-5)
