"""Manual-collective primitives: ring-overlap matmul and compressed psum
(subprocess with 4 fake devices; main test process keeps 1 device)."""
import json
import subprocess
import sys


_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.dist.collectives import overlap_all_gather_matmul, compressed_psum
from jax.experimental.shard_map import shard_map

mesh = jax.make_mesh((4,), ("model",), axis_types=(jax.sharding.AxisType.Auto,))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 64), jnp.float32)
w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32), jnp.float32) * 0.1

got = jax.jit(lambda x, w: overlap_all_gather_matmul(mesh, x, w))(x, w)
want = x @ w
err = float(jnp.max(jnp.abs(got - want)))

# the overlap schedule uses collective-permute, not all-gather
hlo = jax.jit(lambda x, w: overlap_all_gather_matmul(mesh, x, w)).lower(x, w).compile().as_text()
n_perm = hlo.count("collective-permute")
n_ag = sum(1 for l in hlo.splitlines() if " all-gather(" in l)

# compressed psum: sums per-device grads within int8 tolerance
g = jax.random.normal(jax.random.fold_in(key, 2), (4, 16), jnp.float32)
f = shard_map(lambda gi: compressed_psum(gi[0], "model", "int8"),
              mesh=mesh, in_specs=P("model", None), out_specs=P())
got_sum = f(g)
want_sum = g.sum(0)
rel = float(jnp.max(jnp.abs(got_sum - want_sum)) / jnp.max(jnp.abs(want_sum)))
print(json.dumps({"err": err, "n_perm": n_perm, "n_ag": n_ag, "psum_rel": rel}))
"""


def test_ring_matmul_and_compressed_psum_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd=".",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-4, out
    assert out["n_perm"] >= 1 and out["n_ag"] == 0, (
        "overlap schedule should replace all-gather with collective-permute",
        out,
    )
    assert out["psum_rel"] < 0.06, out
