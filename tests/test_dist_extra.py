"""Extra coverage for the dist seams: MoE parameter specs (EP and TP modes),
bubble_fraction edge cases, cache/batch spec corners, degenerate pipelines."""

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.plan import derive_plan
from repro.dist.pipeline import bubble_fraction, pipeline_forward
from repro.dist.sharding import Shardings
from repro.launch.mesh import make_pipeline_mesh


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


class Leaf:
    def __init__(self, shape):
        self.shape = shape


def _sh(arch, mesh_shape=None, **kw):
    mesh_shape = mesh_shape or {"data": 16, "model": 16}
    cfg = get_config(arch)
    plan = derive_plan(cfg, mesh_shape, **kw)
    return Shardings(FakeMesh(dict(mesh_shape)), plan, cfg), cfg, plan


def _path(*names):
    return [jtu.DictKey(n) for n in names]


# ---------------------------------------------------------------- MoE specs
def test_moe_tp_mode_shards_expert_ffn_width():
    # mixtral: 8 experts do not divide model=16, but moe_d_ff=14336 does ->
    # the planner falls back to TP inside each expert.
    sh, cfg, plan = _sh("mixtral-8x7b", batch=256, seq_len=4096)
    assert plan.moe_mode == "tp"
    w1 = sh.param_spec(
        _path("blocks", "stack", "ffn", "w1"), Leaf((32, 8, 4096, 14336))
    )
    assert w1[-1] == "model"  # column parallel on the expert ffn width
    w2 = sh.param_spec(
        _path("blocks", "stack", "ffn", "w2"), Leaf((32, 8, 14336, 4096))
    )
    assert w2[-2] == "model"  # row parallel on the same width


def test_moe_ep_w2_and_router():
    sh, cfg, plan = _sh("qwen3-moe-30b-a3b", batch=256, seq_len=4096)
    assert plan.moe_mode == "ep"
    w2 = sh.param_spec(
        _path("blocks", "stack", "ffn", "w2"), Leaf((48, 128, 768, 2048))
    )
    assert w2[1] == "model"  # experts sharded on the stacked leading dim
    router = sh.param_spec(
        _path("blocks", "stack", "ffn", "router"), Leaf((48, 2048, 128))
    )
    assert all(ax is None for ax in router)  # router stays replicated


def test_moe_ep_nondivisible_experts_dropped():
    # 128 experts % model=24 != 0: the safety net must drop the axis rather
    # than let GSPMD pad the expert dim.
    sh, cfg, plan = _sh(
        "qwen3-moe-30b-a3b", {"data": 2, "model": 24}, batch=96, seq_len=4096
    )
    w1 = sh.param_spec(
        _path("blocks", "stack", "ffn", "w1"), Leaf((48, 128, 2048, 768))
    )
    assert w1[1] is None


# ------------------------------------------------------- bubble_fraction edges
def test_bubble_fraction_single_stage():
    assert bubble_fraction(1, 1) == 0.0
    assert bubble_fraction(64, 1) == 0.0


def test_bubble_fraction_fewer_micro_than_stages():
    assert bubble_fraction(2, 4) == pytest.approx(3 / 5)
    assert bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert bubble_fraction(0, 4) == 1.0


def test_bubble_fraction_monotone_in_microbatches():
    vals = [bubble_fraction(m, 4) for m in (1, 2, 4, 8, 16, 64)]
    assert vals == sorted(vals, reverse=True)
    assert vals[-1] < 0.05  # deep microbatching amortizes the ramp


# ----------------------------------------------------------- spec corner cases
def test_param_spec_1d_replicated():
    sh, _, _ = _sh("qwen3-1.7b", batch=256, seq_len=4096)
    scale = sh.param_spec(
        _path("blocks", "stack", "attn", "ln", "scale"), Leaf((28, 2048))
    )
    assert all(ax is None for ax in scale)


def test_cache_heads_sharded_when_divisible():
    # model=4 divides n_kv_heads=8: prefer head sharding over seq sharding.
    sh, _, _ = _sh(
        "qwen3-1.7b", {"data": 4, "model": 4}, batch=128, seq_len=32768,
        training=False,
    )
    spec = sh.cache_spec(
        _path("layers", "stack", "attn", "k"), Leaf((28, 128, 32768, 8, 128))
    )
    assert spec[3] == "model" and spec[2] is None


def test_fit_handles_grouped_axes_and_unknown_axes():
    sh, _, _ = _sh("smollm-135m", batch=256, seq_len=4096)
    fitted = sh._fit(P(("data", "model"), None), (256, 64))
    assert fitted[0] == ("data", "model")
    assert sh._fit(P(("data", "model"), None), (100, 64))[0] is None
    assert sh._fit(P("pod", None), (64, 64))[0] is None  # axis not in mesh


def test_batch_axes_prefer_largest_fold():
    sh, _, plan = _sh("smollm-135m", batch=256, seq_len=4096)
    assert plan.dp_over_model
    assert sh.batch_axes_for(512) == ("data", "model")
    assert sh.batch_axes_for(48) == ("data",)  # 48 % 256 != 0, 48 % 16 == 0


# ------------------------------------------------- _fit safety-net logging
def test_fit_drop_logs_offending_dim_and_axis(caplog):
    sh, _, _ = _sh("smollm-135m", batch=256, seq_len=4096)
    with caplog.at_level("WARNING", logger="repro.dist.sharding"):
        fitted = sh._fit(P(None, "model"), (256, 100))  # 100 % 16 != 0
    assert fitted[1] is None
    assert any(
        "100" in rec.message and "model" in rec.message
        for rec in caplog.records
    ), caplog.records
    # deduped: the same (dim, axis) pair warns once
    n = len(caplog.records)
    sh._fit(P(None, "model"), (256, 100))
    assert len(caplog.records) == n


# -------------------------------------------------- degenerate pipeline (S=1)
def test_pipeline_single_stage_is_plain_forward():
    # make_pipeline_mesh on this host = a 1-stage ("pod",) mesh; the
    # schedule degenerates to one tick per microbatch, no permutes.
    mesh = make_pipeline_mesh()
    n = dict(mesh.shape)["pod"]
    w = jax.random.normal(jax.random.PRNGKey(0), (n, 8, 8)) * 0.3
    micro = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 8))
    # stage_fn receives its local leading-dim slice: (1, 8, 8) here
    pp = jax.jit(pipeline_forward(lambda wi, x: jnp.tanh(x @ wi[0]), mesh))
    got = pp(w, micro)
    ref = micro
    for i in range(n):
        ref = jnp.tanh(ref @ w[i])
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-6
