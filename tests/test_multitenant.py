"""Multi-tenant scheduling: priority classes, per-tenant fair shares,
SLO-aware chunk sizing, the SLO plan feedback, trace workloads and the
ServeArgs CLI record."""
import argparse
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_traces_bounded

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.launch.serve import ServeArgs, build_parser
from repro.serve import (
    Request,
    ServingEngine,
    WORKLOADS,
    make_trace,
    parse_mix,
    per_class_report,
)
from repro.serve.scheduler import Scheduler

MESH1 = {"data": 1, "model": 1}


def _sched(cfg, **kw):
    kw.setdefault("max_seq_len", 32)
    kw.setdefault("decode_batch", 2)
    kw.setdefault("block_size", 4)
    kw.setdefault("kv_dtype", "fp32")
    kw.setdefault("prefill_chunk", 4)
    serve = derive_serve_plan(cfg, MESH1, **kw)
    return Scheduler(serve), serve


def _drive(s, serve, token=7):
    s.admit(10**9)
    s.drain_copies()
    s._grow_for_decode()
    _, _, _, kinds = s._slab_view(serve.mixed_slab_width)
    s._slab_done(np.full((serve.decode_batch,), token, np.int64), kinds)


# ----------------------------------------------------------- admission policy
def test_priority_class_admission_order():
    """One free slot, two arrived waiters: the higher priority class is
    admitted first regardless of arrival order."""
    cfg = get_config("smollm-135m").reduced()
    s, serve = _sched(cfg, decode_batch=1)
    lo = Request(rid="lo", prompt=[1, 2, 3], max_new_tokens=2, arrival=0)
    hi = Request(
        rid="hi", prompt=[4, 5, 6], max_new_tokens=2, arrival=1, priority=5
    )
    s.submit(lo)
    s.submit(hi)
    s.admit(5)
    assert hi.state == "prefill" and lo.state == "waiting"


def test_tenant_fair_share_breaks_priority_ties():
    """Equal priority: the tenant holding fewer slots wins the free slot
    even when the loaded tenant's request arrived first."""
    cfg = get_config("smollm-135m").reduced()
    s, serve = _sched(cfg, decode_batch=3)
    a1 = Request(rid="a1", prompt=[1, 2], max_new_tokens=4, tenant="a")
    a2 = Request(rid="a2", prompt=[3, 4], max_new_tokens=4, tenant="a")
    s.submit(a1)
    s.submit(a2)
    s.admit(0)
    assert {a1.state, a2.state} == {"prefill"}
    a3 = Request(rid="a3", prompt=[5, 6], max_new_tokens=4, arrival=0, tenant="a")
    b1 = Request(rid="b1", prompt=[7, 8], max_new_tokens=4, arrival=1, tenant="b")
    s.submit(a3)
    s.submit(b1)
    s.admit(1)  # one slot left: tenant b (0 active) beats tenant a (2 active)
    assert b1.state == "prefill" and a3.state == "waiting"


def test_priority_eviction_and_no_livelock():
    """A senior (higher-priority) runner evicts a junior to grow; a junior
    must never evict a senior — it self-preempts instead."""
    cfg = get_config("smollm-135m").reduced()
    s, serve = _sched(cfg, decode_batch=2, block_size=2, max_seq_len=16)
    serve = dataclasses.replace(serve, n_blocks=1 + 6)
    s = Scheduler(serve)
    hi = Request(rid="hi", prompt=[1, 2, 3, 4], max_new_tokens=9, priority=5)
    lo = Request(rid="lo", prompt=[5, 6, 7, 8], max_new_tokens=9)
    s.submit(hi)
    s.submit(lo)
    for _ in range(40):
        if s.idle:
            break
        _drive(s, serve)
    assert s.n_evictions >= 1
    assert hi.t_done is not None and lo.t_done is not None
    # the high-priority request never lost its slot: one continuous run
    assert hi.t_done < lo.t_done or s.n_evictions == 0
    assert s.alloc.available == 6


def test_slo_chunk_sizing_throttles_sloless_prefills():
    """With an SLO'd prefill at risk (measured step time vs TTFT target),
    SLO-less prefills throttle to one block per step; the SLO'd request
    keeps the full slab width."""
    cfg = get_config("smollm-135m").reduced()
    s, serve = _sched(cfg, decode_batch=2, block_size=4, prefill_chunk=8,
                      max_seq_len=64)
    urgent = Request(
        rid="u", prompt=list(range(24)), max_new_tokens=2, slo_ttft_ms=1.0
    )
    bulk = Request(rid="b", prompt=list(range(24)), max_new_tokens=2)
    s.submit(urgent)
    s.submit(bulk)
    s.admit(0)
    assert not s._slo_pressure()  # no measured step time yet -> no pressure
    s.step_ms = 50.0  # measured steps are slow; 1ms TTFT is at risk
    assert s._slo_pressure()
    _, _, _, kinds = s._slab_view(serve.mixed_slab_width)
    assert kinds[urgent.slot] == 8  # full width
    assert kinds[bulk.slot] == 4  # throttled to one block
    s.step_ms = None
    _, _, _, kinds = s._slab_view(serve.mixed_slab_width)
    assert kinds[bulk.slot] == 8  # no pressure signal -> full width again


# -------------------------------------------------------------- plan feedback
def test_plan_slo_widens_slab_and_reins_in_gamma():
    cfg = get_config("smollm-135m")
    base = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=2048, draft="ngram")
    slo = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=2048, draft="ngram",
        slo_ttft_ms=1.0, typical_prompt_len=2048,
    )
    # a 1ms TTFT budget at ~0.3ms/step leaves ~3 steps for 2048 tokens
    assert slo.mixed_slab_width > base.mixed_slab_width
    assert slo.slo_ttft_ms == 1.0 and base.slo_ttft_ms is None
    # gamma under SLO: slack//2 - 1 at the derived batch
    assert slo.spec_len <= base.spec_len
    b64 = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=2048, decode_batch=64, draft="ngram"
    )
    s64 = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=2048, decode_batch=64, draft="ngram",
        slo_ttft_ms=200.0, typical_prompt_len=256,
    )
    assert b64.spec_len == 2  # slack 240/64 ~ 3.75 -> gamma 2
    assert s64.spec_len == 0  # slack//2 - 1 = 0 under a TTFT target
    # a loose SLO must not shrink an explicitly wider slab
    wide = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=2048, mixed_slab_width=512,
        slo_ttft_ms=10_000.0, typical_prompt_len=256,
    )
    assert wide.mixed_slab_width == 512


# ------------------------------------------------------------------ workloads
def test_parse_mix_and_classes():
    assert parse_mix("chat:4,summarize:2") == {"chat": 4, "summarize": 2}
    assert parse_mix("classify") == {"classify": 1}
    with pytest.raises(ValueError):
        parse_mix("nosuch:3")
    with pytest.raises(ValueError):
        parse_mix("")
    assert set(WORKLOADS) == {"chat", "summarize", "classify"}
    assert WORKLOADS["classify"].priority > WORKLOADS["chat"].priority
    assert WORKLOADS["summarize"].slo_ttft_ms is None


def test_make_trace_shapes_and_tenancy():
    cfg = get_config("smollm-135m").reduced()
    reqs = make_trace(
        cfg, {"chat": 3, "classify": 3}, tenants=2, system_prompt_len=16,
        stagger=2, seed=0, max_tokens=64,
    )
    assert len(reqs) == 6
    assert [r.arrival for r in reqs] == [0, 2, 4, 6, 8, 10]
    by_tenant = {}
    for r in reqs:
        by_tenant.setdefault(r.tenant, []).append(r)
        wc = WORKLOADS[r.tag]
        assert r.priority == wc.priority and r.slo_ttft_ms == wc.slo_ttft_ms
        assert len(r.prompt) + r.max_new_tokens <= 64
    assert set(by_tenant) == {"tenant0", "tenant1"}
    for rs in by_tenant.values():
        sys0 = rs[0].prompt[:16]
        assert all(r.prompt[:16] == sys0 for r in rs)  # shared system prompt
    # same seed -> same trace (replayable); different seed -> different
    again = make_trace(
        cfg, {"chat": 3, "classify": 3}, tenants=2, system_prompt_len=16,
        stagger=2, seed=0, max_tokens=64,
    )
    assert [r.prompt for r in again] == [r.prompt for r in reqs]


def test_trace_replay_engine_parity_and_report(key):
    """End-to-end trace replay on the real engine: byte parity sharing on
    vs off, prefix hits from the shared system prompts, per-class report."""
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=4, seq_len=16, training=False)
    serve = derive_serve_plan(
        cfg, MESH1, max_seq_len=64, decode_batch=4, block_size=8,
        kv_dtype="fp32", prefill_chunk=8,
    )
    from repro.models.params import init_params

    params = init_params(key, cfg, plan, dtype=jnp.float32)
    mix = {"chat": 3, "classify": 2}

    def trace():
        return make_trace(cfg, mix, tenants=2, system_prompt_len=24,
                          stagger=1, seed=3, max_tokens=64)

    outs = {}
    for sharing in (True, False):
        eng = ServingEngine(
            params, cfg, plan,
            dataclasses.replace(serve, prefix_sharing=sharing),
        )
        outs[sharing] = eng.run(trace())
        if sharing:
            summ = eng.summary()
            assert_traces_bounded(summ["traces"])
            assert summ["prefix"]["hits"] > 0
            assert set(summ["tenants"]) == {"tenant0", "tenant1"}
            report = per_class_report(eng.sched.finished)
            assert set(report) == set(mix)
            assert all(v["count"] == mix[k] for k, v in report.items())
    assert outs[True] == outs[False]


# ---------------------------------------------------------------- ServeArgs
def test_serve_args_maps_one_to_one_onto_plan_overrides():
    ns = build_parser().parse_args(
        [
            "--arch", "smollm-135m", "--fix-batch", "--batch", "4",
            "--max-seq", "128", "--slab-width", "16", "--pages-per-tile", "2",
            "--no-fused", "--kv-dtype", "int8", "--draft", "ngram",
            "--spec-len", "2", "--no-prefix-sharing", "--slo-ttft-ms", "250",
            "--deadline-ms", "1500", "--retry-limit", "2", "--stall-limit", "64",
        ]
    )
    a = ServeArgs.from_namespace(ns)
    ov = a.plan_overrides()
    assert ov == {
        "max_seq_len": 128, "decode_batch": 4, "prefill_chunk": None,
        "mixed_slab_width": 16, "pages_per_tile": 2, "fused_attention": False,
        "kv_dtype": "int8", "draft": "ngram", "spec_len": 2,
        "prefix_sharing": False, "slo_ttft_ms": 250.0,
        "typical_prompt_len": 32, "rolled_steps": None,
        "deadline_ms": 1500.0, "retry_limit": 2, "stall_limit": 64,
    }
    cfg = get_config("smollm-135m")
    sp = derive_serve_plan(cfg, MESH1, TPU_V5E, **ov)
    assert sp.decode_batch == 4 and sp.kv_dtype == "int8"
    assert not sp.prefix_sharing and sp.slo_ttft_ms == 250.0
    assert sp.mixed_slab_width == 16 and not sp.fused_attention
    assert sp.deadline_ms == 1500.0 and sp.retry_limit == 2
    assert sp.stall_limit == 64


def test_serve_args_old_spellings_and_trace_flags():
    # every pre-existing flag spelling still parses
    ns = build_parser().parse_args(
        [
            "--arch", "smollm-135m", "--engine", "eager", "--batch", "2",
            "--requests", "5", "--prompt-len", "16", "--gen", "4",
            "--stagger", "3", "--prefill-chunk", "8",
        ]
    )
    a = ServeArgs.from_namespace(ns)
    assert (a.engine, a.batch, a.requests, a.prompt_len, a.gen, a.stagger) == (
        "eager", 2, 5, 16, 4, 3
    )
    assert a.prefill_chunk == 8 and a.trace is None
    # new trace flags
    ns2 = build_parser().parse_args(
        ["--arch", "smollm-135m", "--trace", "chat:2,classify:1",
         "--tenant-mix", "3"]
    )
    a2 = ServeArgs.from_namespace(ns2)
    assert a2.trace == "chat:2,classify:1" and a2.tenant_mix == 3
    cfg = get_config("smollm-135m").reduced()
    reqs = a2.request_stream(cfg)
    assert len(reqs) == 3 and len({r.tenant for r in reqs}) == 3


def test_request_new_fields_are_keyword_only():
    with pytest.raises(TypeError):
        Request("r", [1, 2], 4, 0, "tenant")  # tenant not positional
    r = Request(rid="r", prompt=[1, 2], max_new_tokens=4, tenant="t",
                priority=3, slo_ttft_ms=50.0, tag="chat")
    assert (r.tenant, r.priority, r.slo_ttft_ms, r.tag) == ("t", 3, 50.0, "chat")
    d = Request(rid="d", prompt=[1], max_new_tokens=1)
    assert (d.tenant, d.priority, d.slo_ttft_ms, d.tag) == ("default", 0, None, "")
