"""Speculative decoding: greedy-token parity for ANY draft source (drafts
change speed, never tokens), slab-native verification through the one jitted
step, length-vector rollback, plan-derived draft depth, and the scheduler
edge cases speculation stresses (mid-speculation eviction, slot reuse after
full rejection, slab-width degradation)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan
from repro.serve import Request, ServingEngine, greedy_generate, make_draft_source
from repro.serve.speculative import ModelDraft, NGramDraft, prompt_lookup

MESH1 = {"data": 1, "model": 1}


def _setup(key, arch="smollm-135m", **serve_kw):
    cfg = get_config(arch).reduced()
    plan = derive_plan(cfg, MESH1, batch=4, seq_len=16, training=False)
    serve_kw.setdefault("max_seq_len", 64)
    serve_kw.setdefault("decode_batch", 4)
    serve_kw.setdefault("block_size", 8)
    serve_kw.setdefault("kv_dtype", "fp32")
    serve_kw.setdefault("prefill_chunk", 8)
    serve = derive_serve_plan(cfg, MESH1, **serve_kw)
    from repro.models.params import init_params

    params = init_params(key, cfg, plan, dtype=jnp.float32)
    return cfg, plan, serve, params


def _oracle(params, cfg, plan, prompt, gen):
    out = greedy_generate(
        params, cfg, plan, {"tokens": jnp.asarray(prompt)[None]},
        n_steps=gen, cache_len=len(prompt) + gen, cache_dtype=jnp.float32,
    )
    return list(np.asarray(out)[0])


def _mixed_prompts(cfg, seed=0, lengths=(5, 8, 12, 12, 3, 9)):
    """Half random, half repetitive (so prompt-lookup actually fires)."""
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in lengths]
    for i in range(1, len(prompts), 2):
        pat = prompts[i][:3]
        prompts[i] = (pat * len(prompts[i]))[: len(prompts[i])]
    return prompts


def _self_draft(cfg, serve, params):
    """The target drafting for itself: acceptance == 1, the full-accept path."""
    base = cfg.name[: -len("-reduced")]
    return make_draft_source(base, cfg, serve, params=params, reduced=True)


def _garbage_draft(cfg, serve):
    """Independent random weights: exact parity whatever they propose."""
    base = cfg.name[: -len("-reduced")]
    return make_draft_source(base, cfg, serve, seed=123, reduced=True)


class _OffByOneDraft:
    """Adversarial source: proposes (last+1+i) mod V — random-init targets
    collapse to repeat-token attractors, so these are reliably rejected and
    the rollback path runs every single step."""

    def __init__(self, vocab):
        self.vocab = vocab

    def propose(self, asks):
        return {
            rid: [(seq[-1] + 1 + i) % self.vocab for i in range(n)]
            for rid, seq, n in asks
        }


# ---------------------------------------------------------------- unit level
def test_prompt_lookup_copies_after_last_match():
    assert prompt_lookup([1, 2, 3, 9, 1, 2, 3], 4) == [9, 1, 2, 3]
    # longest n-gram wins; most recent occurrence wins
    assert prompt_lookup([5, 1, 2, 7, 1, 2, 8, 1, 2], 1) == [8]
    assert prompt_lookup([1, 2, 3, 4], 3) == []  # nothing recurs
    # a tail-adjacent match only has a truncated window: an earlier
    # occurrence with the full n tokens of continuation is preferred
    assert prompt_lookup([4, 4, 4], 2) == [4, 4]
    # ... and the truncated draft is still better than none
    assert prompt_lookup([4, 4], 2) == [4]
    # a loop whose earlier occurrence has room yields the full depth
    assert prompt_lookup([1, 2, 3, 1, 2, 3, 1, 2], 3) == [3, 1, 2]


# ----------------------------------------------------------- tentpole parity
@pytest.mark.parametrize("gamma", [1, 2, 4])
def test_spec_parity_ngram_staggered(key, gamma):
    """Staggered mixed-length stream with prompt-lookup drafting at every
    gamma: byte-identical to the eager greedy path, ONE trace of the one
    unified step (no retrace per gamma — depth varies only in `kinds`
    values)."""
    cfg, plan, serve, params = _setup(key, spec_len=gamma, draft="ngram")
    prompts = _mixed_prompts(cfg)
    reqs = [
        Request(rid=f"r{i}", prompt=p, max_new_tokens=6, arrival=2 * i)
        for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve, draft=NGramDraft())
    got = engine.run(reqs)
    for i, p in enumerate(prompts):
        want = _oracle(params, cfg, plan, p, 6)
        assert got[f"r{i}"] == want, (gamma, i, got[f"r{i}"], want)
    assert engine.trace_counts == {"step": 1}
    assert engine.stats["draft_rows"] > 0  # speculation actually engaged


@pytest.mark.parametrize("gamma", [1, 2, 4])
def test_spec_parity_model_draft_full_accept(key, gamma):
    """Self-drafting oracle (drafter == target): every draft accepted, and
    tokens are still byte-identical — the accept path changes speed only.
    The drafter's own step traces exactly once too."""
    cfg, plan, serve, params = _setup(key, spec_len=gamma, draft="smollm-135m")
    prompts = _mixed_prompts(cfg, seed=1, lengths=(5, 9, 12))
    reqs = [
        Request(rid=f"a{i}", prompt=p, max_new_tokens=7) for i, p in enumerate(prompts)
    ]
    draft = _self_draft(cfg, serve, params)
    engine = ServingEngine(params, cfg, plan, serve, draft=draft)
    got = engine.run(reqs)
    for i, p in enumerate(prompts):
        assert got[f"a{i}"] == _oracle(params, cfg, plan, p, 7)
    s = engine.summary()
    assert s["spec"]["acceptance_rate"] == 1.0
    assert s["spec"]["tokens_per_spec_step"] > 1.0
    assert engine.trace_counts == {"step": 1}
    assert draft.trace_counts == {"draft_step": 1}


def test_spec_parity_model_draft_independent_weights(key):
    """A drafter with its own (differently seeded) weights: tokens are
    byte-identical to the oracle whatever it proposes — acceptance is a
    speed observation, never a correctness input."""
    cfg, plan, serve, params = _setup(key, spec_len=2, draft="smollm-135m")
    prompts = _mixed_prompts(cfg, seed=2, lengths=(6, 11, 4))
    reqs = [
        Request(rid=f"g{i}", prompt=p, max_new_tokens=6) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(
        params, cfg, plan, serve, draft=_garbage_draft(cfg, serve)
    )
    got = engine.run(reqs)
    for i, p in enumerate(prompts):
        assert got[f"g{i}"] == _oracle(params, cfg, plan, p, 6)
    assert engine.stats["draft_rows"] > 0


def test_spec_parity_full_rejection_rollback(key):
    """Adversarial drafts rejected at row 0 every step: pure rollback —
    lens retreats past every draft row, and emitted tokens stay exact."""
    cfg, plan, serve, params = _setup(key, spec_len=3)
    prompts = _mixed_prompts(cfg, seed=2, lengths=(6, 11, 4))
    reqs = [
        Request(rid=f"x{i}", prompt=p, max_new_tokens=6) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(
        params, cfg, plan, serve, draft=_OffByOneDraft(cfg.vocab_size)
    )
    got = engine.run(reqs)
    for i, p in enumerate(prompts):
        assert got[f"x{i}"] == _oracle(params, cfg, plan, p, 6)
    s = engine.summary()
    assert s["spec"]["draft_rows"] > 0
    assert s["spec"]["acceptance_rate"] == 0.0
    assert s["spec"]["tokens_per_spec_step"] == 1.0  # rollback to plain pace


def test_spec_swa_wraparound_parity(key):
    """Sliding-window arch past its window: draft rows land beyond the
    window boundary and the kernel's per-row window mask must keep parity."""
    cfg, plan, serve, params = _setup(key, arch="mixtral-8x7b", spec_len=2)
    assert cfg.sliding_window == 16
    prompts = _mixed_prompts(cfg, seed=5, lengths=(20, 7, 25))
    reqs = [
        Request(rid=f"w{i}", prompt=p, max_new_tokens=8)
        for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve, draft=NGramDraft())
    got = engine.run(reqs)
    for i, p in enumerate(prompts):
        assert got[f"w{i}"] == _oracle(params, cfg, plan, p, 8)
    assert engine.trace_counts == {"step": 1}


def test_spec_int8_pages_match_spec_off(key):
    """Int8 KV pages: speculation must reproduce the spec-off engine's
    tokens exactly (draft rows quantize into the pool the same way the
    serial path would have)."""
    cfg, plan, serve, params = _setup(key, kv_dtype="int8", spec_len=2)
    prompts = _mixed_prompts(cfg, seed=3, lengths=(6, 9, 6))
    reqs = lambda pre: [
        Request(rid=f"{pre}{i}", prompt=p, max_new_tokens=5)
        for i, p in enumerate(prompts)
    ]
    want = ServingEngine(params, cfg, plan, serve).run(reqs("q"))
    got = ServingEngine(
        params, cfg, plan, serve, draft=_self_draft(cfg, serve, params)
    ).run(reqs("q"))
    assert got == want


def test_spec_gather_fallback_matches_fused(key):
    """Both attention engines verify the same slab: identical tokens."""
    cfg, plan, serve, params = _setup(key, spec_len=2)
    prompts = _mixed_prompts(cfg, seed=6, lengths=(9, 9, 9))
    reqs = lambda: [
        Request(rid=f"f{i}", prompt=p, max_new_tokens=5)
        for i, p in enumerate(prompts)
    ]
    fused = ServingEngine(
        params, cfg, plan, serve, fused=True, draft=NGramDraft()
    )
    fallback = ServingEngine(
        params, cfg, plan, serve, fused=False, draft=NGramDraft()
    )
    assert fused.run(reqs()) == fallback.run(reqs())


# ------------------------------------------------- scheduler edge cases
def test_spec_eviction_mid_speculation_preserves_tokens(key):
    """A pool too small for the stream forces recompute-preemption while
    slots hold in-flight draft rows; evicted requests still return
    oracle-exact tokens and the drafter state self-heals."""
    cfg, plan, serve, params = _setup(
        key, decode_batch=2, block_size=2, prefill_chunk=4, max_seq_len=16,
        spec_len=2,
    )
    serve = dataclasses.replace(serve, n_blocks=1 + 8)
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(0, cfg.vocab_size, 4)) for _ in range(2)]
    reqs = [
        Request(rid=f"e{i}", prompt=p, max_new_tokens=9) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(
        params, cfg, plan, serve, draft=_self_draft(cfg, serve, params)
    )
    got = engine.run(reqs)
    assert engine.sched.n_evictions >= 1
    for i, p in enumerate(prompts):
        assert got[f"e{i}"] == _oracle(params, cfg, plan, p, 9)


def test_spec_slot_reuse_after_full_rejection(key):
    """More requests than slots + a drafter whose every draft is rejected:
    completed slots recycle cleanly (no stale draft rows leak into the next
    occupant) and late requests still match the oracle."""
    cfg, plan, serve, params = _setup(key, decode_batch=2, spec_len=2)
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size, 7)) for _ in range(5)]
    reqs = [
        Request(rid=f"s{i}", prompt=p, max_new_tokens=4) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(
        params, cfg, plan, serve, draft=_OffByOneDraft(cfg.vocab_size)
    )
    got = engine.run(reqs)
    assert len(got) == 5
    for i, p in enumerate(prompts):
        assert got[f"s{i}"] == _oracle(params, cfg, plan, p, 4)


def test_spec_degrades_to_plain_decode_when_slab_too_narrow(key):
    """gamma+1 > mixed_slab_width must degrade to plain decode, not
    deadlock: a slab of width 1 has no room for draft rows, so the engine
    never asks the drafter and the stream still drains with exact tokens."""
    cfg, plan, serve, params = _setup(
        key, prefill_chunk=1, mixed_slab_width=1, spec_len=4
    )
    assert serve.spec_len == 0  # the plan already clamps gamma to the slab
    serve = dataclasses.replace(serve, spec_len=4)  # hand-built hostile plan
    prompts = _mixed_prompts(cfg, seed=4, lengths=(4, 6))
    reqs = [
        Request(rid=f"n{i}", prompt=p, max_new_tokens=4) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve, draft=NGramDraft())
    got = engine.run(reqs)
    assert engine.stats["draft_rows"] == 0  # degraded: no speculation at all
    for i, p in enumerate(prompts):
        assert got[f"n{i}"] == _oracle(params, cfg, plan, p, 4)


def test_spec_partial_slab_room_truncates_gamma(key):
    """gamma larger than the slab leaves W-1 draft rows, not a deadlock."""
    cfg, plan, serve, params = _setup(
        key, prefill_chunk=3, mixed_slab_width=3, spec_len=8
    )
    assert serve.spec_len == 2  # clamped to W - 1
    prompts = _mixed_prompts(cfg, seed=7, lengths=(5, 5))
    reqs = [
        Request(rid=f"t{i}", prompt=p, max_new_tokens=6) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(
        params, cfg, plan, serve, draft=_self_draft(cfg, serve, params)
    )
    got = engine.run(reqs)
    for i, p in enumerate(prompts):
        assert got[f"t{i}"] == _oracle(params, cfg, plan, p, 6)
    assert engine.stats["draft_rows"] > 0


# ---------------------------------------------------------- plan derivation
def test_serve_plan_spec_len_from_roofline_slack():
    cfg = get_config("smollm-135m")
    # no draft source named -> no speculation
    off = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=2048)
    assert off.spec_len == 0 and off.draft == "none"
    # small decode batch = bandwidth-bound decode = compute slack -> gamma > 0
    small = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=2048, decode_batch=4, draft="ngram"
    )
    assert small.spec_len > 0
    # at/above the machine-balance batch the step is compute-bound: gamma = 0
    big = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=2048, decode_batch=4096, draft="ngram"
    )
    assert big.spec_len == 0
    # gamma never blows the slab width
    narrow = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=2048, decode_batch=4,
        prefill_chunk=2, mixed_slab_width=2, draft="ngram",
    )
    assert narrow.spec_len <= narrow.mixed_slab_width - 1 == 1
    # explicit override still clamps
    forced = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=2048, decode_batch=4,
        mixed_slab_width=4, draft="smollm-135m", spec_len=64,
    )
    assert forced.spec_len == 3 and forced.draft == "smollm-135m"
    assert "spec_len" in forced.to_record()


# -------------------------------------------------------- stats and latency
def test_engine_counts_accepted_tokens_not_slab_rows(key):
    """Throughput counts emitted output tokens: prompt rows live in
    prefill_tokens, rejected draft rows are invisible, and the per-request
    latency percentiles ride the summary."""
    cfg, plan, serve, params = _setup(key, spec_len=2)
    prompts = _mixed_prompts(cfg, seed=8, lengths=(6, 9, 5))
    reqs = [
        Request(rid=f"c{i}", prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(
        params, cfg, plan, serve, draft=_OffByOneDraft(cfg.vocab_size)
    )
    got = engine.run(reqs)
    s = engine.summary()
    n_out = sum(len(v) for v in got.values())
    assert s["generated_tokens"] == n_out == 3 * 5
    assert s["prefill_tokens"] == sum(len(p) for p in prompts)
    assert s["tok_per_s"] == pytest.approx(n_out / s["wall_s"])
    for pkey in ("latency_s", "ttft_s"):
        pct = s[pkey]
        assert pct and pct["p50"] <= pct["p90"] <= pct["p99"]
    assert s["ttft_s"]["p50"] <= s["latency_s"]["p50"]


def test_model_draft_caps_proposals_to_target_vocab(key):
    """A drafter with a bigger vocab than the target must stop at the first
    unverifiable id instead of handing the target an out-of-range token."""
    cfg, plan, serve, params = _setup(key, spec_len=3)
    base = cfg.name[: -len("-reduced")]
    draft = make_draft_source(base, cfg, serve, seed=5, reduced=True)
    assert isinstance(draft, ModelDraft)
    draft.target_vocab = 1  # pathological target: only token 0 verifiable
    out = draft.propose([("x", [0, 0, 0], 3)])
    assert all(t == 0 for t in out["x"])