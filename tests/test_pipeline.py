"""Pipeline parallelism over the pod axis: correctness vs sequential
execution on a multi-device mesh (subprocess: tests keep 1 device)."""
import json
import subprocess
import sys

from repro.dist.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 2) == 0.5
    assert abs(bubble_fraction(16, 2) - 1 / 17) < 1e-9
    assert bubble_fraction(8, 1) == 0.0


_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.dist.pipeline import pipeline_forward

mesh = jax.make_mesh((4,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
D = 16
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (4, D, D), jnp.float32) * 0.3  # one layer per stage
micro = jax.random.normal(jax.random.fold_in(key, 1), (6, 2, D), jnp.float32)

def stage_fn(wi, x):
    return jnp.tanh(x @ wi[0])  # wi: this stage's (1, D, D) leading-dim slice

pp = jax.jit(pipeline_forward(stage_fn, mesh, axis="pod"))
got = pp(w, micro)

# sequential oracle
x = micro
for i in range(4):
    x = jnp.tanh(x @ w[i])
err = float(jnp.max(jnp.abs(got - x)))
n_perm = jax.jit(pipeline_forward(stage_fn, mesh)).lower(w, micro).compile().as_text().count("collective-permute")
print(json.dumps({"err": err, "n_perm": n_perm}))
"""


def test_pipeline_matches_sequential_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd=".",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["err"] < 1e-5, f"pipeline output diverges: {out}"
    assert out["n_perm"] >= 1  # the stage handoff is a collective-permute
