"""CAT planner: design-case reproduction + property tests."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.configs import ALL_ARCHS, get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import (
    PRG_MAX_PIPELINE_DEPTH,
    SPATIAL,
    TEMPORAL,
    derive_plan,
    design_case_vck5000,
)
from repro.core.pu import derive_pu_family, is_compute_bound, pick_pu, solve_mm_tiles


class TestDesignCase:
    """Paper §V.B BERT-Base walk-through on VCK5000 numbers."""

    def test_factor1_matches_paper(self):
        dc = design_case_vck5000()
        # paper reports Factor1 ~= 1.5 (4 LBs of 256x768x768 over the engine)
        assert 1.3 <= dc["factor1"] <= 1.6
        assert dc["factor1"] < PRG_MAX_PIPELINE_DEPTH

    def test_factor2_matches_paper(self):
        dc = design_case_vck5000()
        # paper reports 7.5625 MB < 23.9 MB SRAM
        assert 7.0 <= dc["factor2_mb"] <= 8.5
        assert dc["factor2_mb"] < dc["buffer_budget_mb"]

    def test_p_atb_is_4(self):
        assert design_case_vck5000()["p_atb"] == 4

    def test_fully_pipelined_mode_selected(self):
        assert design_case_vck5000()["mode"] == SPATIAL


class TestPUFamily:
    def test_three_specs(self):
        fam = derive_pu_family(TPU_V5E)
        assert set(fam) == {"LARGE", "STANDARD", "SMALL"}
        assert fam["LARGE"].vmem_bytes <= TPU_V5E.vmem_bytes
        # LARGE and STANDARD must be compute-bound (Eq. 4')
        assert is_compute_bound(fam["LARGE"], TPU_V5E)
        assert is_compute_bound(fam["STANDARD"], TPU_V5E)

    def test_mxu_alignment(self):
        for s in solve_mm_tiles(TPU_V5E):
            assert s.block_m % TPU_V5E.mxu_dim == 0
            assert s.block_n % TPU_V5E.mxu_dim == 0

    def test_small_model_gets_small_pu(self):
        little = pick_pu(197, 64, 768)
        big = pick_pu(8192, 8192, 8192)
        assert little.block_n <= big.block_n

    @given(
        m=st.integers(1, 1 << 15),
        n=st.integers(1, 1 << 15),
        k=st.integers(1, 1 << 15),
    )
    @settings(max_examples=50, deadline=None)
    def test_pick_pu_total(self, m, n, k):
        s = pick_pu(m, n, k)
        assert s.vmem_bytes <= TPU_V5E.vmem_bytes


MESHES = [
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
    {"data": 1, "model": 1},
    {"data": 4, "model": 8},
]


class TestDerivePlan:
    @pytest.mark.parametrize("arch", ALL_ARCHS)
    @pytest.mark.parametrize("mesh", MESHES[:2], ids=["single", "multi"])
    def test_plan_derives_for_all(self, arch, mesh):
        cfg = get_config(arch)
        plan = derive_plan(cfg, mesh, batch=256, seq_len=4096)
        assert plan.mha.mode in (SPATIAL, TEMPORAL)
        assert plan.microbatches >= 1
        assert plan.p_atb >= 1
        # head shards must divide heads
        if plan.head_shards > 1:
            assert cfg.n_heads % plan.head_shards == 0

    def test_spatial_requires_divisibility(self):
        cfg = get_config("smollm-135m")  # 9 heads % 16 != 0
        plan = derive_plan(cfg, MESHES[0], batch=256, seq_len=4096)
        assert plan.mha.mode == TEMPORAL
        cfg2 = get_config("qwen3-1.7b")  # 16 heads % 16 == 0, Factor1 < depth
        plan2 = derive_plan(cfg2, MESHES[0], batch=256, seq_len=4096)
        assert plan2.mha.mode == SPATIAL

    def test_factor1_rule_picks_temporal_for_huge_dense(self):
        """Paper Eq.5/6 (§Perf iteration 6): Factor1 >= PRG depth -> mode (2)
        serial/FSDP, even though TP divisibility holds (123B dense)."""
        cfg = get_config("mistral-large-123b")
        plan = derive_plan(cfg, MESHES[0], batch=256, seq_len=4096)
        assert plan.mha.factor1 >= 4
        assert plan.mha.mode == TEMPORAL
        assert plan.dp_over_model and plan.zero_weights
        # inference keeps the spatial/TP plan (latency-optimal weights-resident)
        plan_inf = derive_plan(
            cfg, MESHES[0], batch=128, seq_len=32768, training=False
        )
        assert plan_inf.mha.mode == SPATIAL

    def test_temporal_folds_model_into_dp(self):
        cfg = get_config("smollm-135m")
        plan = derive_plan(cfg, MESHES[0], batch=256, seq_len=4096)
        assert plan.dp_over_model  # 256 % (16*16) == 0

    def test_moe_modes(self):
        p128 = derive_plan(get_config("qwen3-moe-30b-a3b"), MESHES[0], batch=256, seq_len=4096)
        assert p128.moe_mode == "ep"  # 128 experts / 16
        p8 = derive_plan(get_config("mixtral-8x7b"), MESHES[0], batch=256, seq_len=4096)
        assert p8.moe_mode == "tp"  # 8 experts < 16 but d_ff 14336 % 16 == 0

    def test_seq_shard_for_long_context(self):
        cfg = get_config("rwkv6-1.6b")
        plan = derive_plan(cfg, MESHES[0], batch=1, seq_len=524288, training=False)
        assert plan.seq_shard

    @given(
        batch=st.sampled_from([1, 8, 32, 128, 256, 512]),
        seq=st.sampled_from([128, 2048, 4096, 32768]),
        arch=st.sampled_from(list(ALL_ARCHS)),
    )
    @settings(max_examples=40, deadline=None)
    def test_plan_is_deterministic_and_total(self, batch, seq, arch):
        cfg = get_config(arch)
        p1 = derive_plan(cfg, MESHES[0], batch=batch, seq_len=seq)
        p2 = derive_plan(cfg, MESHES[0], batch=batch, seq_len=seq)
        assert p1 == p2  # pure function of its inputs
        assert batch % p1.microbatches == 0 or p1.microbatches == 1
