"""Continuous-batching engine: greedy-token parity with the eager path,
ONE static-shape unified mixed step under request churn, plan-driven knobs,
sharded serving, and the no-dense-gather guarantee of the fused kernel."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_traces_bounded

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan, serve_feasible
from repro.serve import Request, ServingEngine, greedy_generate

MESH1 = {"data": 1, "model": 1}


def _setup(key, arch="smollm-135m", **serve_kw):
    cfg = get_config(arch).reduced()
    plan = derive_plan(cfg, MESH1, batch=4, seq_len=16, training=False)
    serve_kw.setdefault("max_seq_len", 64)
    serve_kw.setdefault("decode_batch", 4)
    serve_kw.setdefault("block_size", 8)
    serve_kw.setdefault("kv_dtype", "fp32")
    serve_kw.setdefault("prefill_chunk", 8)
    serve = derive_serve_plan(cfg, MESH1, **serve_kw)
    from repro.models.params import init_params

    params = init_params(key, cfg, plan, dtype=jnp.float32)
    return cfg, plan, serve, params


def _oracle(params, cfg, plan, prompt, gen):
    """Per-request eager greedy decode (B=1), fp32 cache."""
    out = greedy_generate(
        params, cfg, plan, {"tokens": jnp.asarray(prompt)[None]},
        n_steps=gen, cache_len=len(prompt) + gen, cache_dtype=jnp.float32,
    )
    return list(np.asarray(out)[0])


def test_engine_matches_greedy_generate_staggered(key):
    """Mixed prompt lengths + staggered arrivals through the scheduler must
    produce exactly the eager path's greedy tokens — and ONE trace of the
    single unified step, however the stream churns (the no-retrace +
    one-step-kind acceptance bar)."""
    cfg, plan, serve, params = _setup(key)
    rng = np.random.default_rng(0)
    lengths = [5, 8, 12, 12, 3, 9]
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in lengths]
    reqs = [
        Request(rid=f"r{i}", prompt=p, max_new_tokens=6, arrival=2 * i)
        for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve)
    assert engine.fused  # single-device default is the Pallas kernel path
    got = engine.run(reqs)
    for i, p in enumerate(prompts):
        want = _oracle(params, cfg, plan, p, 6)
        assert got[f"r{i}"] == want, (i, got[f"r{i}"], want)
    assert_traces_bounded(engine.trace_counts)
    assert engine.summary()["mean_occupancy"] > 0.3


def test_engine_swa_wraparound_matches_oracle(key):
    """Sliding-window arch (mixtral-reduced, window 16) with contexts past
    the window: the kernel's window masking must skip the slot's own oldest
    pages and still match the eager path exactly."""
    cfg, plan, serve, params = _setup(key, arch="mixtral-8x7b")
    assert cfg.sliding_window == 16
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (20, 7, 25)]
    reqs = [
        Request(rid=f"w{i}", prompt=p, max_new_tokens=8)
        for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve)
    got = engine.run(reqs)
    for i, p in enumerate(prompts):
        assert got[f"w{i}"] == _oracle(params, cfg, plan, p, 8)
    assert_traces_bounded(engine.trace_counts)


def test_engine_slot_reuse_keeps_parity(key):
    """More requests than slots: completed slots are reused in place
    (padding-free) and late requests still match the oracle."""
    cfg, plan, serve, params = _setup(key, decode_batch=2)
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size, 7)) for _ in range(5)]
    reqs = [
        Request(rid=f"s{i}", prompt=p, max_new_tokens=4) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve)
    got = engine.run(reqs)
    assert len(got) == 5
    for i, p in enumerate(prompts):
        assert got[f"s{i}"] == _oracle(params, cfg, plan, p, 4)
    assert_traces_bounded(engine.trace_counts)


def test_engine_eviction_preserves_tokens(key):
    """A pool too small for the whole stream forces recompute-preemption;
    evicted requests still return oracle-exact tokens."""
    cfg, plan, serve, params = _setup(
        key, decode_batch=2, block_size=2, prefill_chunk=4, max_seq_len=16
    )
    serve = dataclasses.replace(serve, n_blocks=1 + 8)
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(0, cfg.vocab_size, 4)) for _ in range(2)]
    reqs = [
        Request(rid=f"e{i}", prompt=p, max_new_tokens=9) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve)
    got = engine.run(reqs)
    assert engine.sched.n_evictions >= 1
    for i, p in enumerate(prompts):
        assert got[f"e{i}"] == _oracle(params, cfg, plan, p, 9)


def test_engine_int8_kv_runs_and_is_deterministic(key):
    cfg, plan, serve, params = _setup(key, kv_dtype="int8")
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, 6)) for _ in range(3)]

    def run_once():
        engine = ServingEngine(params, cfg, plan, serve)
        return engine.run(
            Request(rid=f"q{i}", prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)
        )

    a, b = run_once(), run_once()
    assert a == b
    assert all(len(v) == 5 for v in a.values())


def test_engine_fallback_gather_path_matches_fused(key):
    """The jnp gather fallback (model-sharded meshes) and the fused kernel
    are the same op: identical greedy tokens, still one step kind."""
    cfg, plan, serve, params = _setup(key)
    rng = np.random.default_rng(6)
    prompts = [list(rng.integers(0, cfg.vocab_size, 9)) for _ in range(3)]
    reqs = lambda: (
        Request(rid=f"f{i}", prompt=p, max_new_tokens=5)
        for i, p in enumerate(prompts)
    )
    fused = ServingEngine(params, cfg, plan, serve, fused=True)
    fallback = ServingEngine(params, cfg, plan, serve, fused=False)
    assert fused.run(reqs()) == fallback.run(reqs())
    assert_traces_bounded(fallback.trace_counts)


def test_engine_sharded_mesh_matches_single(key):
    """Decode through dist.Shardings on whatever host mesh exists (CI runs
    4 fake devices -> (data=1, model=4)): tokens must equal the unsharded
    engine's."""
    from repro.dist.sharding import Shardings
    from repro.launch.mesh import make_host_mesh

    cfg, plan_1, serve, params = _setup(key)
    mesh = make_host_mesh()
    plan = derive_plan(cfg, dict(mesh.shape), batch=4, seq_len=16, training=False)
    sh = Shardings(mesh, plan, cfg)
    sharded_params = jax.device_put(params, sh.param_shardings(params))
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab_size, 9)) for _ in range(3)]
    reqs = lambda: (
        Request(rid=f"m{i}", prompt=p, max_new_tokens=5)
        for i, p in enumerate(prompts)
    )
    got = ServingEngine(sharded_params, cfg, plan, serve, shardings=sh).run(reqs())
    want = ServingEngine(params, cfg, plan_1, serve).run(reqs())
    assert got == want


def _dense_cache_gathers(jaxpr, cache_len):
    """Gather eqns producing a (B, cache_len, ...) dense-cache buffer — the
    signature of ``paged_gather`` materializing the whole table."""
    found = []

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "gather":
                for ov in eqn.outvars:
                    shp = ov.aval.shape
                    if len(shp) >= 3 and shp[1] == cache_len:
                        found.append(shp)
            for sub in eqn.params.values():
                subs = sub if isinstance(sub, (list, tuple)) else [sub]
                for s in subs:
                    if hasattr(s, "jaxpr"):
                        walk(s.jaxpr)

    walk(jaxpr)
    return found


def test_unified_step_jaxpr_has_no_dense_gather(key):
    """The acceptance bar of the fused kernel: no dense (B, cache_len, ...)
    gather is ever materialized inside the unified step — the only gathers
    left are the (B, W)-sized embedding/table lookups.  The gather fallback
    is the positive control: its jaxpr must show the dense buffer."""
    cfg, plan, serve, params = _setup(key)
    B, W = serve.decode_batch, serve.mixed_slab_width
    args = (
        params,
        ServingEngine(params, cfg, plan, serve).pools,
        jnp.zeros((B, W), jnp.int32),
        jnp.zeros((B, serve.max_blocks_per_seq), jnp.int32),
        jnp.zeros((B,), jnp.int32),
        jnp.ones((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32),
    )

    def jaxpr_of(engine):
        return jax.make_jaxpr(lambda *a: engine._step.__wrapped__(*a))(*args).jaxpr

    fused = ServingEngine(params, cfg, plan, serve, fused=True)
    assert _dense_cache_gathers(jaxpr_of(fused), serve.max_seq_len) == [], (
        "dense cache_len gather in the unified fused step"
    )
    fallback = ServingEngine(params, cfg, plan, serve, fused=False)
    assert _dense_cache_gathers(jaxpr_of(fallback), serve.max_seq_len)


# ----------------------------------------------------------- rolled loop
def test_rolled_loop_parity_and_span_accounting(key):
    """K>1 rolled spans: byte-identical tokens to the K=1 engine, genuine
    multi-iteration dispatches, and at most ONE compile of each program."""
    cfg, plan, serve, params = _setup(key)
    assert serve.rolled_steps > 1  # tiny weights -> big dispatch slack
    rng = np.random.default_rng(8)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (5, 9, 12)]
    reqs = lambda: [
        Request(rid=f"k{i}", prompt=p, max_new_tokens=8)
        for i, p in enumerate(prompts)
    ]
    rolled = ServingEngine(params, cfg, plan, serve)
    got = rolled.run(reqs())
    k1 = ServingEngine(
        params, cfg, plan, dataclasses.replace(serve, rolled_steps=1)
    )
    assert got == k1.run(reqs())
    assert k1.trace_counts == {"step": 1}
    assert_traces_bounded(rolled.trace_counts)
    assert rolled.trace_counts["rolled_step"] == 1
    r = rolled.summary()["rolled"]
    assert r["enabled"] and r["dispatches"] >= 1 and r["mean_span"] > 1
    # the span really replaced host round-trips: device iterations advanced
    # the clock identically, but the rolled engine dispatched fewer times
    assert rolled.iteration == k1.iteration
    assert rolled.stats["rolled_steps"] > rolled.stats["rolled_dispatches"]


def test_plan_rolled_event_horizon_and_reservation(key):
    """Host-only scheduler checks: the horizon stops at each kind of host
    event, and a granted span is always fully block-covered up front."""
    from repro.serve.scheduler import RUNNING, Scheduler

    cfg = get_config("smollm-135m").reduced()
    serve = derive_serve_plan(
        cfg, MESH1, max_seq_len=64, decode_batch=2, block_size=8,
        kv_dtype="fp32", prefill_chunk=8,
    )

    def runner(s, rid, gen, arrival=0):
        r = Request(rid=rid, prompt=list(range(1, 9)), max_new_tokens=gen,
                    arrival=arrival)
        s.submit(r)
        s.admit(arrival)
        assert r.state == "prefill"
        r.state, r.out = RUNNING, [7]  # first token already emitted
        s.lens[r.slot] = len(r.prompt)
        return r

    # free horizon: cap and the runner's own remaining budget
    s = Scheduler(serve)
    r = runner(s, "a", gen=10)
    k, steps = s.plan_rolled(0, 8)
    assert k == 8 and steps[r.slot] == 8
    assert len(r.blocks) >= -(-(8 + 8) // serve.block_size)  # pre-reserved

    # an unarrived waiter bounds the span by its arrival (admission event)
    s = Scheduler(serve)
    runner(s, "a", gen=10)
    s.submit(Request(rid="w", prompt=[1] * 8, max_new_tokens=4, arrival=3))
    assert s.plan_rolled(0, 8)[0] == 3

    # an arrived-but-blocked waiter: earliest completion is its admission
    s = Scheduler(serve)
    runner(s, "a", gen=3)  # 2 steps of budget left
    runner(s, "b", gen=10)
    s.submit(Request(rid="w", prompt=[1] * 8, max_new_tokens=4, arrival=0))
    assert s.plan_rolled(0, 8)[0] == 2

    # a mid-prefill slot is host work every iteration: K=1
    s = Scheduler(serve)
    runner(s, "a", gen=10)
    p = Request(rid="p", prompt=[1] * 16, max_new_tokens=4)
    s.submit(p)
    s.admit(0)
    assert p.state == "prefill"
    assert s.plan_rolled(0, 8) == (1, None)

    # pool pressure the reservation cannot cover -> K=1 (eviction is the
    # K=1 path's job); nothing is allocated on the refused span
    tiny = dataclasses.replace(serve, n_blocks=2)  # trash + 1
    s = Scheduler(tiny)
    r = runner(s, "a", gen=20)
    held = list(r.blocks)
    assert s.plan_rolled(0, 8) == (1, None)
    assert r.blocks == held and s.alloc.available == 0


def test_summary_safe_at_zero_and_one_sample(key):
    """Regression (PR 7 satellite): summary() used to report None
    throughput for step-driven engines and count-less one-sample
    percentiles.  Cold, one-request and step-driven engines must all
    report sane numbers without run()."""
    cfg, plan, serve, params = _setup(key)
    engine = ServingEngine(params, cfg, plan, serve)
    s = engine.summary()  # cold: zero steps, zero finished requests
    assert s["tok_per_s"] is None and s["wall_s"] is None
    assert s["latency_s"] is None and s["ttft_s"] is None
    assert s["step_ms"] is None and s["tenants"] == {}

    engine.submit(Request(rid="one", prompt=[1, 2, 3], max_new_tokens=2))
    while not engine.sched.idle:
        engine.step()
    s = engine.summary()
    assert s["wall_s"] is None  # run() never measured a wall clock
    assert s["generated_tokens"] == 2
    assert s["device_s"] > 0
    assert s["tok_per_s"] == pytest.approx(2 / s["device_s"])
    lat = s["latency_s"]
    assert lat["n"] == 1  # a 1-sample p99 must be recognizable as such
    assert lat["p50"] == lat["p90"] == lat["p99"] == lat["mean"]
    assert s["step_ms"] is None or s["step_ms"] > 0


# ----------------------------------------------------------- plan-driven
def test_serve_plan_derivation_roofline_and_capacity():
    cfg = get_config("smollm-135m")
    sp = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=2048)
    # roofline batch: machine balance ~240 -> pow2 floor, capped by HBM
    assert sp.decode_batch == 128
    assert sp.block_size == TPU_V5E.mxu_dim // 8
    assert sp.kv_dtype == "bf16"
    assert sp.n_blocks == 1 + sp.decode_batch * sp.max_blocks_per_seq
    assert sp.max_concurrency == sp.decode_batch

    # starved HBM must push the KV pages to the paper's int8 grid
    tiny = dataclasses.replace(TPU_V5E, hbm_bytes=1 * 1024**3)
    sp8 = derive_serve_plan(cfg, MESH1, tiny, max_seq_len=2048)
    assert sp8.kv_dtype == "int8"
    assert sp8.decode_batch < sp.decode_batch


def test_serve_plan_kernel_knobs():
    """pages-per-tile comes from the VMEM budget (and divides the table);
    the mixed-slab width defaults to the prefill chunk."""
    cfg = get_config("smollm-135m")
    sp = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=2048)
    assert sp.mixed_slab_width == sp.prefill_chunk
    assert sp.max_blocks_per_seq % sp.pages_per_tile == 0
    assert sp.fused_attention
    # a VMEM-starved chip must take more, smaller tile sweeps
    small = dataclasses.replace(TPU_V5E, vmem_bytes=64 * 1024)
    sp_small = derive_serve_plan(cfg, MESH1, small, max_seq_len=2048)
    assert sp_small.pages_per_tile < sp.pages_per_tile
    # knobs are overridable
    sp_o = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=2048, mixed_slab_width=4, pages_per_tile=2
    )
    assert sp_o.mixed_slab_width == 4 and sp_o.pages_per_tile == 2


def test_serve_plan_rolled_steps_from_dispatch_overhead():
    """K comes from the dispatch-overhead roofline: roll until the host
    round-trip is under ~10% of the span, capped at 32 and clamped by a
    TTFT SLO (an arrival must not wait out a long span)."""
    cfg = get_config("smollm-135m")
    sp = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=2048)
    assert sp.rolled_steps >= 1
    assert sp.rolled_steps & (sp.rolled_steps - 1) == 0  # power of two
    # zero dispatch overhead: nothing to amortize, rolling stays off
    free = dataclasses.replace(TPU_V5E, dispatch_overhead_s=0.0)
    assert derive_serve_plan(cfg, MESH1, free, max_seq_len=2048).rolled_steps == 1
    # pathological dispatch cost saturates the cap
    slow = dataclasses.replace(TPU_V5E, dispatch_overhead_s=1.0)
    assert derive_serve_plan(cfg, MESH1, slow, max_seq_len=2048).rolled_steps == 32
    # a TTFT target clamps the span an in-flight dispatch may hold
    slo = derive_serve_plan(
        cfg, MESH1, slow, max_seq_len=2048, slo_ttft_ms=4.0
    )
    assert slo.rolled_steps < 32
    # explicit override wins and lands in the record
    sp_o = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=2048, rolled_steps=4)
    assert sp_o.rolled_steps == 4
    assert sp_o.to_record()["rolled_steps"] == 4
    assert "rolled_steps=4" in sp_o.describe()


def test_serve_plan_gather_tax_caps_fallback_batch():
    """The roofline's gather-bytes term only exists on the fallback path:
    the dense write+read of a full-context cache per slot per step stops
    the gather engine's batch from amortizing the weight stream, so the
    fused plan must admit at least as many decode slots."""
    cfg = get_config("smollm-135m")
    fused = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=32768)
    gather = derive_serve_plan(
        cfg, MESH1, TPU_V5E, max_seq_len=32768, fused_attention=False
    )
    assert gather.decode_batch < fused.decode_batch


def test_serve_plan_model_axis_scales_batch():
    cfg = get_config("smollm-135m")
    a = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=1024)
    b = derive_serve_plan(cfg, {"data": 1, "model": 4}, TPU_V5E, max_seq_len=1024)
    # TP shards the weight stream: per-chip balance point comes down
    assert b.decode_batch <= a.decode_batch


def test_serve_feasibility_gates():
    ok, _ = serve_feasible(get_config("smollm-135m"))
    assert ok
    for arch in ("rwkv6-1.6b", "recurrentgemma-9b", "whisper-small", "paligemma-3b"):
        ok, reason = serve_feasible(get_config(arch))
        assert not ok and reason
    with pytest.raises(ValueError):
        derive_serve_plan(get_config("rwkv6-1.6b"), MESH1)
