"""Continuous-batching engine: greedy-token parity with the eager path,
static-shape steps under request churn, plan-driven knobs, sharded serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, derive_serve_plan, serve_feasible
from repro.serve import Request, ServingEngine, greedy_generate

MESH1 = {"data": 1, "model": 1}


def _setup(key, arch="smollm-135m", **serve_kw):
    cfg = get_config(arch).reduced()
    plan = derive_plan(cfg, MESH1, batch=4, seq_len=16, training=False)
    serve_kw.setdefault("max_seq_len", 64)
    serve_kw.setdefault("decode_batch", 4)
    serve_kw.setdefault("block_size", 8)
    serve_kw.setdefault("kv_dtype", "fp32")
    serve_kw.setdefault("prefill_chunk", 8)
    serve = derive_serve_plan(cfg, MESH1, **serve_kw)
    from repro.models.params import init_params

    params = init_params(key, cfg, plan, dtype=jnp.float32)
    return cfg, plan, serve, params


def _oracle(params, cfg, plan, prompt, gen):
    """Per-request eager greedy decode (B=1), fp32 cache."""
    out = greedy_generate(
        params, cfg, plan, {"tokens": jnp.asarray(prompt)[None]},
        n_steps=gen, cache_len=len(prompt) + gen, cache_dtype=jnp.float32,
    )
    return list(np.asarray(out)[0])


def test_engine_matches_greedy_generate_staggered(key):
    """Mixed prompt lengths + staggered arrivals through the scheduler must
    produce exactly the eager path's greedy tokens — and one trace per step
    kind, however the stream churns (the no-retrace acceptance bar)."""
    cfg, plan, serve, params = _setup(key)
    rng = np.random.default_rng(0)
    lengths = [5, 8, 12, 12, 3, 9]
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in lengths]
    reqs = [
        Request(rid=f"r{i}", prompt=p, max_new_tokens=6, arrival=2 * i)
        for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve)
    got = engine.run(reqs)
    for i, p in enumerate(prompts):
        want = _oracle(params, cfg, plan, p, 6)
        assert got[f"r{i}"] == want, (i, got[f"r{i}"], want)
    assert engine.trace_counts == {"prefill": 1, "decode": 1}
    assert engine.summary()["mean_occupancy"] > 0.3


def test_engine_slot_reuse_keeps_parity(key):
    """More requests than slots: completed slots are reused in place
    (padding-free) and late requests still match the oracle."""
    cfg, plan, serve, params = _setup(key, decode_batch=2)
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size, 7)) for _ in range(5)]
    reqs = [
        Request(rid=f"s{i}", prompt=p, max_new_tokens=4) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve)
    got = engine.run(reqs)
    assert len(got) == 5
    for i, p in enumerate(prompts):
        assert got[f"s{i}"] == _oracle(params, cfg, plan, p, 4)
    assert engine.trace_counts == {"prefill": 1, "decode": 1}


def test_engine_eviction_preserves_tokens(key):
    """A pool too small for the whole stream forces recompute-preemption;
    evicted requests still return oracle-exact tokens."""
    cfg, plan, serve, params = _setup(
        key, decode_batch=2, block_size=2, prefill_chunk=4, max_seq_len=16
    )
    serve = dataclasses.replace(serve, n_blocks=1 + 8)
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(0, cfg.vocab_size, 4)) for _ in range(2)]
    reqs = [
        Request(rid=f"e{i}", prompt=p, max_new_tokens=9) for i, p in enumerate(prompts)
    ]
    engine = ServingEngine(params, cfg, plan, serve)
    got = engine.run(reqs)
    assert engine.sched.n_evictions >= 1
    for i, p in enumerate(prompts):
        assert got[f"e{i}"] == _oracle(params, cfg, plan, p, 9)


def test_engine_int8_kv_runs_and_is_deterministic(key):
    cfg, plan, serve, params = _setup(key, kv_dtype="int8")
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, 6)) for _ in range(3)]

    def run_once():
        engine = ServingEngine(params, cfg, plan, serve)
        return engine.run(
            Request(rid=f"q{i}", prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)
        )

    a, b = run_once(), run_once()
    assert a == b
    assert all(len(v) == 5 for v in a.values())


def test_engine_sharded_mesh_matches_single(key):
    """Decode through dist.Shardings on whatever host mesh exists (CI runs
    4 fake devices -> (data=1, model=4)): tokens must equal the unsharded
    engine's."""
    from repro.dist.sharding import Shardings
    from repro.launch.mesh import make_host_mesh

    cfg, plan_1, serve, params = _setup(key)
    mesh = make_host_mesh()
    plan = derive_plan(cfg, dict(mesh.shape), batch=4, seq_len=16, training=False)
    sh = Shardings(mesh, plan, cfg)
    sharded_params = jax.device_put(params, sh.param_shardings(params))
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab_size, 9)) for _ in range(3)]
    reqs = lambda: (
        Request(rid=f"m{i}", prompt=p, max_new_tokens=5)
        for i, p in enumerate(prompts)
    )
    got = ServingEngine(sharded_params, cfg, plan, serve, shardings=sh).run(reqs())
    want = ServingEngine(params, cfg, plan_1, serve).run(reqs())
    assert got == want


# ----------------------------------------------------------- plan-driven
def test_serve_plan_derivation_roofline_and_capacity():
    cfg = get_config("smollm-135m")
    sp = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=2048)
    # roofline batch: machine balance ~240 -> pow2 floor, capped by HBM
    assert sp.decode_batch == 128
    assert sp.block_size == TPU_V5E.mxu_dim // 8
    assert sp.kv_dtype == "bf16"
    assert sp.n_blocks == 1 + sp.decode_batch * sp.max_blocks_per_seq
    assert sp.max_concurrency == sp.decode_batch

    # starved HBM must push the KV pages to the paper's int8 grid
    tiny = dataclasses.replace(TPU_V5E, hbm_bytes=1 * 1024**3)
    sp8 = derive_serve_plan(cfg, MESH1, tiny, max_seq_len=2048)
    assert sp8.kv_dtype == "int8"
    assert sp8.decode_batch < sp.decode_batch


def test_serve_plan_model_axis_scales_batch():
    cfg = get_config("smollm-135m")
    a = derive_serve_plan(cfg, MESH1, TPU_V5E, max_seq_len=1024)
    b = derive_serve_plan(cfg, {"data": 1, "model": 4}, TPU_V5E, max_seq_len=1024)
    # TP shards the weight stream: per-chip balance point comes down
    assert b.decode_batch <= a.decode_batch


def test_serve_feasibility_gates():
    ok, _ = serve_feasible(get_config("smollm-135m"))
    assert ok
    for arch in ("rwkv6-1.6b", "recurrentgemma-9b", "whisper-small", "paligemma-3b"):
        ok, reason = serve_feasible(get_config(arch))
        assert not ok and reason
    with pytest.raises(ValueError):
        derive_serve_plan(get_config("rwkv6-1.6b"), MESH1)
