"""Paged KV cache: block allocator, pool write/gather, int8 round-trip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.plan import derive_plan, derive_serve_plan
from repro.models.cache import (
    init_paged_cache,
    paged_flat_slots,
    paged_gather,
    paged_update,
)
from repro.serve.scheduler import BlockAllocator, Request, Scheduler

MESH1 = {"data": 1, "model": 1}


def _serve(cfg, **kw):
    kw.setdefault("max_seq_len", 32)
    kw.setdefault("decode_batch", 2)
    kw.setdefault("block_size", 4)
    kw.setdefault("kv_dtype", "fp32")
    kw.setdefault("prefill_chunk", 4)
    return derive_serve_plan(cfg, MESH1, **kw)


# ---------------------------------------------------------------- allocator
def test_allocator_alloc_free_wraparound():
    a = BlockAllocator(6)  # blocks 1..5 allocatable, 0 is trash
    assert a.available == 5
    got = a.alloc(3)
    assert sorted(got) == [1, 2, 3]
    assert a.alloc(3) is None  # only 2 left
    a.free(got)
    assert a.available == 5
    # wraparound: freed ids come back out
    again = a.alloc(5)
    assert sorted(again) == [1, 2, 3, 4, 5]
    a.free(again)


def test_allocator_rejects_bad_frees():
    a = BlockAllocator(4)
    blocks = a.alloc(2)
    with pytest.raises(ValueError):
        a.free([0])  # trash block is never allocatable
    with pytest.raises(ValueError):
        a.free([9])
    a.free(blocks)
    with pytest.raises(ValueError):
        a.free([blocks[0]])  # double free


# ------------------------------------------------------------------- pools
def test_paged_write_gather_round_trip(key):
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=8, training=False)
    serve = _serve(cfg)
    pools = init_paged_cache(cfg, plan, serve)
    # stack: tuple over the layer pattern, leaves stacked (n_groups, N, ...)
    e0 = jax.tree.map(lambda x: x[0], pools["layers"]["stack"][0])["paged"]

    B, S, KV, Dh = 2, 6, cfg.n_kv_heads, cfg.d_head
    k = jax.random.normal(key, (B, S, KV, Dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, Dh), jnp.float32)
    # slot 0 owns blocks 1,2; slot 1 owns blocks 3,4
    table = jnp.array([[1, 2, 0, 0, 0, 0, 0, 0], [3, 4, 0, 0, 0, 0, 0, 0]], jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    e0 = paged_update(e0, k, v, pos, table, serve.block_size)
    kf, vf = paged_gather(e0, table, serve.block_size)
    np.testing.assert_allclose(np.asarray(kf[:, :S]), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(vf[:, :S]), np.asarray(v), rtol=1e-6)

    # block reuse (wraparound): slot 1's blocks handed to a new request on
    # slot 0 — fresh writes must fully shadow the stale pages
    table2 = jnp.array([[3, 4, 0, 0, 0, 0, 0, 0], [0, 0, 0, 0, 0, 0, 0, 0]], jnp.int32)
    k2 = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, Dh), jnp.float32)
    e0 = paged_update(e0, k2, v, pos, table2, serve.block_size)
    kf2, _ = paged_gather(e0, table2, serve.block_size)
    np.testing.assert_allclose(np.asarray(kf2[0, :S]), np.asarray(k2[0]), rtol=1e-6)


def test_paged_flat_slots_mapping():
    table = jnp.array([[5, 9], [7, 2]], jnp.int32)
    pos = jnp.array([[0, 3, 4], [1, 5, 7]], jnp.int32)  # block_size 4
    got = np.asarray(paged_flat_slots(table, pos, 4))
    assert got.tolist() == [[20, 23, 36], [29, 9, 11]]


def test_int8_kv_round_trip_tolerance(key):
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=8, training=False)
    serve = _serve(cfg, kv_dtype="int8")
    pools = init_paged_cache(cfg, plan, serve)
    e0 = jax.tree.map(lambda x: x[0], pools["layers"]["stack"][0])["paged"]
    assert e0["k"].dtype == jnp.int8 and "k_scale" in e0

    B, S, KV, Dh = 1, 8, cfg.n_kv_heads, cfg.d_head
    k = 3.0 * jax.random.normal(key, (B, S, KV, Dh), jnp.float32)
    v = 0.1 * jax.random.normal(jax.random.fold_in(key, 3), (B, S, KV, Dh), jnp.float32)
    table = jnp.array([[1, 2, 0, 0, 0, 0, 0, 0]], jnp.int32)
    pos = jnp.arange(S)[None]
    e0 = paged_update(e0, k, v, pos, table, serve.block_size)
    kf, vf = paged_gather(e0, table, serve.block_size)
    # per-(token, head) grid: worst case half a quantization step of the
    # vector max => ~0.5/127 relative to each vector's own scale
    for got, want in ((kf[:, :S], k), (vf[:, :S], v)):
        scale = np.abs(np.asarray(want)).max(axis=-1, keepdims=True)
        err = np.abs(np.asarray(got) - np.asarray(want)) / (scale + 1e-12)
        assert err.max() < 1.0 / 127.0, err.max()


# --------------------------------------------------------------- scheduler
def test_scheduler_eviction_and_recovery():
    """Pool too small for both runners: youngest is evicted (recompute
    preemption), re-admitted after the elder finishes, stream still drains."""
    cfg = get_config("smollm-135m").reduced()
    serve = _serve(cfg, decode_batch=2, block_size=2, prefill_chunk=4, max_seq_len=16)
    serve = dataclasses.replace(serve, n_blocks=1 + 8)  # 8 allocatable blocks
    s = Scheduler(serve)
    r0 = Request(rid="a", prompt=[1, 2, 3, 4], max_new_tokens=9)
    r1 = Request(rid="b", prompt=[5, 6, 7, 8], max_new_tokens=9)
    s.submit(r0)
    s.submit(r1)
    s.admit(0)
    assert {r0.state, r1.state} == {"prefill"}
    for r in (r0, r1):
        s.prefill_chunk_done(r, first_token=11)
    evicted = False
    for _ in range(30):
        if not s.running():
            s.admit(99)
            for r in s.slots:
                if r is not None and r.state == "prefill":
                    s.prefill_chunk_done(r, first_token=11)
            if not s.running():
                break
        s.grow_for_decode()
        evicted = evicted or s.n_evictions > 0
        s.decode_done(np.full((serve.decode_batch,), 7, np.int64))
    assert evicted and s.n_evictions >= 1
    assert {len(r.out) for r in (r0, r1)} == {9}
    assert r0.state == "done" and r1.state == "done"
    assert s.alloc.available == 8  # everything returned to the pool


def test_grow_preempts_mid_prefill_holder_instead_of_crashing():
    """Oversubscribed pool, one runner + one mid-prefill block holder: the
    runner must preempt the younger prefill slot, not raise pool-exhausted
    (regression: victims used to be drawn from running() only)."""
    cfg = get_config("smollm-135m").reduced()
    serve = _serve(cfg, decode_batch=2, block_size=2, prefill_chunk=4, max_seq_len=16)
    serve = dataclasses.replace(serve, n_blocks=1 + 7)
    s = Scheduler(serve)
    r0 = Request(rid="a", prompt=[1, 2, 3, 4], max_new_tokens=8)
    r1 = Request(rid="b", prompt=[5, 6, 7, 8, 9, 10, 11, 12], max_new_tokens=2)
    s.submit(r0)
    s.submit(r1)
    s.admit(0)  # r0: 2 blocks, r1: 4 blocks (padded prompt), 1 free
    s.prefill_chunk_done(r0, first_token=3)  # r0 RUNNING
    s.prefill_chunk_done(r1, None)  # r1 mid-prefill, holding its blocks
    for _ in range(4):  # r0 decodes until the pool runs dry
        s.grow_for_decode()
        s.decode_done(np.full((serve.decode_batch,), 7, np.int64))
    assert s.n_evictions == 1
    assert r1.state == "waiting" and not r1.blocks
    assert r0.state == "running" and len(r0.out) == 5


def test_decode_view_shields_mid_prefill_slots():
    """The batched decode writes a dummy token for every non-running slot;
    those writes must land in the trash block, never in pages a mid-prefill
    request already owns (regression: decode between two prefill chunks used
    to overwrite the request's position 0)."""
    cfg = get_config("smollm-135m").reduced()
    serve = _serve(cfg, decode_batch=2, block_size=4, prefill_chunk=4, max_seq_len=32)
    s = Scheduler(serve)
    r0 = Request(rid="run", prompt=[1, 2, 3, 4], max_new_tokens=4)
    r1 = Request(rid="pre", prompt=[5, 6, 7, 8, 9, 10, 11, 12], max_new_tokens=4)
    s.submit(r0)
    s.submit(r1)
    s.admit(0)
    s.prefill_chunk_done(r0, first_token=3)  # r0 RUNNING
    s.prefill_chunk_done(r1, None)  # r1 half prefilled (pos 4 of 8)
    assert r1.state == "prefill" and r1.blocks
    table, lens = s.decode_view()
    assert table[r0.slot].tolist() == s.table[r0.slot].tolist()
    assert table[r1.slot].tolist() == [0] * serve.max_blocks_per_seq
    assert lens[r1.slot] == 0
    # the dummy write for r1's slot resolves to the trash block, not its pages
    flat = paged_flat_slots(
        jnp.asarray(table), jnp.asarray(lens)[:, None], serve.block_size
    )
    assert int(flat[r1.slot, 0]) < serve.block_size  # trash block extent
    assert all(int(flat[r1.slot, 0]) // serve.block_size != b for b in r1.blocks)


def test_scheduler_rejects_oversized_request():
    cfg = get_config("smollm-135m").reduced()
    s = Scheduler(_serve(cfg, max_seq_len=16, prefill_chunk=4, block_size=4))
    with pytest.raises(ValueError):
        s.submit(Request(rid="x", prompt=list(range(14)), max_new_tokens=8))
