"""Paged KV cache: block allocator, pool write/gather, int8 round-trip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.plan import derive_plan, derive_serve_plan
from repro.models.cache import (
    init_paged_cache,
    paged_flat_slots,
    paged_gather,
    paged_update,
)
from repro.serve.scheduler import BlockAllocator, Request, Scheduler

MESH1 = {"data": 1, "model": 1}


def _serve(cfg, **kw):
    kw.setdefault("max_seq_len", 32)
    kw.setdefault("decode_batch", 2)
    kw.setdefault("block_size", 4)
    kw.setdefault("kv_dtype", "fp32")
    kw.setdefault("prefill_chunk", 4)
    return derive_serve_plan(cfg, MESH1, **kw)


# ---------------------------------------------------------------- allocator
def test_allocator_alloc_free_wraparound():
    a = BlockAllocator(6)  # blocks 1..5 allocatable, 0 is trash
    assert a.available == 5
    got = a.alloc(3)
    assert sorted(got) == [1, 2, 3]
    assert a.alloc(3) is None  # only 2 left
    a.free(got)
    assert a.available == 5
    # wraparound: freed ids come back out
    again = a.alloc(5)
    assert sorted(again) == [1, 2, 3, 4, 5]
    a.free(again)


def test_allocator_rejects_bad_frees():
    a = BlockAllocator(4)
    blocks = a.alloc(2)
    with pytest.raises(ValueError):
        a.free([0])  # trash block is never allocatable
    with pytest.raises(ValueError):
        a.free([9])
    a.free(blocks)
    # double free: counted, warned no-op — with refcounts a trusted second
    # free would silently steal a sharer's block, so the allocator defends
    with pytest.warns(RuntimeWarning):
        assert a.free([blocks[0]]) == []
    assert a.double_frees == 1
    assert a.available == 3  # pool unchanged by the bad free


def test_allocator_refcounts_share_and_release():
    a = BlockAllocator(6)
    got = a.alloc(2)
    a.share(got)  # second owner
    assert a.free(got) == []  # first free: still shared, nothing released
    assert a.available == 3
    assert sorted(a.free(got)) == sorted(got)  # last owner releases
    assert a.available == 5
    assert a.peak_in_use == 2
    with pytest.raises(ValueError):
        a.share(got)  # unowned blocks cannot gain sharers


# ------------------------------------------------------------------- pools
def test_paged_write_gather_round_trip(key):
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=8, training=False)
    serve = _serve(cfg)
    pools = init_paged_cache(cfg, plan, serve)
    # stack: tuple over the layer pattern, leaves stacked (n_groups, N, ...)
    e0 = jax.tree.map(lambda x: x[0], pools["layers"]["stack"][0])["paged"]

    B, S, KV, Dh = 2, 6, cfg.n_kv_heads, cfg.d_head
    k = jax.random.normal(key, (B, S, KV, Dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, Dh), jnp.float32)
    # slot 0 owns blocks 1,2; slot 1 owns blocks 3,4
    table = jnp.array([[1, 2, 0, 0, 0, 0, 0, 0], [3, 4, 0, 0, 0, 0, 0, 0]], jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    e0 = paged_update(e0, k, v, pos, table, serve.block_size)
    kf, vf = paged_gather(e0, table, serve.block_size)
    np.testing.assert_allclose(np.asarray(kf[:, :S]), np.asarray(k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(vf[:, :S]), np.asarray(v), rtol=1e-6)

    # block reuse (wraparound): slot 1's blocks handed to a new request on
    # slot 0 — fresh writes must fully shadow the stale pages
    table2 = jnp.array([[3, 4, 0, 0, 0, 0, 0, 0], [0, 0, 0, 0, 0, 0, 0, 0]], jnp.int32)
    k2 = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, Dh), jnp.float32)
    e0 = paged_update(e0, k2, v, pos, table2, serve.block_size)
    kf2, _ = paged_gather(e0, table2, serve.block_size)
    np.testing.assert_allclose(np.asarray(kf2[0, :S]), np.asarray(k2[0]), rtol=1e-6)


def test_paged_flat_slots_mapping():
    table = jnp.array([[5, 9], [7, 2]], jnp.int32)
    pos = jnp.array([[0, 3, 4], [1, 5, 7]], jnp.int32)  # block_size 4
    got = np.asarray(paged_flat_slots(table, pos, 4))
    assert got.tolist() == [[20, 23, 36], [29, 9, 11]]


def test_int8_kv_round_trip_tolerance(key):
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=8, training=False)
    serve = _serve(cfg, kv_dtype="int8")
    pools = init_paged_cache(cfg, plan, serve)
    e0 = jax.tree.map(lambda x: x[0], pools["layers"]["stack"][0])["paged"]
    assert e0["k"].dtype == jnp.int8 and "k_scale" in e0

    B, S, KV, Dh = 1, 8, cfg.n_kv_heads, cfg.d_head
    k = 3.0 * jax.random.normal(key, (B, S, KV, Dh), jnp.float32)
    v = 0.1 * jax.random.normal(jax.random.fold_in(key, 3), (B, S, KV, Dh), jnp.float32)
    table = jnp.array([[1, 2, 0, 0, 0, 0, 0, 0]], jnp.int32)
    pos = jnp.arange(S)[None]
    e0 = paged_update(e0, k, v, pos, table, serve.block_size)
    kf, vf = paged_gather(e0, table, serve.block_size)
    # per-(token, head) grid: worst case half a quantization step of the
    # vector max => ~0.5/127 relative to each vector's own scale
    for got, want in ((kf[:, :S], k), (vf[:, :S], v)):
        scale = np.abs(np.asarray(want)).max(axis=-1, keepdims=True)
        err = np.abs(np.asarray(got) - np.asarray(want)) / (scale + 1e-12)
        assert err.max() < 1.0 / 127.0, err.max()


# ---------------------------------------------------- gather high-water mark
def test_paged_gather_clamps_to_live_high_water_mark(key):
    """The fallback gather must materialize only up to the last live block
    column, not always the full cache_len (satellite fix: the eager /
    interpreter path keeps working, just smaller)."""
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=8, training=False)
    serve = _serve(cfg, max_seq_len=64, block_size=4)  # 16-wide tables
    pools = init_paged_cache(cfg, plan, serve)
    e0 = jax.tree.map(lambda x: x[0], pools["layers"]["stack"][0])["paged"]
    # only blocks in columns 0..1 are live -> gather stops at 2 blocks
    table = jnp.zeros((2, serve.max_blocks_per_seq), jnp.int32)
    table = table.at[0, :2].set(jnp.array([1, 2]))
    table = table.at[1, :1].set(jnp.array([3]))
    kf, vf = paged_gather(e0, table, serve.block_size)
    assert kf.shape[1] == 2 * serve.block_size
    # all-trash tables (idle batch) still yield one block, not zero
    kt, _ = paged_gather(e0, jnp.zeros_like(table), serve.block_size)
    assert kt.shape[1] == serve.block_size
    # explicit override and the jit path keep the full extent available
    kx, _ = paged_gather(e0, table, serve.block_size, max_blocks=4)
    assert kx.shape[1] == 4 * serve.block_size


def test_paged_update_valid_mask_routes_dead_rows_to_trash(key):
    """Mixed-slab writes: rows past a slot's ``kinds`` count must land in
    the trash block, never in the slot's own (or anyone else's) pages."""
    cfg = get_config("smollm-135m").reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=8, training=False)
    serve = _serve(cfg)
    pools = init_paged_cache(cfg, plan, serve)
    e0 = jax.tree.map(lambda x: x[0], pools["layers"]["stack"][0])["paged"]
    B, S, KV, Dh = 2, 4, cfg.n_kv_heads, cfg.d_head
    k = jax.random.normal(key, (B, S, KV, Dh), jnp.float32)
    table = jnp.array([[1, 2, 0, 0, 0, 0, 0, 0], [3, 4, 0, 0, 0, 0, 0, 0]], jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    valid = jnp.array([[True] * 4, [True, False, False, False]])
    flat = np.asarray(paged_flat_slots(table, pos, serve.block_size, valid))
    bs = serve.block_size
    assert (flat[1, 1:] < bs).all()  # dead rows -> trash block extent
    assert (flat[0] >= bs).all() and flat[1, 0] >= bs  # live rows -> own pages
    # a full masked update leaves the dead rows' would-be pages untouched
    e1 = paged_update(e0, k, k, pos, table, bs, valid)
    np.testing.assert_array_equal(
        np.asarray(e1["k"])[4, 1:], np.zeros((bs - 1, KV, Dh))
    )
    # positions past the table extent (a decode row's dead tail) must clamp,
    # not index out of range
    far = pos + serve.max_blocks_per_seq * bs
    paged_flat_slots(table, far, bs, jnp.zeros_like(valid))


# --------------------------------------------------------------- scheduler
def _drive_slab(s, serve, token=7):
    """One host-side engine iteration against a fake device step."""
    s.admit(10**9)
    s.grow_for_decode()
    tokens, tables, lens, kinds = s.slab_view(serve.mixed_slab_width)
    s.slab_done(np.full((serve.decode_batch,), token, np.int64), kinds)
    return kinds


def test_scheduler_eviction_and_recovery():
    """Pool too small for both runners: youngest is evicted (recompute
    preemption), re-admitted after the elder finishes, stream still drains."""
    cfg = get_config("smollm-135m").reduced()
    serve = _serve(cfg, decode_batch=2, block_size=2, prefill_chunk=4, max_seq_len=16)
    serve = dataclasses.replace(serve, n_blocks=1 + 8)  # 8 allocatable blocks
    s = Scheduler(serve)
    r0 = Request(rid="a", prompt=[1, 2, 3, 4], max_new_tokens=9)
    r1 = Request(rid="b", prompt=[5, 6, 7, 8], max_new_tokens=9)
    s.submit(r0)
    s.submit(r1)
    s.admit(0)
    assert {r0.state, r1.state} == {"prefill"}
    for _ in range(40):
        if s.idle:
            break
        _drive_slab(s, serve)
    assert s.n_evictions >= 1
    assert {len(r.out) for r in (r0, r1)} == {9}
    assert r0.state == "done" and r1.state == "done"
    assert s.alloc.available == 8  # everything returned to the pool


def test_grow_preempts_mid_prefill_holder_instead_of_crashing():
    """Oversubscribed pool, one runner + one mid-prefill block holder: the
    runner must preempt the younger prefill slot, not raise pool-exhausted
    (regression: victims used to be drawn from running() only)."""
    cfg = get_config("smollm-135m").reduced()
    serve = _serve(
        cfg, decode_batch=2, block_size=2, prefill_chunk=4, max_seq_len=16,
        mixed_slab_width=4,
    )
    serve = dataclasses.replace(serve, n_blocks=1 + 7)
    s = Scheduler(serve)
    r0 = Request(rid="a", prompt=[1, 2, 3, 4], max_new_tokens=8, arrival=0)
    r1 = Request(rid="b", prompt=[5, 6, 7, 8, 9, 10, 11, 12], max_new_tokens=2,
                 arrival=1)
    s.submit(r0)
    s.submit(r1)
    s.admit(0)  # r0 admitted alone: 2 blocks
    tokens, tables, lens, kinds = s.slab_view(4)
    s.slab_done(np.full((2,), 3, np.int64), kinds)  # r0 RUNNING
    s.admit(1)  # r1 takes 4 blocks, 1 free; stays mid-prefill (8 > slab 4)
    tokens, tables, lens, kinds = s.slab_view(4)
    assert r1.state == "prefill" and r1.blocks
    for _ in range(4):  # r0 decodes until the pool runs dry
        s.grow_for_decode()
        _, _, _, kinds = s.slab_view(4)
        s.slab_done(np.full((2,), 7, np.int64), kinds)
    assert s.n_evictions == 1
    assert r1.state == "waiting" and not r1.blocks
    assert r0.state == "running" and len(r0.out) == 5


def test_slab_view_masks_idle_and_mid_prefill_rows():
    """Slab packing invariants: an idle slot's row is dead (kinds 0, table
    all-trash); a mid-prefill slot carries its own chunk at its own offset
    and its dead rows resolve to the trash block, never its pages."""
    cfg = get_config("smollm-135m").reduced()
    serve = _serve(
        cfg, decode_batch=3, block_size=4, prefill_chunk=4, max_seq_len=32
    )
    s = Scheduler(serve)
    r0 = Request(rid="run", prompt=[1, 2, 3, 4], max_new_tokens=4)
    r1 = Request(rid="pre", prompt=[5, 6, 7, 8, 9, 10, 11, 12], max_new_tokens=4)
    s.submit(r0)
    s.submit(r1)
    s.admit(0)
    _, _, _, kinds = s.slab_view(4)
    s.slab_done(np.full((3,), 3, np.int64), kinds)  # r0 RUNNING, r1 pos=4
    assert r0.state == "running" and r1.state == "prefill" and r1.pos == 4
    tokens, tables, lens, kinds = s.slab_view(4)
    assert kinds[r0.slot] == 1 and tokens[r0.slot, 0] == 3
    assert kinds[r1.slot] == 4 and lens[r1.slot] == 4
    assert tokens[r1.slot].tolist() == [9, 10, 11, 12]
    idle = next(b for b in range(3) if s.slots[b] is None)
    assert kinds[idle] == 0 and tables[idle].tolist() == [0] * tables.shape[1]
    # dead rows of the decode slot route to the trash block, not its pages
    pos = lens[:, None] + np.arange(4)[None]
    valid = np.arange(4)[None] < kinds[:, None]
    flat = np.asarray(
        paged_flat_slots(
            jnp.asarray(tables), jnp.asarray(pos), serve.block_size,
            jnp.asarray(valid),
        )
    )
    bs = serve.block_size
    assert (flat[r0.slot, 1:] < bs).all() and (flat[idle] < bs).all()
    assert all(f // bs in r1.blocks for f in flat[r1.slot])


def test_scheduler_rejects_oversized_request():
    cfg = get_config("smollm-135m").reduced()
    s = Scheduler(_serve(cfg, max_seq_len=16, prefill_chunk=4, block_size=4))
    with pytest.raises(ValueError):
        s.submit(Request(rid="x", prompt=list(range(14)), max_new_tokens=8))
