"""pod_role="pipeline" end-to-end: a plan trains through launch/train.py on
a 4-device fake mesh (2 stages x dp 2) with loss matching the data-parallel
baseline (subprocess: the main test process keeps 1 device)."""
import json
import subprocess
import sys

import jax.tree_util as jtu
import pytest

from repro.configs import get_config
from repro.core.plan import derive_plan
from repro.dist.sharding import Shardings
from repro.models.transformer import check_pipeline_supported


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


class Leaf:
    def __init__(self, shape):
        self.shape = shape


PIPE_MESH = {"pod": 2, "data": 2, "model": 1}


def _pipe_plan(arch="smollm-135m-reduced", batch=8, **kw):
    cfg = get_config(arch)
    return cfg, derive_plan(
        cfg, PIPE_MESH, batch=batch, seq_len=32, training=True,
        pod_role="pipeline", **kw,
    )


def test_pipeline_plan_fills_the_pipe():
    cfg, plan = _pipe_plan()
    assert plan.pod_role == "pipeline"
    # enough microbatches to amortize the bubble, still dividing the batch
    assert plan.microbatches >= plan.pod_axis
    assert 8 % plan.microbatches == 0
    # and the microbatch still folds over the data axis
    assert (8 // plan.microbatches) % PIPE_MESH["data"] == 0


def test_param_spec_slices_stack_over_pod():
    cfg, plan = _pipe_plan()
    sh = Shardings(FakeMesh(PIPE_MESH), plan, cfg)
    path = [jtu.DictKey(k) for k in ("blocks", "stack", "attn", "wqkv")]
    spec = sh.param_spec(path, Leaf((2, 64, 128)))
    assert spec[0] == "pod"  # per-stage slice on the stacked leading dim
    # non-stack leaves stay unsliced
    spec2 = sh.param_spec([jtu.DictKey("embed")], Leaf((512, 64)))
    assert spec2[0] != "pod"


def test_pipeline_rejects_moe():
    cfg, plan = _pipe_plan("mixtral-8x7b-reduced")
    with pytest.raises(ValueError, match="MoE"):
        check_pipeline_supported(cfg, plan, 8)


_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
from repro.launch.train import run

lp, _ = run("smollm-135m-reduced", steps=3, batch=8, seq=32,
            pipeline=2, dp=2, log_every=0)
lb, _ = run("smollm-135m-reduced", steps=3, batch=8, seq=32, dp=4, log_every=0)
print(json.dumps({"pipeline": lp, "baseline": lb}))
"""


def test_pipeline_train_matches_data_parallel_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _SNIPPET],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd=".",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    diffs = [abs(a - b) for a, b in zip(out["pipeline"], out["baseline"])]
    assert max(diffs) < 1e-4, f"pipeline diverges from DP baseline: {out}"
    # the run actually went somewhere (optimizer applied every step)
    assert out["pipeline"][0] != out["pipeline"][-1]
