"""Checkpoint: roundtrip, atomic manifests, resume, elastic restore."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "a": {"w": jax.random.normal(k1, (8, 16), jnp.float32)},
        "b": (jax.random.normal(k2, (4,), jnp.bfloat16), jnp.int32(7)),
    }


def test_roundtrip(tmp_path, key):
    t = _tree(key)
    save_checkpoint(str(tmp_path), 10, t)
    got = restore_checkpoint(str(tmp_path), 10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_step_ignores_incomplete(tmp_path, key):
    t = _tree(key)
    save_checkpoint(str(tmp_path), 5, t)
    save_checkpoint(str(tmp_path), 10, t)
    # a crashed save: directory without manifest
    (tmp_path / "step_15").mkdir()
    assert latest_step(str(tmp_path)) == 10


def test_async_save(tmp_path, key):
    t = _tree(key)
    thread = save_checkpoint(str(tmp_path), 3, t, async_save=True)
    thread.join()
    assert latest_step(str(tmp_path)) == 3
    m = json.loads((tmp_path / "step_3" / "manifest.json").read_text())
    assert m["step"] == 3 and m["n_arrays"] == 3


def test_train_resume_bitexact(tmp_path):
    """Kill/restart: resumed run must follow the same loss trajectory."""
    from repro.launch.train import run

    losses_a, _ = run(
        "smollm-135m-reduced", steps=8, batch=2, seq=32,
        ckpt_dir=str(tmp_path), ckpt_every=4, log_every=0,
    )
    losses_b, _ = run(
        "smollm-135m-reduced", steps=8, batch=2, seq=32,
        ckpt_dir=str(tmp_path), ckpt_every=4, resume=True, log_every=0,
    )  # resumes at step 8... nothing to do; rerun from 4:
    # remove step_8 so resume starts at 4 and replays 4..8
    import shutil

    if (tmp_path / "step_8").exists():
        shutil.rmtree(tmp_path / "step_8")
    losses_c, _ = run(
        "smollm-135m-reduced", steps=8, batch=2, seq=32,
        ckpt_dir=str(tmp_path), ckpt_every=100, resume=True, log_every=0,
    )
    np.testing.assert_allclose(losses_c, losses_a[4:], rtol=1e-4)
