"""Prefill -> decode consistency vs the full-sequence oracle, all archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import ALL_ARCHS, get_config
from repro.core.plan import derive_plan
from repro.models import cache_from_prefill, forward, init_params

MESH1 = {"data": 1, "model": 1}
DECODE_ARCHS = [a for a in ALL_ARCHS if not get_config(a).encoder_only]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_full(arch, key):
    cfg = get_config(arch).reduced()
    plan = derive_plan(cfg, MESH1, batch=2, seq_len=8, training=False)
    params = init_params(key, cfg, plan, dtype=jnp.float32)
    B, S0, EXTRA = 2, 8, 3
    tokens = jax.random.randint(key, (B, S0 + EXTRA), 0, cfg.vocab_size)
    base = make_batch(cfg, key, B=B, S=S0)
    base.pop("targets", None)
    base.pop("label", None)

    full = dict(base)
    full["tokens"] = tokens
    x_full, _, _ = forward(params, full, cfg=cfg, plan=plan)

    pre = dict(base)
    pre["tokens"] = tokens[:, :S0]
    _, pc, _ = forward(params, pre, cfg=cfg, plan=plan, collect_cache=True)
    P = cfg.n_prefix_embeds if cfg.frontend != "none" else 0
    cache = cache_from_prefill(cfg, plan, pc, cache_len=P + S0 + EXTRA + 2)
    outs = []
    for t in range(EXTRA):
        step = {"tokens": tokens[:, S0 + t : S0 + t + 1]}
        x1, cache, _ = forward(params, step, cfg=cfg, plan=plan, cache=cache)
        outs.append(np.asarray(x1[:, 0]))
    want = np.asarray(x_full[:, P + S0 : P + S0 + EXTRA])
    got = np.stack(outs, axis=1)
    err = np.max(np.abs(want - got)) / (np.max(np.abs(want)) + 1e-9)
    assert err < 2e-3, f"{arch}: decode diverges from full pass (rel {err:.1e})"


def test_windowed_ring_cache_wraps(key):
    """Decode past the window: ring buffer must equal the full-seq oracle."""
    import dataclasses

    cfg = dataclasses.replace(
        get_config("mixtral-8x7b").reduced(), sliding_window=8, n_layers=2
    )
    plan = derive_plan(cfg, MESH1, batch=1, seq_len=8, training=False)
    params = init_params(key, cfg, plan, dtype=jnp.float32)
    T = 20  # well past the window
    tokens = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    x_full, _, _ = forward(params, {"tokens": tokens}, cfg=cfg, plan=plan)
    _, pc, _ = forward(
        params, {"tokens": tokens[:, :4]}, cfg=cfg, plan=plan, collect_cache=True
    )
    from repro.models import cache_from_prefill

    cache = cache_from_prefill(cfg, plan, pc, cache_len=cfg.sliding_window)
    outs = []
    for t in range(4, T):
        x1, cache, _ = forward(
            params, {"tokens": tokens[:, t : t + 1]}, cfg=cfg, plan=plan, cache=cache
        )
        outs.append(np.asarray(x1[:, 0]))
    got = np.stack(outs, axis=1)
    want = np.asarray(x_full[:, 4:])
    err = np.max(np.abs(want - got)) / (np.max(np.abs(want)) + 1e-9)
    assert err < 2e-3, f"ring cache wrap mismatch: {err:.1e}"
