"""Sharding rules: divisibility safety net, Megatron orientation, cache specs."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.plan import derive_plan
from repro.dist.sharding import Shardings
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def setup():
    mesh = make_host_mesh()  # 1 device: specs still constructed/validated
    cfg = get_config("qwen3-1.7b")
    plan = derive_plan(cfg, {"data": 16, "model": 16}, batch=256, seq_len=4096)
    return mesh, cfg, plan


class FakeMesh:
    """Shape-only stand-in so spec logic is testable without 256 devices."""

    def __init__(self, shape):
        self.shape = shape


def _sh(arch="qwen3-1.7b", mesh_shape=None, **kw):
    mesh_shape = mesh_shape or {"data": 16, "model": 16}
    cfg = get_config(arch)
    plan = derive_plan(cfg, mesh_shape, **kw)
    return Shardings(FakeMesh(dict(mesh_shape)), plan, cfg), cfg, plan


def test_megatron_orientation_spatial():
    sh, cfg, plan = _sh(batch=256, seq_len=4096)
    assert plan.mha.mode == "spatial"
    class L:  # fake leaf
        def __init__(self, shape): self.shape = shape
    import jax.tree_util as jtu
    wqkv = sh.param_spec([jtu.DictKey("blocks"), jtu.DictKey("stack"),
                          jtu.DictKey("attn"), jtu.DictKey("wqkv")],
                         L((28, 2048, 4096)))
    assert wqkv[-1] == "model"  # column parallel
    wo = sh.param_spec([jtu.DictKey("attn"), jtu.DictKey("wo")], L((2048, 2048)))
    assert wo[0] == "model"  # row parallel


def test_fit_drops_nondivisible():
    sh, _, _ = _sh()
    spec = sh._fit(P("model", None), (100, 64))  # 100 % 16 != 0
    assert spec[0] is None
    spec2 = sh._fit(P("model", None), (128, 64))
    assert spec2[0] == "model"


def test_batch_axes_fold_for_temporal():
    sh, cfg, plan = _sh("smollm-135m", batch=256, seq_len=4096)
    assert plan.dp_over_model
    assert sh.batch_axes_for(256) == ("data", "model")
    # batch that only divides data
    assert sh.batch_axes_for(16) == ("data",)
    assert sh.batch_axes_for(3) is None


def test_moe_param_specs():
    sh, cfg, plan = _sh("qwen3-moe-30b-a3b", batch=256, seq_len=4096)
    assert plan.moe_mode == "ep"
    import jax.tree_util as jtu

    class L:
        def __init__(self, shape): self.shape = shape
    w1 = sh.param_spec(
        [jtu.DictKey("blocks"), jtu.DictKey("stack"), jtu.DictKey("ffn"),
         jtu.DictKey("w1")],
        L((48, 128, 2048, 768)),
    )
    assert w1[1] == "model"  # experts sharded (stacked leading dim)


def test_cache_seq_sharded_over_model():
    sh, cfg, plan = _sh(batch=128, seq_len=32768, training=False)
    import jax.tree_util as jtu

    class L:
        def __init__(self, shape): self.shape = shape
    spec = sh.cache_spec(
        [jtu.DictKey("layers"), jtu.DictKey("stack"), jtu.DictKey("attn"),
         jtu.DictKey("k")],
        L((28, 128, 32768, 8, 128)),
    )
    # stacked: (None, batch, "model" on seq, None, None)
    assert spec[2] == "model"
