"""End-to-end behaviour: training descends, serving generates, the
multi-device dry-run machinery works (subprocess: tests keep 1 device)."""
import json
import subprocess
import sys

import numpy as np


def test_training_loss_descends(tmp_path):
    from repro.launch.train import run

    losses, _ = run(
        "smollm-135m-reduced", steps=40, batch=4, seq=64, lr=1e-3, log_every=0
    )
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.05, f"loss did not descend: {first:.3f} -> {last:.3f}"


def test_gradient_compression_still_descends():
    from repro.launch.train import run

    losses, _ = run(
        "smollm-135m-reduced", steps=30, batch=4, seq=64, lr=1e-3,
        compression="int8", log_every=0,
    )
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_greedy_generation_runs(key):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.plan import derive_plan
    from repro.models import init_params
    from repro.serve.engine import greedy_generate

    cfg = get_config("qwen3-1.7b").reduced()
    plan = derive_plan(cfg, {"data": 1, "model": 1}, batch=2, seq_len=8, training=False)
    params = init_params(key, cfg, plan, dtype=jnp.float32)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    out = greedy_generate(params, cfg, plan, batch, n_steps=4, cache_len=16)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab_size


_DRYRUN_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs import get_config, TRAIN_4K
import repro.configs.shapes as shapes
import dataclasses
from repro.launch.dryrun import build_cell
from repro.core.hlo_cost import analyze_hlo

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = get_config("qwen3-1.7b").reduced()
shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=8)
fn, args, plan = build_cell(cfg, shape, mesh)
compiled = fn.lower(*args).compile()
hc = analyze_hlo(compiled.as_text())
print(json.dumps({
    "flops": hc.flops,
    "n_coll": len(hc.collectives),
    "coll_bytes": hc.collective_operand_bytes,
}))
"""


def test_sharded_dryrun_subprocess():
    """8 fake devices in a child process: lower+compile+cost must succeed and
    produce collectives (the distribution config is coherent)."""
    r = subprocess.run(
        [sys.executable, "-c", _DRYRUN_SNIPPET],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=".",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
    assert out["n_coll"] > 0  # sharded training must communicate
