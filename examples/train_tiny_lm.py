"""End-to-end driver: train a ~100M-class reduced LM for a few hundred steps
with checkpointing, resume, gradient compression and the step watchdog.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]

This is the deliverable-(b) end-to-end training example: it asserts the loss
actually descends and demonstrates kill/resume fault tolerance.
"""
import argparse
import shutil
import tempfile

from repro.ckpt.checkpoint import latest_step
from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="smollm-135m-reduced")
    a = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="cat_ckpt_")
    try:
        half = a.steps // 2
        print(f"=== phase 1: steps 0..{half} (checkpointing to {ckpt}) ===")
        losses1, _ = run(
            a.arch, steps=half, batch=8, seq=128, lr=1e-3,
            ckpt_dir=ckpt, ckpt_every=50, compression="bf16", log_every=25,
        )
        print(f"latest checkpoint: step {latest_step(ckpt)}")
        print(f"=== phase 2 (simulated restart): resume -> {a.steps} ===")
        losses2, _ = run(
            a.arch, steps=a.steps, batch=8, seq=128, lr=1e-3,
            ckpt_dir=ckpt, ckpt_every=50, resume=True,
            compression="bf16", log_every=25,
        )
        first, last = losses1[0], losses2[-1]
        print(f"\nloss {first:.4f} -> {last:.4f}")
        assert last < first - 0.1, "training failed to descend"
        print("OK: loss descended across a checkpoint/restart boundary")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
