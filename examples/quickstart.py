"""Quickstart: derive a CAT accelerator instance, train a tiny LM for a few
steps, and decode from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan
from repro.launch.mesh import make_host_mesh
from repro.launch.train import run
from repro.serve.engine import greedy_generate


def main():
    # 1. The CAT contract: (model config, mesh, hardware) -> accelerator plan.
    cfg = get_config("qwen3-1.7b")
    plan = derive_plan(
        cfg, {"data": 16, "model": 16}, TPU_V5E, batch=256, seq_len=4096
    )
    print("=== derived accelerator instance (production mesh) ===")
    print(plan.describe())

    # 2. Train the reduced family member on this host for a few steps.
    print("\n=== training qwen3-1.7b-reduced for 30 steps ===")
    losses, state = run("qwen3-1.7b-reduced", steps=30, batch=4, seq=64, lr=1e-3)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    # 3. Serve: prefill + greedy decode with the trained weights.
    print("\n=== greedy decode ===")
    rcfg = get_config("qwen3-1.7b").reduced()
    host_plan = derive_plan(
        rcfg, dict(make_host_mesh().shape), batch=2, seq_len=16, training=False
    )
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, rcfg.vocab_size)
    }
    out = greedy_generate(state.params, rcfg, host_plan, batch, n_steps=8, cache_len=32)
    print("generated token ids:\n", out)


if __name__ == "__main__":
    main()
