"""The paper's §V.B design case + accelerator-family derivation for any arch.

    PYTHONPATH=src python examples/derive_accelerator.py --arch mixtral-8x7b
    PYTHONPATH=src python examples/derive_accelerator.py --design-case

--design-case reproduces the BERT-Base walk-through on the paper's own
VCK5000 numbers (Factor1 ~= 1.5, Factor2 ~= 7.56 MB, P_ATB = 4, fully
pipelined mode) — the validation anchor against the paper's §V.B.
"""
import argparse

from repro.configs import ALL_ARCHS, get_config
from repro.core.hardware import TPU_V5E
from repro.core.plan import derive_plan, design_case_vck5000
from repro.core.pu import derive_pu_family


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--design-case", action="store_true")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    a = ap.parse_args()

    if a.design_case:
        dc = design_case_vck5000()
        print("paper §V.B design case (VCK5000, BERT-Base L=256):")
        for k, v in dc.items():
            print(f"  {k:26s} = {v if not isinstance(v, float) else round(v, 4)}")
        print("  (paper reports Factor1~1.5, Factor2=7.5625MB, P_ATB=4,")
        print("   fully-pipelined mode — all four reproduced)")
        return

    print("MM PU family for TPU v5e (paper Fig. 4 analog):")
    for name, spec in derive_pu_family(TPU_V5E).items():
        print(
            f"  {name:8s} {spec.block_m}x{spec.block_n}x{spec.block_k} "
            f"({spec.vmem_bytes/2**20:.1f} MiB VMEM, AI={spec.arithmetic_intensity:.0f})"
        )
    archs = [a.arch] if a.arch else list(ALL_ARCHS)
    for arch in archs:
        cfg = get_config(arch)
        for mesh in ({"data": 16, "model": 16}, {"pod": 2, "data": 16, "model": 16}):
            plan = derive_plan(cfg, mesh, TPU_V5E, batch=a.batch, seq_len=a.seq)
            print(f"\n--- {arch} on {mesh} ---")
            print(plan.describe())


if __name__ == "__main__":
    main()
