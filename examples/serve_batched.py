"""Batched serving example across architecture families: dense GQA, MoE,
attention-free RWKV6, and enc-dec whisper — same engine, different ATBs.

The decode is *sharded*: 4 fake host devices form a (data=1, model=4) mesh,
params are placed by ``repro.dist.Shardings`` (Megatron orientation with the
divisibility safety net — smoke-sized dims that do not divide the axis stay
replicated), and the jitted decode runs under the plan's activation
constraints.

Part 2 serves a *staggered* request stream through the continuous-batching
engine (paged KV cache + unified mixed prefill/decode step) on the same
sharded mesh — mixed prompt lengths, no lockstep, one trace total.

Part 3 turns speculation on: smollm-135m (reduced) drafts gamma tokens per
slot for qwen3-1.7b (reduced), the same unified slab verifies gamma+1 rows
per speculating slot, and the emitted tokens are asserted identical to the
plain engine's — the draft source only changes how many tokens one step
yields, never which tokens.

    PYTHONPATH=src python examples/serve_batched.py
"""
import os

# Prepend (not setdefault): the demo needs its 4 fake devices even when the
# user already has unrelated XLA_FLAGS set.  Must run before jax imports.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.plan import derive_plan
from repro.dist.sharding import Shardings
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.serve.engine import greedy_generate


def main():
    mesh = make_host_mesh()
    for arch in ("qwen3-1.7b", "mixtral-8x7b", "rwkv6-1.6b", "whisper-small"):
        cfg = get_config(arch).reduced()
        plan = derive_plan(
            cfg, dict(mesh.shape), batch=4, seq_len=16, training=False
        )
        sh = Shardings(mesh, plan, cfg)
        params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
        param_sh = sh.param_shardings(params)
        params = jax.device_put(params, param_sh)
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
        if cfg.enc_dec:
            batch["enc_embeds"] = jax.random.normal(
                key, (4, cfg.enc_seq, cfg.d_model), jnp.float32
            )
        batch = jax.device_put(batch, sh.batch_shardings(batch))
        t0 = time.time()
        out = greedy_generate(
            params, cfg, plan, batch, n_steps=8, cache_len=40, shard=sh.constrain
        )
        dt = time.time() - t0
        n_sharded = sum(
            s.spec != jax.sharding.PartitionSpec(*([None] * len(s.spec)))
            for s in jax.tree.leaves(param_sh)
        )
        print(
            f"{arch:18s} mesh={dict(mesh.shape)} sharded_leaves={n_sharded:3d} "
            f"generated {out.shape[0]}x{out.shape[1]} tokens in "
            f"{dt:5.1f}s ({out.size/dt:6.1f} tok/s)  sample: {out[0][:6].tolist()}"
        )

    # ---- part 2: continuous batching on the sharded mesh -------------------
    from repro.core.plan import derive_serve_plan
    from repro.serve import ServingEngine
    from repro.serve.scheduler import random_stream

    cfg = get_config("qwen3-1.7b").reduced()
    plan = derive_plan(cfg, dict(mesh.shape), batch=4, seq_len=16, training=False)
    serve = derive_serve_plan(
        cfg, dict(mesh.shape), max_seq_len=64, decode_batch=4, prefill_chunk=8
    )
    sh = Shardings(mesh, plan, cfg)
    params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
    params = jax.device_put(params, sh.param_shardings(params))
    reqs = random_stream(cfg, 6, (4, 14), gen=8, stagger=2, seed=0, rid_prefix="r")
    engine = ServingEngine(params, cfg, plan, serve, shardings=sh)
    out = engine.run(reqs)
    s = engine.summary()
    print(
        f"continuous batching: {len(out)} staggered requests, "
        f"occupancy={s['mean_occupancy']:.2f} traces={s['traces']} "
        f"tok/s={s['tok_per_s']:.1f}  r000: {out['r000']}"
    )

    # ---- part 3: speculative decoding (small model drafts, big verifies) ---
    from repro.serve.speculative import make_draft_source

    serve_spec = derive_serve_plan(
        cfg, {"data": 1, "model": 1}, max_seq_len=64, decode_batch=4,
        prefill_chunk=8, draft="smollm-135m", spec_len=3,
    )
    plan1 = derive_plan(
        cfg, {"data": 1, "model": 1}, batch=4, seq_len=16, training=False
    )
    params1 = init_params(jax.random.PRNGKey(0), cfg, plan1, dtype=jnp.float32)
    stream = lambda: random_stream(
        cfg, 6, (4, 14), gen=8, stagger=2, seed=0, rid_prefix="r"
    )
    plain = ServingEngine(params1, cfg, plan1, serve_spec).run(stream())
    draft = make_draft_source("smollm-135m", cfg, serve_spec, reduced=True)
    spec_engine = ServingEngine(params1, cfg, plan1, serve_spec, draft=draft)
    spec_out = spec_engine.run(stream())
    assert spec_out == plain, "speculation changed tokens (it never may)"
    ss = spec_engine.summary()["spec"]
    print(
        f"speculative decoding: {draft.name} drafting gamma={serve_spec.spec_len} "
        f"for {cfg.name}: acceptance={ss['acceptance_rate']:.2f}, "
        f"{ss['tokens_per_spec_step']:.2f} tokens/step on speculating slots "
        f"(plain decode = 1.0), tokens identical: True"
    )


if __name__ == "__main__":
    main()
