"""Batched serving example across architecture families: dense GQA, MoE,
attention-free RWKV6, and enc-dec whisper — same engine, different ATBs.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.plan import derive_plan
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.serve.engine import greedy_generate


def main():
    mesh = make_host_mesh()
    for arch in ("qwen3-1.7b", "mixtral-8x7b", "rwkv6-1.6b", "whisper-small"):
        cfg = get_config(arch).reduced()
        plan = derive_plan(
            cfg, dict(mesh.shape), batch=4, seq_len=16, training=False
        )
        params = init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.float32)
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
        if cfg.enc_dec:
            batch["enc_embeds"] = jax.random.normal(
                key, (4, cfg.enc_seq, cfg.d_model), jnp.float32
            )
        t0 = time.time()
        out = greedy_generate(params, cfg, plan, batch, n_steps=8, cache_len=40)
        dt = time.time() - t0
        print(
            f"{arch:18s} generated {out.shape[0]}x{out.shape[1]} tokens in "
            f"{dt:5.1f}s ({out.size/dt:6.1f} tok/s)  sample: {out[0][:6].tolist()}"
        )


if __name__ == "__main__":
    main()
